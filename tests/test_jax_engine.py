"""Device-resident jax phase-engine guarantees.

What the jitted pipeline must preserve (docs/performance.md):

  * numpy parity across the topology family AND across the hard phase
    kinds that used to force a numpy fallback — fault candidate masks
    and active congestion notifications — proven to have actually run
    on jax via the `PIPELINE_CALLS` dispatch counters;
  * device/queue state correctness across `reset_queues()` and
    fault/notify epoch bumps (the numpy backend is the oracle, and the
    plan cache must hand back a FRESH device bundle after a bump);
  * the `SimParams.pallas_kernel` knob: "on" (interpret off-TPU) agrees
    with "off" within the pinned tolerance, "auto" resolves to the ref
    path on CPU, junk is rejected;
  * `run_phase_batch` / the tenancy lockstep sweep: batching changes
    the dispatch, never the results.
"""

import numpy as np
import pytest

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TopologyParams)
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.simulator import run_phase_batch
from repro.dragonfly.topology import make_allocation, small_topology
from repro.faults import FaultSchedule, link_down

JAX_RTOL = 2e-2   # float32 pipeline vs float64 numpy (docs/performance.md)

TOPO = DragonflyTopology(TopologyParams(n_groups=4, chassis_per_group=2,
                                        blades_per_chassis=4))


def _jax_ok():
    from repro.compat.runtime import resolve_backend
    return resolve_backend("jax") == "jax"


requires_jax = pytest.mark.skipif(not _jax_ok(), reason="jax unavailable")


def _flows(topo, seed=42, n=400):
    rng = np.random.default_rng(seed)
    n_nodes = topo.n_nodes
    src = rng.integers(0, n_nodes, size=n)
    dst = (src + rng.integers(1, n_nodes, size=n)) % n_nodes
    size = rng.pareto(1.2, size=n) * 65536 + 1024
    return src, dst, size


def _assert_close(rj, rn, rtol=JAX_RTOL):
    np.testing.assert_allclose(rj.t_us, rn.t_us, rtol=rtol)
    np.testing.assert_allclose(rj.latency_us, rn.latency_us, rtol=rtol)
    np.testing.assert_allclose(rj.stalls_per_flit, rn.stalls_per_flit,
                               rtol=rtol, atol=1e-4)
    assert np.array_equal(rj.flits, rn.flits)


def _dispatches():
    from repro.dragonfly.jax_backend import PIPELINE_CALLS
    return sum(PIPELINE_CALLS.values())


# --------------------------------------------------------------------------
# Parity matrix: topology family x {healthy, faulted, notifying} — and
# the jax pipeline must actually DISPATCH on the masked/notified phases
# (they used to silently fall back to numpy).
# --------------------------------------------------------------------------
@requires_jax
@pytest.mark.parametrize("name", ["aries", "dragonfly", "dragonfly_plus"])
@pytest.mark.parametrize("scenario", ["healthy", "faulted", "notifying"])
def test_jax_parity_topology_family(name, scenario):
    topo = small_topology(name)
    src, dst, size = _flows(topo, seed=7)
    kw = {"seed": 5}
    if scenario == "notifying":
        kw.update(notify_threshold_s=1e-5, notify_penalty_s=300e-6)
    sims = {}
    for be in ("numpy", "jax"):
        sim = DragonflySimulator(topo, SimParams(backend=be, **kw))
        if scenario == "faulted":
            sim.set_faults(FaultSchedule.of(
                link_down([1, topo.n_links // 2], start=0)))
        sims[be] = sim
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_2)
    before = _dispatches()
    for _ in range(3):      # phase 2+ sees raised notifications / queues
        rn = sims["numpy"].run_phase(src, dst, size, pol)
        rj = sims["jax"].run_phase(src, dst, size, pol)
        _assert_close(rj, rn)
    assert _dispatches() - before == 3
    if scenario == "notifying":
        assert sims["jax"].notify_epoch() == sims["numpy"].notify_epoch()


@requires_jax
def test_jax_faulted_phase_runs_on_device_with_plan():
    """Fault cand_mask phases ride the plan-pinned device path too, and
    stranded flows (all candidates dead) agree with numpy."""
    src, dst, size = _flows(TOPO, seed=11)
    sims, plans = {}, {}
    for be in ("numpy", "jax"):
        sim = DragonflySimulator(TOPO, SimParams(seed=3, backend=be))
        sim.set_faults(FaultSchedule.of(link_down(n_random=6, seed=4)))
        sims[be] = sim
        plans[be] = sim.plan_for(src, dst, size)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_3)
    before = _dispatches()
    for _ in range(2):
        rn = sims["numpy"].run_phase(src, dst, size, pol,
                                     plan=plans["numpy"])
        rj = sims["jax"].run_phase(src, dst, size, pol, plan=plans["jax"])
        _assert_close(rj, rn)
    assert _dispatches() - before == 2


# --------------------------------------------------------------------------
# Device/queue state across reset_queues() and epoch bumps.
# --------------------------------------------------------------------------
@requires_jax
def test_jax_state_survives_reset_and_epoch_bumps():
    """One interleaved life: phases -> reset_queues -> phases -> fault
    epoch bump -> phases.  The jax sim must track the numpy oracle
    through every transition, and the plan cache must hand back a fresh
    plan (fresh device bundle) after the bump."""
    src, dst, size = _flows(TOPO, seed=13)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    sim_n = DragonflySimulator(TOPO, SimParams(seed=9))
    sim_j = DragonflySimulator(TOPO, SimParams(seed=9, backend="jax"))

    plan_j = sim_j.plan_for(src, dst, size)
    plan_n = sim_n.plan_for(src, dst, size)
    for _ in range(2):
        _assert_close(sim_j.run_phase(src, dst, size, pol, plan=plan_j),
                      sim_n.run_phase(src, dst, size, pol, plan=plan_n))
    assert plan_j.device_bundle is not None

    sim_j.reset_queues()
    sim_n.reset_queues()
    assert np.all(sim_j.link_queue_s == 0.0)
    _assert_close(sim_j.run_phase(src, dst, size, pol, plan=plan_j),
                  sim_n.run_phase(src, dst, size, pol, plan=plan_n))

    # epoch bumps on an active-set CHANGE: activate links mid-run
    sim_j.set_faults(FaultSchedule.of(link_down([2, 5], start=4)))
    sim_n.set_faults(FaultSchedule.of(link_down([2, 5], start=4)))
    sim_j.run_phase(src, dst, size, pol)      # phase 3: still healthy
    sim_n.run_phase(src, dst, size, pol)
    assert sim_j.fault_epoch() == sim_n.fault_epoch() > 0
    plan_j2 = sim_j.plan_for(src, dst, size)
    plan_n2 = sim_n.plan_for(src, dst, size)
    assert plan_j2 is not plan_j              # epoch keyed the cache
    assert plan_j2.device_bundle is None      # fresh bundle, pinned lazily
    _assert_close(sim_j.run_phase(src, dst, size, pol, plan=plan_j2),
                  sim_n.run_phase(src, dst, size, pol, plan=plan_n2))
    assert plan_j2.device_bundle is not None


# --------------------------------------------------------------------------
# pallas_kernel knob.
# --------------------------------------------------------------------------
@requires_jax
def test_pallas_kernel_on_agrees_with_off():
    """force-"on" (interpret mode off-TPU) replays the "off" scatter
    path within the pinned tolerance — the kernel parity contract."""
    src, dst, size = _flows(TOPO, seed=17, n=150)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    results = {}
    for knob in ("off", "on"):
        sim = DragonflySimulator(
            TOPO, SimParams(seed=4, backend="jax", pallas_kernel=knob))
        results[knob] = sim.run_phase(src, dst, size, pol)
    _assert_close(results["on"], results["off"], rtol=1e-4)


def test_pallas_kernel_auto_is_off_on_cpu():
    from repro.compat.runtime import on_tpu, resolve_pallas_kernel
    if not on_tpu():
        assert resolve_pallas_kernel("auto") is False
    assert resolve_pallas_kernel("on") is True
    assert resolve_pallas_kernel("off") is False
    with pytest.raises(ValueError):
        resolve_pallas_kernel("sometimes")


def test_pallas_kernel_knob_validated():
    with pytest.raises(ValueError):
        DragonflySimulator(TOPO, SimParams(pallas_kernel="maybe"))


# --------------------------------------------------------------------------
# Batched dispatch: run_phase_batch == per-sim run_phase.
# --------------------------------------------------------------------------
def _batch_calls(backend, n_sims=3, seed0=20):
    calls = []
    for k in range(n_sims):
        sim = DragonflySimulator(TOPO, SimParams(seed=seed0 + k,
                                                 backend=backend))
        src, dst, size = _flows(TOPO, seed=seed0 + k)
        calls.append((sim, dict(src_nodes=src, dst_nodes=dst, bytes_=size,
                                policy=RoutingPolicy(
                                    RoutingMode.ADAPTIVE_0))))
    return calls


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_run_phase_batch_matches_sequential(backend):
    if backend == "jax" and not _jax_ok():
        pytest.skip("jax unavailable")
    batched = [run_phase_batch([(sim, dict(kw))
                                for sim, kw in _batch_calls(backend)])
               for _ in range(1)][0]
    sequential = [sim.run_phase(**kw)
                  for sim, kw in _batch_calls(backend)]
    for rb, rs in zip(batched, sequential):
        assert np.array_equal(rb.t_us, rs.t_us)
        assert np.array_equal(rb.latency_us, rs.latency_us)
        assert np.array_equal(rb.flits, rs.flits)


@requires_jax
def test_run_phase_batch_uses_one_vmapped_dispatch():
    from repro.dragonfly.jax_backend import PIPELINE_CALLS
    before = dict(PIPELINE_CALLS)
    run_phase_batch([(sim, kw) for sim, kw in _batch_calls("jax")])
    assert PIPELINE_CALLS["batched"] == before["batched"] + 1
    assert PIPELINE_CALLS["single"] == before["single"]


# --------------------------------------------------------------------------
# Sweep lockstep: identical records, batched dispatch on jax.
# --------------------------------------------------------------------------
def _sweep(backend, lockstep):
    from repro.tenancy import TenancyMix, Workload, sweep
    mix = TenancyMix("mix2", (
        Workload("vic", "halo3d", 16, {"nx": 32, "vars_": 2},
                 arm=RoutingMode.ADAPTIVE_3),
        Workload("agg", "alltoall", 24, {"size_per_pair": 16384},
                 arm=RoutingMode.ADAPTIVE_0)))
    arms = {"min": RoutingMode.MIN_HASH, "ad3": RoutingMode.ADAPTIVE_3}
    return sweep(TOPO, [mix], arms, params=SimParams(backend=backend),
                 rounds=2, lockstep=lockstep)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_sweep_lockstep_matches_sequential(backend):
    if backend == "jax" and not _jax_ok():
        pytest.skip("jax unavailable")
    seq = _sweep(backend, lockstep=False)
    lck = _sweep(backend, lockstep=True)
    assert len(seq) == len(lck) == 2
    for a, b in zip(seq, lck):
        for key in a:
            if isinstance(a[key], float):
                assert np.isclose(a[key], b[key], rtol=1e-12, atol=0.0)
            else:
                assert a[key] == b[key]


@requires_jax
def test_sweep_lockstep_batches_the_column():
    from repro.dragonfly.jax_backend import PIPELINE_CALLS
    before = PIPELINE_CALLS["batched"]
    _sweep("jax", lockstep=True)
    assert PIPELINE_CALLS["batched"] > before
