"""repro.faults guarantees (docs/faults.md): schedule/epoch semantics,
zero-fault bit-identity across the topology family, dead-link masking
physics, the fault-epoch plan-cache key, the PolicyEngine staleness
guard end-to-end over NIC-counter dropout, serve retry/fallback,
heartbeat-driven detection with elastic shrink, and the tenancy
recovery metrics."""

import hashlib

import numpy as np
import pytest

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, SimParams,
                             registered_topologies, small_topology)
from repro.dragonfly import invariants as inv
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.faults import (FaultSchedule, HeartbeatDriver, counter_dropout,
                          link_degrade, link_down, link_flap,
                          remap_allocation, router_down)
from repro.policy import DecisionBatch, make_engine, scoped_site_filter
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           RestartAction)
from repro.tenancy import InterferenceEngine, TenancyMix, Workload

ALL_NAMES = registered_topologies()
SMALL = {name: small_topology(name) for name in ALL_NAMES}
POLICY = RoutingPolicy(RoutingMode.ADAPTIVE_0)


def _digest(a) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()) \
        .hexdigest()[:16]


def _flows(topo, seed=3, n=64):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, topo.n_nodes, size=n)
    dst = (src + rng.integers(1, topo.n_nodes, size=n)) % topo.n_nodes
    size = rng.pareto(1.2, size=n) * 65536 + 1024
    return src, dst, size


# --------------------------------------------------------------------------
# Spec / schedule semantics.
# --------------------------------------------------------------------------
def test_windows_and_flap_square_wave():
    s = link_down([0], start=2, end=5)
    assert [s.active_at(p) for p in range(7)] == \
        [False, False, True, True, True, False, False]
    f = link_flap([0], start=1, end=9, period=3, duty=1)
    assert [f.active_at(p) for p in range(10)] == \
        [False, True, False, False, True, False, False, True, False, False]


def test_spec_validation():
    with pytest.raises(ValueError):
        link_down([0], start=5, end=3)
    with pytest.raises(ValueError):
        link_degrade(1.5, [0])
    with pytest.raises(ValueError):
        link_flap([0], period=0)


def test_schedule_clear_and_start():
    sched = FaultSchedule.of(link_down([0], start=2, end=5),
                             link_degrade(0.5, [1], start=1, end=7))
    assert sched.first_start() == 1
    assert sched.all_clear_phase() == 7
    assert FaultSchedule.of(link_down([0], start=2)).all_clear_phase() \
        is None
    assert not FaultSchedule()
    assert FaultSchedule().first_start() is None


def test_epochs_count_active_set_changes():
    topo = SMALL["aries"]
    bound = FaultSchedule.of(link_down([0], start=2, end=4),
                             link_down([1], start=3, end=5)).bind(topo)
    # active sets per phase: {}, {}, {0}, {0,1}, {1}, {}, {}
    assert [bound.epoch_at(p) for p in range(7)] == [0, 0, 1, 2, 3, 4, 4]
    assert bound.state_at(0) is None
    assert bound.state_at(2).dead[0] and not bound.state_at(2).dead[1]
    assert bound.state_at(5) is None


def test_explicit_ids_validated_on_bind():
    topo = SMALL["aries"]
    with pytest.raises(ValueError):
        FaultSchedule.of(link_down([topo.n_links])).bind(topo)
    with pytest.raises(ValueError):
        FaultSchedule.of(router_down([topo.n_routers])).bind(topo)


def test_capacity_scale_composition():
    topo = SMALL["aries"]
    bound = FaultSchedule.of(link_degrade(0.5, [3]),
                             link_degrade(0.4, [3, 4]),
                             link_down([5])).bind(topo)
    st = bound.state_at(0)
    assert st.capacity_scale[3] == pytest.approx(0.2)
    assert st.capacity_scale[4] == pytest.approx(0.4)
    assert st.capacity_scale[5] == 0.0 and st.dead[5]
    inv.check_capacity_scale(topo, st)


# --------------------------------------------------------------------------
# Zero-fault bit-identity across the whole topology family: an empty
# schedule, and a schedule whose windows never activate, replay the
# fault-free simulator seed-for-seed (digest pin, docs/faults.md).
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_NAMES)
def test_zero_fault_bit_identity(name):
    topo = SMALL[name]
    src, dst, size = _flows(topo)
    idle = FaultSchedule.of(link_down([0], start=100, end=200),
                            router_down([0], start=100, end=200))
    runs = []
    for faults in (None, FaultSchedule(), idle):
        sim = DragonflySimulator(topo, SimParams(seed=11), faults=faults)
        digests = []
        for _ in range(3):
            res = sim.run_phase(src, dst, size, POLICY)
            digests.append((_digest(res.t_us), _digest(res.latency_us),
                            _digest(res.stalls_per_flit),
                            _digest(sim.link_queue_s)))
            assert res.stranded is None or not res.stranded.any()
        runs.append(digests)
    assert runs[0] == runs[1] == runs[2]
    # the empty schedule is falsy and never even binds
    assert DragonflySimulator(topo, SimParams(seed=11),
                              faults=FaultSchedule()).faults is None


# --------------------------------------------------------------------------
# Masking physics.
# --------------------------------------------------------------------------
def test_all_global_links_down_strands_intergroup_flows():
    topo = SMALL["aries"]
    lo, hi = topo.link_ranges()["global"]
    sched = FaultSchedule.of(link_down(range(lo, hi)))
    sim = DragonflySimulator(topo, SimParams(seed=2, bg_enable=False),
                             faults=sched)
    src, dst, size = _flows(topo, n=96)
    res = sim.run_phase(src, dst, size, POLICY)
    inter = np.asarray(topo.group_of_node(src)) \
        != np.asarray(topo.group_of_node(dst))
    assert res.stranded is not None
    assert np.array_equal(res.stranded, inter)
    assert res.n_stranded == int(inter.sum()) > 0
    # stranded flows pay the reroute-or-drop penalty
    assert (res.t_us[inter] >= sim.params.fault_penalty_us).all()


def test_router_down_strands_its_nodes():
    topo = SMALL["dragonfly"]
    sched = FaultSchedule.of(router_down([0])).bind(topo)
    down = set(int(n) for n in sched.down_nodes_at(0))
    assert down                      # the router hosts p nodes
    sim = DragonflySimulator(topo, SimParams(seed=2, bg_enable=False),
                             faults=sched)
    src, dst, size = _flows(topo, n=96)
    res = sim.run_phase(src, dst, size, POLICY)
    touches = np.asarray([int(s) in down or int(d) in down
                          for s, d in zip(src, dst)])
    assert np.array_equal(res.stranded, touches)


def test_degraded_capacity_slows_the_phase():
    topo = SMALL["aries"]
    src, dst, size = _flows(topo, n=96)
    times = {}
    brownout = FaultSchedule.of(link_degrade(0.05, range(topo.n_links)))
    for label, faults in (("healthy", None), ("brownout", brownout)):
        sim = DragonflySimulator(topo, SimParams(seed=2, bg_enable=False),
                                 faults=faults)
        times[label] = float(sim.run_phase(src, dst, size, POLICY)
                             .t_us.sum())
    assert times["brownout"] > times["healthy"]


def test_dead_links_carry_no_queue():
    topo = SMALL["aries"]
    lo, hi = topo.link_ranges()["global"]
    dead_ids = [lo, lo + 1]
    sim = DragonflySimulator(topo, SimParams(seed=2, bg_enable=False),
                             faults=FaultSchedule.of(link_down(dead_ids)))
    src, dst, size = _flows(topo, n=96)
    for _ in range(3):
        sim.run_phase(src, dst, size, POLICY)
        assert (sim.link_queue_s[dead_ids] == 0.0).all()


# --------------------------------------------------------------------------
# Fault-mask invariants across the family (the ci_lint --topology battery
# is the headless twin of this test).
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_NAMES)
def test_fault_mask_invariants(name):
    topo = SMALL[name]
    bound = FaultSchedule.of(
        link_down(n_random=2, seed=11),
        link_degrade(0.25, n_random=1, seed=12),
        router_down([0])).bind(topo)
    st = bound.state_at(0)
    inv.check_capacity_scale(topo, st)
    src, dst = inv.sample_pairs(topo, n=48, seed=2)
    inv.check_fault_mask(topo, st.dead, src, dst,
                         rng=np.random.default_rng(8))
    inv.check_fault_mask(topo, np.zeros(topo.n_links, dtype=bool),
                         src, dst, rng=np.random.default_rng(8))


# --------------------------------------------------------------------------
# Plan cache: the content key covers the fault epoch, so a plan drawn on
# the healthy machine is not replayed into a changed link set.
# --------------------------------------------------------------------------
def test_plan_cache_recomputes_on_fault_epoch():
    topo = SMALL["aries"]
    sched = FaultSchedule.of(link_down([0], start=1, end=3))
    sim = DragonflySimulator(topo, SimParams(seed=4, bg_enable=False),
                             faults=sched)
    src, dst, size = _flows(topo, n=32)
    p0 = sim.plan_for(src, dst, size)
    assert sim.plan_for(src, dst, size) is p0      # same epoch: cached
    sim.run_phase(src, dst, size, POLICY, plan=p0)  # phase 0 -> 1: epoch 1
    assert sim.fault_epoch() == 1
    p1 = sim.plan_for(src, dst, size)
    assert p1 is not p0                             # fault epoch recomputes
    sim.run_phase(src, dst, size, POLICY, plan=p1)
    sim.run_phase(src, dst, size, POLICY)           # phase 2 -> 3: cleared
    assert sim.plan_for(src, dst, size) is not p1


# --------------------------------------------------------------------------
# Staleness guard end-to-end: counter dropout freezes the NIC counters,
# the engine stops hearing feedback, degrades to the static fallback,
# and recovers the moment counters resume.
# --------------------------------------------------------------------------
def test_staleness_fallback_end_to_end():
    topo = SMALL["aries"]
    alloc = make_allocation(topo, 8, spread="inter_groups", seed=1)
    sched = FaultSchedule.of(counter_dropout(start=2, end=5))
    sim = DragonflySimulator(topo, SimParams(seed=6, bg_enable=False),
                             faults=sched)
    eng = make_engine("app_aware", staleness_limit=2,
                      fallback_mode=RoutingMode.MIN_HASH)
    backend = sim.backend_for(alloc.allocation_id)
    rng = np.random.default_rng(0)
    nodes = np.asarray(alloc.nodes)
    src = nodes[rng.integers(0, len(nodes), size=40)]
    dst = nodes[(np.arange(40) + 1) % len(nodes)]
    size = np.full(40, 1 << 20, dtype=np.float64)
    last_pkts, trace = 0, []
    for phase in range(8):
        was_degraded = eng.degraded     # the state this decide() sees
        modes = eng.decide(DecisionBatch.of(size, site="s"))
        trace.append((phase, was_degraded, set(modes.tolist())))
        res = sim.run_phase(src, dst, size, POLICY, allocation=alloc,
                            modes=modes)
        pkts = backend.read_counters().request_packets
        if pkts > last_pkts:           # counters advanced: telemetry
            last_pkts = pkts
            eng.bus.publish_flow_arrays([float(res.latency_us.mean())],
                                        [float(res.stalls_per_flit.mean())])
    degraded_phases = [p for p, d, _ in trace if d]
    # dropout covers phases [2, 5): feedback stops after the phase-1
    # publish, the guard trips after staleness_limit=2 silent decides,
    # and recovery is immediate once counters resume at phase 5
    assert degraded_phases == [4, 5]
    for p, d, modeset in trace:
        if d:
            assert modeset == {RoutingMode.MIN_HASH}
        else:
            assert RoutingMode.MIN_HASH not in modeset
    assert eng.fallback_decides == 2
    assert not eng.degraded


def test_on_fault_epoch_resets_scoped_sites_only():
    eng = make_engine("app_aware")
    for site in (("A", "a2a"), ("B", "a2a")):
        for _ in range(3):
            eng.decide(DecisionBatch.of(np.full(8, 1 << 20), site=site))
            eng.bus.publish_flow_arrays([5.0] * 8, [0.2] * 8)
    n = eng.on_fault_epoch(scoped_site_filter("A"))
    assert n == 1                       # only A's site reset
    assert eng.on_fault_epoch() >= 1    # None = all sites


def test_eps_greedy_reset_samples_scoped():
    eng = make_engine("eps_greedy")
    for site in (("A", "s"), ("B", "s")):
        eng.decide(DecisionBatch.of(np.full(8, 1 << 20), site=site))
        eng.bus.publish_flow_arrays([5.0] * 8, [0.2] * 8)
    assert eng.on_fault_epoch(scoped_site_filter("A")) == 1
    assert eng.on_fault_epoch(scoped_site_filter("A")) == 0   # already gone


# --------------------------------------------------------------------------
# serve.route_kv_transfer: bounded retry with backoff, DIRECT fallback.
# --------------------------------------------------------------------------
def _serve_engine():
    from repro.collectives.modes import CollectiveMode
    from repro.collectives.selector import ICICostModel, MeshSpec
    eng = make_engine("app_aware",
                      mode_a=CollectiveMode.HIERARCHICAL,
                      mode_b=CollectiveMode.DIRECT,
                      mode_a_alltoall=CollectiveMode.HIERARCHICAL)
    return eng, ICICostModel(MeshSpec(n_pods=2, inner_chips=256))


def test_route_kv_transfer_retries_then_falls_back_to_direct():
    from repro.collectives.modes import CollectiveMode
    from repro.serve.engine import route_kv_transfer
    eng, cost = _serve_engine()
    attempts, sleeps = [], []

    def transfer(mode):
        attempts.append(mode)
        return mode is CollectiveMode.DIRECT   # only DIRECT works

    # big volume => the decided mode is HIERARCHICAL, which fails
    used = route_kv_transfer(eng, cost, 1 << 30,
                             site=("A", "kv_transfer"), transfer=transfer,
                             max_retries=2, backoff_s=0.1,
                             sleep=sleeps.append)
    assert used is CollectiveMode.DIRECT
    assert attempts == [CollectiveMode.HIERARCHICAL] * 3 \
        + [CollectiveMode.DIRECT]
    assert sleeps == [0.1, 0.2]                # exponential backoff


def test_route_kv_transfer_success_needs_no_retry():
    from repro.serve.engine import route_kv_transfer
    eng, cost = _serve_engine()
    attempts, sleeps = [], []
    used = route_kv_transfer(eng, cost, 1 << 30,
                             transfer=lambda m: attempts.append(m) or True,
                             max_retries=2, backoff_s=0.1,
                             sleep=sleeps.append)
    assert len(attempts) == 1 and attempts[0] is used
    assert sleeps == []
    # legacy path: no transfer callable, one decide + one publish
    assert route_kv_transfer(eng, cost, 1 << 10) is not None


def test_route_kv_transfer_raises_when_fallback_fails():
    from repro.serve.engine import route_kv_transfer
    eng, cost = _serve_engine()
    with pytest.raises(RuntimeError, match="fallback"):
        route_kv_transfer(eng, cost, 1 << 30,
                          transfer=lambda m: False, max_retries=1,
                          sleep=lambda s: None)


def test_kv_transfer_failures_stay_allocation_scoped():
    from repro.collectives.modes import CollectiveMode
    from repro.serve.engine import route_kv_transfer
    eng, cost = _serve_engine()
    # tenant B learns normally on its scoped site
    for _ in range(3):
        route_kv_transfer(eng, cost, 1 << 30, site=("B", "kv_transfer"))
    before = eng.decide(DecisionBatch.single(
        1 << 30, site=("B", "kv_transfer")))[0]
    # tenant A's transfers fail over to DIRECT repeatedly
    for _ in range(3):
        route_kv_transfer(eng, cost, 1 << 30, site=("A", "kv_transfer"),
                          transfer=lambda m: m is CollectiveMode.DIRECT,
                          max_retries=1, sleep=lambda s: None)
    after = eng.decide(DecisionBatch.single(
        1 << 30, site=("B", "kv_transfer")))[0]
    assert after == before             # B's automaton is untouched


# --------------------------------------------------------------------------
# Detection front end: suppressed heartbeats -> phi-accrual DEAD ->
# ELASTIC_SHRINK re-materialisation off the down nodes.
# --------------------------------------------------------------------------
def test_heartbeat_driver_detects_and_shrinks_elastically():
    topo = SMALL["dragonfly"]
    bound = FaultSchedule.of(router_down([0], start=3)).bind(topo)
    down = set(int(n) for n in bound.down_nodes_at(3))
    # allocation straddling the doomed router
    alloc = make_allocation(topo, 6, spread="inter_groups", seed=5)
    if not down & set(int(n) for n in alloc.nodes):
        nodes = tuple(sorted(down))[:1] + tuple(alloc.nodes)[:-1]
        alloc = type(alloc)(allocation_id=alloc.allocation_id,
                            nodes=nodes)
    drv = HeartbeatDriver(bound, alloc, FaultToleranceConfig(), seed=9)
    silenced = []
    for phase in range(7):
        silenced.append(drv.tick(phase))
    assert silenced[2] == () and silenced[3] != ()
    rep = drv.poll(6)
    assert rep.action == RestartAction.ELASTIC_SHRINK
    assert set(rep.dead_nodes) == down & set(int(n) for n in alloc.nodes)
    new_nodes = set(int(n) for n in rep.allocation.nodes)
    assert not (new_nodes & down)      # remapped off the dead router
    assert len(rep.allocation.nodes) == len(alloc.nodes)
    assert rep.allocation.allocation_id.endswith("@remap1")
    # healthy machine: nothing detected, nothing remapped
    assert drv.poll(6).action == RestartAction.NONE


def test_remap_allocation_pool_semantics():
    topo = SMALL["aries"]
    alloc = make_allocation(topo, 4, spread="inter_groups", seed=0)
    nodes = list(alloc.nodes)
    used = [n for n in range(topo.n_nodes) if n not in nodes[0:1]]
    # pool dry (every other node used): the dead rank is dropped
    shrunk = remap_allocation(topo, alloc, [nodes[0]], used_nodes=used,
                              seed=1, tag="t")
    assert len(shrunk.nodes) == 3 and nodes[0] not in shrunk.nodes
    # with a pool, rank order of survivors is preserved and the
    # replacement avoids down/used nodes
    remapped = remap_allocation(topo, alloc, [nodes[1]],
                                down_nodes=[nodes[1]], seed=1, tag="t")
    assert len(remapped.nodes) == 4
    assert [n for n in remapped.nodes if n != remapped.nodes[1]] == \
        [n for n in nodes if n != nodes[1]]
    assert remapped.nodes[1] not in nodes
    # no dead ranks: identity
    assert remap_allocation(topo, alloc, []) is alloc


# --------------------------------------------------------------------------
# Tenancy integration: recovery metrics and per-tenant stranding.
# --------------------------------------------------------------------------
def _small_mix():
    return TenancyMix("mix", (
        Workload("vic", "halo3d", 12, {"nx": 32, "vars_": 2},
                 arm="app_aware"),
        Workload("agg", "alltoall", 12, {"size_per_pair": 8192},
                 arm=RoutingMode.ADAPTIVE_0)))


def test_run_mix_with_faults_reports_recovery():
    topo = SMALL["aries"]
    sched = FaultSchedule.of(
        link_down(start=1, end=3, n_random=2, link_kind="global", seed=3))
    eng = InterferenceEngine(topo, SimParams(seed=5, bg_enable=False),
                             seed=5)
    res = eng.run_mix(_small_mix(), rounds=6, faults=sched)
    assert res.faults and res.faults[0]["kind"] == "link_down"
    for rep in res.tenants:
        assert len(rep.round_times_us) == 6
        assert rep.recovery_rounds is not None
        assert rep.recovery_rounds >= 0 or rep.recovery_rounds == -1
        assert rep.stranded_flows >= 0
        assert rep.slowdown is not None and rep.slowdown > 0
    # the same mix without faults reports no recovery fields
    clean = InterferenceEngine(topo, SimParams(seed=5, bg_enable=False),
                               seed=5).run_mix(_small_mix(), rounds=6)
    assert clean.faults is None
    assert clean.victim_report.recovery_rounds is None


def test_recovery_metric_math():
    eng = InterferenceEngine(SMALL["aries"],
                             SimParams(seed=0, bg_enable=False))
    sched = FaultSchedule.of(link_down([0], start=2, end=4))
    # recovers one round after clear: rounds=1, time=the slow round
    assert eng._recovery([10.0, 10.0, 30.0, 30.0, 20.0, 10.0], sched) \
        == (1, 20.0)
    # immediate recovery
    assert eng._recovery([10.0, 10.0, 30.0, 30.0, 10.5, 10.0], sched) \
        == (0, 0.0)
    # never back to baseline inside the run
    assert eng._recovery([10.0, 10.0, 30.0, 30.0, 30.0, 30.0], sched) \
        == (-1, -1.0)
    # faults never clear: no recovery metric
    open_ended = FaultSchedule.of(link_down([0], start=2))
    assert eng._recovery([10.0] * 6, open_ended) == (None, None)
    # clean companion trajectory: phase-periodic times recover even
    # though a flat baseline would say -1
    clean = [10.0, 40.0, 10.0, 40.0, 10.0, 40.0]
    noisy = [10.0, 40.0, 90.0, 90.0, 10.0, 41.0]
    assert eng._recovery(noisy, sched, clean=clean) == (0, 0.0)


def test_run_mix_epoch_resets_engine_sites():
    # a schedule changing mid-run must trigger on_fault_epoch for the
    # engine-armed tenants (contaminated samples are discarded)
    topo = SMALL["aries"]
    sched = FaultSchedule.of(
        link_degrade(0.5, start=2, end=4, n_random=2, link_kind="global",
                     seed=7))
    eng = InterferenceEngine(topo, SimParams(seed=5, bg_enable=False),
                             seed=5)
    res = eng.run_mix(_small_mix(), rounds=5, baselines=False,
                      faults=sched)
    assert res.victim_report.time_us > 0
