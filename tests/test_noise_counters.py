"""§3 methodology: counters, windows, QCD, allocation guards."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.counters import CounterWindow, InMemoryBackend, NICCounters
from repro.core.noise import AllocationMismatch, NoiseEstimator, iqr, qcd


def test_counter_window_deltas_normalized():
    be = InMemoryBackend()
    w = CounterWindow(be)
    w.read()  # prime
    be.counters.observe(flits=1000, stalled_cycles=500, packets=200,
                        latency_us_total=400.0)
    be.advance(2.0)
    d = w.read()
    assert d.flits == 1000
    assert d.stalls_per_flit == pytest.approx(0.5)
    assert d.mean_latency_us == pytest.approx(2.0)
    assert d.flit_rate == pytest.approx(500.0)  # per-second (§3.2 guard)


def test_counter_window_second_read_zero():
    be = InMemoryBackend()
    w = CounterWindow(be)
    w.read()
    be.counters.observe(10, 1, 2, 1.0)
    be.advance(1.0)
    w.read()
    d = w.read()
    assert d.flits == 0 and d.packets == 0


def test_table1_correlation_is_not_causation():
    """An idle app observing for 2x longer sees ~2x the flits; the windowed
    flit RATE stays constant — the §3.2 fix."""
    rates = []
    for idle_s in (1.0, 2.0):
        be = InMemoryBackend()
        w = CounterWindow(be)
        w.read()
        bg_rate = 110e6
        be.counters.observe(int(bg_rate * idle_s), 0, 1, 0.0)
        be.advance(idle_s)
        d = w.read()
        rates.append(d.flit_rate)
    assert rates[0] == pytest.approx(rates[1], rel=1e-6)


def test_qcd_range_and_known_value():
    assert qcd([1, 1, 1, 1]) == 0.0
    data = [1, 2, 3, 4]  # q1=1.75 q3=3.25 -> 1.5/5 = .3
    assert qcd(data) == pytest.approx(0.3)
    assert iqr(data) == pytest.approx(1.5)


@given(st.lists(st.floats(0.1, 1e6), min_size=4, max_size=200))
def test_qcd_bounded_for_positive_data(xs):
    v = qcd(xs)
    assert 0.0 <= v <= 1.0


def test_allocation_mismatch_guard():
    est = NoiseEstimator("allocA")
    est.add(allocation_id="allocA", exec_us=1.0, latency_us=1.0,
            stalls_per_flit=0.0)
    with pytest.raises(AllocationMismatch):
        est.add(allocation_id="allocB", exec_us=1.0, latency_us=1.0,
                stalls_per_flit=0.0)


def test_noise_report_outlier_ratio():
    est = NoiseEstimator("a")
    for v in [1.0] * 99 + [100.0]:
        est.add(allocation_id="a", exec_us=v, latency_us=v,
                stalls_per_flit=0.0)
    rep = est.report()
    assert rep.outlier_ratio == pytest.approx(0.01)
    assert rep.network_noise == rep.qcd_latency
