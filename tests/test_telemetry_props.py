"""TelemetryBus property tests (hypothesis; deterministic stub fallback
via tests/conftest.py when the real package is absent):

  * publish -> decide ordering: feedback is always bound to the most
    recently decided batch — policies stay strictly one message behind
    (§4.3), publishes before any decide are dropped, never queued;
  * counter-kind normalization is idempotent and total over the alias
    table, and unknown kinds fail loudly;
  * allocation-scoped isolation: the notification counter kind never
    leaks one tenant's congestion events into another tenant's NIC
    (§3.2), for any seed/tenant split.
"""

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TenantSegments, TopologyParams)
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.policy import COUNTER_KINDS, PolicyEngine, TelemetryBus, \
    normalize_kind
from repro.policy.telemetry import _KIND_ALIASES
from repro.policy.types import DecisionBatch

TOPO = DragonflyTopology(TopologyParams(n_groups=4, chassis_per_group=2,
                                        blades_per_chassis=4))

#: every accepted spelling: canonical kinds, aliases, and case/space noise
_ACCEPTED = sorted(
    {v for k in (*COUNTER_KINDS, *_KIND_ALIASES)
     for v in (k, k.upper(), k.capitalize(), f"  {k}", f"{k} ", f" {k} ")})


# --------------------------------------------------------------------------
# normalize_kind: idempotent, total over the alias table, loud otherwise.
# --------------------------------------------------------------------------
@given(st.sampled_from(_ACCEPTED))
def test_normalize_kind_idempotent(kind):
    out = normalize_kind(kind)
    assert out in COUNTER_KINDS
    assert normalize_kind(out) == out            # fixed point


@given(st.sampled_from(["bogus", "", "nicx", "notifyy", "sim2", "N/A"]))
def test_normalize_kind_unknown_raises(kind):
    with pytest.raises(ValueError):
        normalize_kind(kind)


@given(st.sampled_from(sorted(_KIND_ALIASES)))
def test_publish_canonicalizes_source(alias):
    bus = TelemetryBus()
    seen = []
    bus.subscribe(lambda fb: seen.append(fb.source))
    bus.publish_flow_arrays([5.0], [0.0], source=alias)
    assert seen == [_KIND_ALIASES[alias]]
    assert bus.history[-1].source == _KIND_ALIASES[alias]


# --------------------------------------------------------------------------
# publish -> decide ordering.
# --------------------------------------------------------------------------
class _Recorder:
    """Minimal Policy that logs which batch every update was bound to."""

    def __init__(self):
        self.decided = []
        self.updates = []                        # (batch, latency[0])

    def decide(self, batch):
        self.decided.append(batch)
        return np.full(len(batch), RoutingMode.ADAPTIVE_0, dtype=object)

    def update(self, batch, feedback):
        self.updates.append((batch, float(feedback.latency_cycles[0])))


@given(st.lists(st.floats(min_value=1.0, max_value=1e4),
                min_size=1, max_size=8),
       st.booleans())
def test_feedback_binds_to_last_decided_batch(latencies, orphan_first):
    pol = _Recorder()
    eng = PolicyEngine(pol)
    if orphan_first:                             # publish before any decide
        eng.bus.publish_flow_arrays([9.0] * 3, [0.0] * 3)
        assert pol.updates == []                 # dropped, never queued
    for i, lat in enumerate(latencies):
        batch = DecisionBatch.of(np.full(3, 1024.0), site=f"s{i}")
        eng.decide(batch)
        eng.bus.publish_flow_arrays([lat] * 3, [0.0] * 3)
        bound, _ = pol.updates[-1]
        assert bound is batch                    # one message behind, never 2
    assert len(pol.updates) == len(latencies)
    assert [b for b, _ in pol.updates] == pol.decided


@given(st.integers(min_value=2, max_value=6))
def test_unconsumed_publishes_all_hit_same_batch(n_publishes):
    """Repeated windows between decides all update the SAME last batch —
    the bus never invents batches and never reorders."""
    pol = _Recorder()
    eng = PolicyEngine(pol)
    batch = DecisionBatch.of(np.full(2, 1024.0), site="s")
    eng.decide(batch)
    for k in range(n_publishes):
        eng.bus.publish_flow_arrays([float(k + 1)] * 2, [0.0] * 2)
    assert [b for b, _ in pol.updates] == [batch] * n_publishes
    assert [v for _, v in pol.updates] == \
        [pytest.approx(1e3 * (k + 1)) for k in range(n_publishes)]


# --------------------------------------------------------------------------
# Allocation-scoped notification isolation (§3.2).
# --------------------------------------------------------------------------
@given(st.integers(min_value=0, max_value=4),
       st.integers(min_value=8, max_value=40))
def test_notification_counters_never_cross_tenants(seed, n_a):
    """Under forced-on flags, each tenant's congestion_notifications is
    exactly its OWN exposed-flow count — the split never leaks."""
    n_b = 48 - n_a
    al_a = make_allocation(TOPO, 8, spread="contiguous", seed=1,
                           allocation_id="a")
    al_b = make_allocation(TOPO, 8, spread="contiguous", seed=6,
                           allocation_id="b")
    seg = TenantSegments.of([al_a, al_b], [n_a, n_b])
    sim = DragonflySimulator(TOPO, SimParams(
        seed=seed, bg_enable=False, phantom_sigma=0.0,
        phantom_ghost_s=0.0, notify_threshold_s=1e-3))
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    rng = np.random.default_rng(seed)
    src = rng.integers(0, TOPO.n_nodes, size=48)
    dst = (src + rng.integers(1, TOPO.n_nodes, size=48)) % TOPO.n_nodes
    size = np.full(48, 4096.0)
    res = None
    for _ in range(3):                           # raise, age, expose
        sim.link_queue_s[:] = 2e-3
        sim.est_memory_s[:] = 2e-3
        res = sim.run_phase(src, dst, size, pol, tenants=seg)
    exposed = res.notified > 0.0
    want_a = int(exposed[res.tenant_of == 0].sum())
    want_b = int(exposed[res.tenant_of == 1].sum())
    assert want_a + want_b > 0                   # the channel really fired
    # counters accumulate over all 3 phases; only the last phase had
    # visible flags, so the totals equal that phase's exposure exactly
    assert sim.counters["a"].congestion_notifications == want_a
    assert sim.counters["b"].congestion_notifications == want_b
