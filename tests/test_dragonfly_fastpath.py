"""PR-3 fast-path guarantees: golden traces vs the pre-refactor oracle,
PhasePlan reuse, the jax backend tolerance matrix, the background-flow
disjointness regression, and the notification-channel OFF-switch
differential (threshold=inf replays the channel-free simulator
bit-for-bit across the whole topology family)."""

import hashlib
import warnings

import numpy as np
import pytest

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TenantSegments, TopologyParams)
from repro.dragonfly.reference import reference_run_phase
from repro.dragonfly.routing import RoutingPolicy, spray_weights
from repro.dragonfly.topology import (make_allocation,
                                      registered_topologies,
                                      small_topology)
from repro.faults import FaultSchedule, link_down, router_down

TOPO = DragonflyTopology(TopologyParams(n_groups=4, chassis_per_group=2,
                                        blades_per_chassis=4))
N = 600


def _flows(seed=42, n=N):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, TOPO.params.n_nodes, size=n)
    dst = (src + rng.integers(1, TOPO.params.n_nodes, size=n)) \
        % TOPO.params.n_nodes
    size = rng.pareto(1.2, size=n) * 65536 + 1024
    return src, dst, size


def _assert_flowresult_equal(a, b, rtol=0.0):
    if rtol == 0.0:
        assert np.array_equal(a.t_us, b.t_us)
        assert np.array_equal(a.latency_us, b.latency_us)
        assert np.array_equal(a.stalls_per_flit, b.stalls_per_flit)
        assert a.nonmin_fraction == b.nonmin_fraction
    else:
        np.testing.assert_allclose(a.t_us, b.t_us, rtol=rtol)
        np.testing.assert_allclose(a.latency_us, b.latency_us, rtol=rtol)
        np.testing.assert_allclose(a.stalls_per_flit, b.stalls_per_flit,
                                   rtol=rtol, atol=1e-6)
        assert a.nonmin_fraction == pytest.approx(b.nonmin_fraction,
                                                  rel=max(rtol, 1e-6),
                                                  abs=1e-6)
    assert np.array_equal(a.flits, b.flits)
    assert np.array_equal(a.packets, b.packets)


# --------------------------------------------------------------------------
# Golden traces: the numpy fast path replays the pre-refactor simulator
# seed-for-seed, BIT-identical — including congested phases, where the
# hoisted score base re-gathers the hot rows with the combined estimate.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mode", list(RoutingMode))
def test_numpy_fast_path_bit_identical_to_reference(mode):
    src, dst, size = _flows()
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=3)
    sp = SimParams(seed=0)
    ref_sim = DragonflySimulator(TOPO, sp)
    fast_sim = DragonflySimulator(TOPO, sp)
    pol = RoutingPolicy(mode)
    for _ in range(3):
        ra = reference_run_phase(ref_sim, src, dst, size, pol, al)
        rb = fast_sim.run_phase(src, dst, size, pol, al)
        _assert_flowresult_equal(ra, rb)
        assert np.array_equal(ref_sim.link_queue_s, fast_sim.link_queue_s)
        assert np.array_equal(ref_sim.est_memory_s, fast_sim.est_memory_s)
    assert ref_sim.clock_s == fast_sim.clock_s
    ca = ref_sim.counters[al.allocation_id]
    cb = fast_sim.counters[al.allocation_id]
    assert ca.request_flits == cb.request_flits
    assert ca.request_packets_cumulative_latency_us \
        == cb.request_packets_cumulative_latency_us


@pytest.mark.parametrize("kw", [
    dict(route_feedback_iters=1),
    dict(bg_enable=False),
    dict(bg_bytes_scale=5e8, bg_flows_per_phase=32),   # congested links
    dict(min_phase_window_s=5e-6),
    dict(max_flows=200),                               # subsample path
])
def test_numpy_fast_path_bit_identical_configs(kw):
    src, dst, size = _flows(seed=7)
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=1)
    sp = SimParams(seed=11, **kw)
    ref_sim = DragonflySimulator(TOPO, sp)
    fast_sim = DragonflySimulator(TOPO, sp)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_3)
    for _ in range(3):
        ra = reference_run_phase(ref_sim, src, dst, size, pol, al)
        rb = fast_sim.run_phase(src, dst, size, pol, al)
        _assert_flowresult_equal(ra, rb)
        assert np.array_equal(ref_sim.link_queue_s, fast_sim.link_queue_s)


def test_numpy_fast_path_bit_identical_mixed_modes():
    """Per-flow modes (the PolicyEngine path) through the int mode-code
    bias table match the reference's per-unique-mode masked passes."""
    src, dst, size = _flows(seed=5)
    pool = [RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_1,
            RoutingMode.ADAPTIVE_3, RoutingMode.MIN_HASH,
            RoutingMode.NMIN_HASH]
    modes = np.empty(N, dtype=object)
    modes[:] = [pool[i % len(pool)] for i in range(N)]
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=2)
    sp = SimParams(seed=4)
    ref_sim = DragonflySimulator(TOPO, sp)
    fast_sim = DragonflySimulator(TOPO, sp)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    ra = reference_run_phase(ref_sim, src, dst, size, pol, al, modes=modes)
    rb = fast_sim.run_phase(src, dst, size, pol, al, modes=modes)
    _assert_flowresult_equal(ra, rb)
    assert np.array_equal(ref_sim.link_queue_s, fast_sim.link_queue_s)


def test_empty_app_phase_bit_identical():
    """Background-only phases (table1's idle probe) stay equivalent."""
    sp = SimParams(seed=9)
    ref_sim = DragonflySimulator(TOPO, sp)
    fast_sim = DragonflySimulator(TOPO, sp)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    e = np.zeros(0, dtype=np.int64)
    for _ in range(2):
        reference_run_phase(ref_sim, e, e, np.zeros(0), pol)
        fast_sim.run_phase(e, e, np.zeros(0), pol)
    assert np.array_equal(ref_sim.link_queue_s, fast_sim.link_queue_s)
    assert ref_sim.total_flits_all_jobs == fast_sim.total_flits_all_jobs


# --------------------------------------------------------------------------
# PhasePlan reuse.
# --------------------------------------------------------------------------
def test_phase_plan_reuse_deterministic_and_cached():
    src, dst, size = _flows(seed=1)
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=1)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    runs = []
    for _ in range(2):
        sim = DragonflySimulator(TOPO, SimParams(seed=3))
        plan = sim.plan_for(src, dst, size)
        assert sim.plan_for(src, dst, size) is plan   # content-addressed
        rs = [sim.run_phase(None, None, None, pol, al, plan=plan)
              for _ in range(3)]
        runs.append(rs)
    for ra, rb in zip(*runs):                         # seeded-deterministic
        _assert_flowresult_equal(ra, rb)


def test_phase_plan_matches_planless_statistics():
    """A plan-reused run is a different RNG trajectory but the same
    physics: per-flow times stay within a loose statistical band."""
    src, dst, size = _flows(seed=8)
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=4)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    sim_a = DragonflySimulator(TOPO, SimParams(seed=5))
    sim_b = DragonflySimulator(TOPO, SimParams(seed=5))
    ra = sim_a.run_phase(src, dst, size, pol, al)
    rb = sim_b.run_phase(None, None, None, pol, al,
                         plan=sim_b.plan_for(src, dst, size))
    assert rb.t_us.shape == ra.t_us.shape
    assert np.median(rb.t_us) == pytest.approx(np.median(ra.t_us), rel=0.2)


def test_phase_plan_subsample_keeps_modes_aligned():
    src, dst, size = _flows(seed=2, n=500)
    sim = DragonflySimulator(TOPO, SimParams(seed=1, max_flows=200))
    plan = sim.make_plan(src, dst, size)
    assert plan.n_flows == 200 and plan.n_flows_in == 500
    modes = np.empty(500, dtype=object)
    modes[:] = [RoutingMode.ADAPTIVE_0] * 500
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    res = sim.run_phase(None, None, None, pol, modes=modes, plan=plan)
    assert res.t_us.shape == (200,)
    with pytest.raises(ValueError):
        sim.run_phase(None, None, None, pol, modes=modes[:10], plan=plan)


# --------------------------------------------------------------------------
# Satellite regression: background flows never touch the allocation.
# --------------------------------------------------------------------------
def test_bg_flows_disjoint_from_allocation():
    """Pre-fix, 3 resample retries could silently leave other-job flows
    on the allocation's nodes.  Cover a brutal case: the allocation owns
    almost the whole machine, so nearly every draw collides."""
    tp = TOPO.params
    keep_out = 5
    nodes = tuple(range(tp.n_nodes - keep_out))       # own all but 5 nodes
    al = make_allocation(TOPO, 4, spread="inter_nodes", seed=0)
    al = type(al)(allocation_id="huge", nodes=nodes)
    sim = DragonflySimulator(TOPO, SimParams(seed=0, bg_flows_per_phase=64))
    for _ in range(20):
        bg = sim._bg_flows(al)
        assert bg is not None
        src, dst, _ = bg
        assert not np.isin(src, nodes).any()
        assert not np.isin(dst, nodes).any()
        assert (src != dst).all()


def test_bg_flows_unchanged_when_disjoint():
    """When no draw collides, the fixed resampler consumes the RNG
    stream exactly like the seed implementation (golden determinism)."""
    sim_a = DragonflySimulator(TOPO, SimParams(seed=6))
    sim_b = DragonflySimulator(TOPO, SimParams(seed=6))
    bg_a = sim_a._bg_flows(None)
    bg_b = sim_b._bg_flows(None)
    for x, y in zip(bg_a, bg_b):
        assert np.array_equal(x, y)


# --------------------------------------------------------------------------
# jax backend: tolerance matrix + clean fallback.
# --------------------------------------------------------------------------
JAX_RTOL = 2e-2   # float32 pipeline vs float64 numpy (docs/performance.md)


def _jax_ok():
    from repro.compat.runtime import resolve_backend
    return resolve_backend("jax") == "jax"


@pytest.mark.skipif(not _jax_ok(), reason="jax unavailable")
@pytest.mark.parametrize("mode", list(RoutingMode))
def test_jax_backend_matches_numpy_within_tolerance(mode):
    src, dst, size = _flows(seed=3, n=250)
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=1)
    sim_n = DragonflySimulator(TOPO, SimParams(seed=2))
    sim_j = DragonflySimulator(TOPO, SimParams(seed=2, backend="jax"))
    pol = RoutingPolicy(mode)
    rn = sim_n.run_phase(src, dst, size, pol, al)
    rj = sim_j.run_phase(src, dst, size, pol, al)
    np.testing.assert_allclose(rj.t_us, rn.t_us, rtol=JAX_RTOL)
    np.testing.assert_allclose(rj.latency_us, rn.latency_us, rtol=JAX_RTOL)
    np.testing.assert_allclose(rj.stalls_per_flit, rn.stalls_per_flit,
                               rtol=JAX_RTOL, atol=1e-4)
    assert rj.nonmin_fraction == pytest.approx(rn.nonmin_fraction,
                                               rel=JAX_RTOL, abs=1e-4)


@pytest.mark.skipif(not _jax_ok(), reason="jax unavailable")
def test_jax_backend_matches_numpy_mixed_modes():
    src, dst, size = _flows(seed=3, n=250)
    pool = [RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_2,
            RoutingMode.ADAPTIVE_3, RoutingMode.IN_ORDER]
    modes = np.empty(250, dtype=object)
    modes[:] = [pool[i % len(pool)] for i in range(250)]
    sim_n = DragonflySimulator(TOPO, SimParams(seed=2))
    sim_j = DragonflySimulator(TOPO, SimParams(seed=2, backend="jax"))
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    rn = sim_n.run_phase(src, dst, size, pol, modes=modes)
    rj = sim_j.run_phase(src, dst, size, pol, modes=modes)
    np.testing.assert_allclose(rj.t_us, rn.t_us, rtol=JAX_RTOL)


def test_jax_backend_falls_back_cleanly(monkeypatch):
    """With jax reported unusable, backend='jax' degrades to numpy and
    reproduces its bit-exact results after a single warning."""
    import repro.compat.runtime as rt

    monkeypatch.setattr(rt, "_JAX_OK", False)
    monkeypatch.setattr(rt, "_WARNED_FALLBACK", False)
    src, dst, size = _flows(seed=1, n=100)
    sim_j = DragonflySimulator(TOPO, SimParams(seed=1, backend="jax"))
    sim_n = DragonflySimulator(TOPO, SimParams(seed=1))
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rj = sim_j.run_phase(src, dst, size, pol)
        sim_j.run_phase(src, dst, size, pol)
    assert any("falling back" in str(w.message) for w in caught)
    rn = sim_n.run_phase(src, dst, size, pol)
    _assert_flowresult_equal(rj, rn)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        DragonflySimulator(TOPO, SimParams(backend="cuda"))


# --------------------------------------------------------------------------
# Notification-channel OFF switch: notify_threshold_s=inf (the default)
# must be indistinguishable from a simulator without the channel — same
# RNG stream, same float ops, bit-identical results — no matter how the
# other notify knobs are set, on every registered topology, with mixed
# per-flow modes, tenants, and an active fault schedule.
# --------------------------------------------------------------------------
#: aggressively non-default channel knobs that must all be inert at inf
_NOTIFY_OFF = dict(notify_threshold_s=float("inf"), notify_clear_frac=0.9,
                   notify_delay_phases=0, notify_penalty_s=1.0)


def _digest(a) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()) \
        .hexdigest()[:16]


def _trace(sim, src, dst, size, pol, alloc=None, tenants=None,
           modes=None, phases=3):
    out = []
    for _ in range(phases):
        res = sim.run_phase(src, dst, size, pol, alloc, tenants=tenants,
                            modes=modes)
        assert res.notified is None          # disabled = no signal at all
        out.append((_digest(res.t_us), _digest(res.latency_us),
                    _digest(res.stalls_per_flit),
                    _digest(sim.link_queue_s),
                    _digest(sim.est_memory_s)))
    return out


def _family_flows(topo, seed=3, n=64):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, topo.n_nodes, size=n)
    dst = (src + rng.integers(1, topo.n_nodes, size=n)) % topo.n_nodes
    size = rng.pareto(1.2, size=n) * 65536 + 1024
    return src, dst, size


@pytest.mark.parametrize("name", registered_topologies())
@pytest.mark.parametrize("mode", [RoutingMode.ADAPTIVE_0,
                                  RoutingMode.ADAPTIVE_3])
def test_notify_off_bit_identical_topology_family(name, mode):
    topo = small_topology(name)
    src, dst, size = _family_flows(topo)
    pol = RoutingPolicy(mode)
    base = DragonflySimulator(topo, SimParams(seed=13))
    off = DragonflySimulator(topo, SimParams(seed=13, **_NOTIFY_OFF))
    assert not off.params.notify_enabled
    assert _trace(base, src, dst, size, pol) \
        == _trace(off, src, dst, size, pol)
    assert base.clock_s == off.clock_s
    assert off.notify_epoch() == 0


def test_notify_off_bit_identical_mixed_modes_and_allocation():
    src, dst, size = _flows(seed=17)
    pool = [RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_1,
            RoutingMode.ADAPTIVE_3, RoutingMode.MIN_HASH]
    modes = np.empty(N, dtype=object)
    modes[:] = [pool[i % len(pool)] for i in range(N)]
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=5)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    base = DragonflySimulator(TOPO, SimParams(seed=19))
    off = DragonflySimulator(TOPO, SimParams(seed=19, **_NOTIFY_OFF))
    assert _trace(base, src, dst, size, pol, alloc=al, modes=modes) \
        == _trace(off, src, dst, size, pol, alloc=al, modes=modes)
    ca, cb = base.counters[al.allocation_id], off.counters[al.allocation_id]
    assert ca.request_flits == cb.request_flits
    assert ca.congestion_notifications == cb.congestion_notifications == 0


def test_notify_off_bit_identical_tenants():
    src, dst, size = _flows(seed=23, n=200)
    al1 = make_allocation(TOPO, 8, spread="contiguous", seed=2,
                          allocation_id="a")
    al2 = make_allocation(TOPO, 8, spread="contiguous", seed=9,
                          allocation_id="b")
    seg = TenantSegments.of([al1, al2], [100, 100])
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    base = DragonflySimulator(TOPO, SimParams(seed=29))
    off = DragonflySimulator(TOPO, SimParams(seed=29, **_NOTIFY_OFF))
    assert _trace(base, src, dst, size, pol, tenants=seg) \
        == _trace(off, src, dst, size, pol, tenants=seg)
    for aid in ("a", "b"):
        assert base.counters[aid].request_packets \
            == off.counters[aid].request_packets
        assert off.counters[aid].congestion_notifications == 0


@pytest.mark.parametrize("name", registered_topologies())
def test_notify_off_bit_identical_under_faults(name):
    topo = small_topology(name)
    src, dst, size = _family_flows(topo, seed=7)
    sched = FaultSchedule.of(
        link_down(start=1, end=3, n_random=2, link_kind="global", seed=4),
        router_down(start=2, end=3, n_random=1, seed=6))
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    base = DragonflySimulator(topo, SimParams(seed=31, bg_enable=False),
                              faults=sched)
    off = DragonflySimulator(
        topo, SimParams(seed=31, bg_enable=False, **_NOTIFY_OFF),
        faults=sched)
    assert _trace(base, src, dst, size, pol, phases=4) \
        == _trace(off, src, dst, size, pol, phases=4)
    assert base.fault_epoch() == off.fault_epoch()
    assert off.notify_epoch() == 0


# --------------------------------------------------------------------------
# spray_weights micro-contract (satellite): rng=None path.
# --------------------------------------------------------------------------
def test_spray_weights_noiseless_path():
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    scores = np.array([[1e-5, 2e-5, np.inf, np.nan],
                       [np.inf, np.inf, np.inf, np.inf]])
    w = spray_weights(scores, pol)
    assert np.isfinite(w).all()
    np.testing.assert_allclose(w.sum(1), [1.0, 0.0], atol=1e-12)
    assert w[0, 2] == w[0, 3] == 0.0      # inf/nan candidates get nothing
    # the input is never mutated (the old copy() is gone)
    assert np.isnan(scores[0, 3])
