"""MoE paths (einsum vs EP) and gradient-communication utilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.collectives.moe_ep import moe_ep, moe_ep_ref
from repro.collectives.modes import CollectiveMode
from repro.collectives.selector import AppAwareSelector, ICICostModel, MeshSpec
from repro.models.common import Family, ModelConfig
from repro.models.moe import init_moe, moe_einsum
from repro.train.grad_comm import (GradCommConfig, bucketize,
                                   compress_decompress, select_bucket_modes)


def moe_cfg(**kw):
    base = dict(name="t", family=Family.MOE, n_layers=1, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, d_ff_expert=64,
                vocab=128, n_experts=8, top_k=2, remat=False)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_einsum_finite_and_aux():
    cfg = moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 32)),
                    jnp.float32)
    y, aux = moe_einsum(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 1.0 - 1e-3   # load-balance loss >= 1 at optimum


def test_moe_ep_matches_ref_on_trivial_mesh():
    cfg = moe_cfg(moe_impl="ep")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 32)),
                    jnp.float32)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        y_ep, aux_ep = jax.jit(lambda p, x: moe_ep(p, x, cfg))(p, x)
    y_ref, aux_ref = moe_ep_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)


def test_moe_ep_grads_finite():
    cfg = moe_cfg(moe_impl="ep")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 8, 32)),
                    jnp.float32)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        g = jax.jit(jax.grad(lambda p, x: moe_ep(p, x, cfg)[0].sum()))(p, x)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


# --------------------------------------------------------------- grad_comm
def test_bucketize_respects_size():
    grads = {f"w{i}": jnp.zeros((1024,)) for i in range(10)}  # 4 KiB each
    buckets = bucketize(grads, bucket_bytes=8 * 1024)
    assert all(len(b) <= 2 for b in buckets)
    assert sorted(i for b in buckets for i in b) == list(range(10))


def test_error_feedback_is_lossless_in_aggregate():
    """EF invariant: wire + residual == accumulated true gradient."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
    res = jnp.zeros(512)
    total_wire = jnp.zeros(512)
    for _ in range(20):
        wire, res = compress_decompress(g, res)
        total_wire = total_wire + wire
    np.testing.assert_allclose(np.asarray(total_wire + res),
                               np.asarray(g * 20), rtol=1e-3, atol=1e-5)


def test_select_bucket_modes_uses_algorithm1():
    sel = AppAwareSelector(ICICostModel(MeshSpec(n_pods=2, inner_chips=256)))
    grads = {"big": jnp.zeros((64 << 20) // 4), "small": jnp.zeros(128)}
    modes = select_bucket_modes(sel, grads, GradCommConfig())
    assert len(modes) >= 1
    assert all(m in (CollectiveMode.DIRECT, CollectiveMode.HIERARCHICAL)
               for _, m in modes)
