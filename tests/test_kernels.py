"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm_op
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan_op
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


def _mk(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("B,H,Hkv,S,hd,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),      # MHA
    (2, 4, 2, 256, 64, 64, 128),     # GQA
    (1, 8, 1, 128, 32, 32, 64),      # MQA (paligemma-style)
    (2, 2, 2, 192, 16, 64, 64),      # non-pow2 seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, Hkv, S, hd, bq, bk, causal):
    q = _mk((B, H, S, hd), jnp.float32)
    k = _mk((B, Hkv, S, hd), jnp.float32)
    v = _mk((B, Hkv, S, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    q = _mk((1, 2, 128, 64), jnp.bfloat16)
    k = _mk((1, 2, 128, 64), jnp.bfloat16)
    v = _mk((1, 2, 128, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 8, 8, 8),
    (2, 64, 3, 8, 16, 16),
    (1, 128, 4, 16, 32, 32),
])
def test_ssd_kernel_matches_model_oracle(B, S, H, P, N, chunk):
    x = _mk((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, (B, S, H)), jnp.float32)
    a_log = jnp.asarray(RNG.uniform(-1, 1, (H,)), jnp.float32)
    bm = _mk((B, S, H, N), jnp.float32)
    cm = _mk((B, S, H, N), jnp.float32)
    y_ref, f_ref = ssd_chunked(x, dt, a_log, bm, cm, chunk)
    y_k, f_k = ssd_scan_op(x, dt, a_log, bm, cm, chunk, force_kernel=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_kernel_state_passing():
    B, S, H, P, N, chunk = 1, 64, 2, 8, 8, 16
    x = _mk((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, (B, S, H)), jnp.float32)
    a_log = jnp.asarray(RNG.uniform(-1, 1, (H,)), jnp.float32)
    bm = _mk((B, S, H, N), jnp.float32)
    cm = _mk((B, S, H, N), jnp.float32)
    y_full, _ = ssd_scan_op(x, dt, a_log, bm, cm, chunk, force_kernel=True)
    y1, s1 = ssd_scan_op(x[:, :32], dt[:, :32], a_log, bm[:, :32],
                         cm[:, :32], chunk, force_kernel=True)
    y2, _ = ssd_scan_op(x[:, 32:], dt[:, 32:], a_log, bm[:, 32:],
                        cm[:, 32:], chunk, init_state=s1, force_kernel=True)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (2, 7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_matches_ref(shape, dtype):
    x = _mk(shape, dtype)
    g = _mk((shape[-1],), jnp.float32)
    out = rmsnorm_op(x, g, force_kernel=True)
    ref = rmsnorm_ref(x, g)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# segment_sum: the Dragonfly fast path's link-load scatter-add.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,segs,bp,bs", [
    (1000, 300, 256, 128),       # multi-block both axes
    (257, 64, 256, 64),          # ragged pair tail
    (64, 1000, 64, 256),         # more segments than pairs
    (5, 3, 1024, 512),           # tiny, single block
])
def test_segment_sum_kernel_matches_ref(n, segs, bp, bs):
    from repro.kernels.segment_sum import segment_sum_ref
    from repro.kernels.segment_sum.segment_sum import segment_sum_pallas

    ids = jnp.asarray(RNG.integers(0, segs, size=n), jnp.int32)
    vals = jnp.asarray(RNG.random(n), jnp.float32)
    out = segment_sum_pallas(vals, ids, segs, block_pairs=bp,
                             block_segs=bs, interpret=True)
    ref = segment_sum_ref(vals, ids, segs)
    assert out.shape == (segs,)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_empty_and_untouched_segments():
    from repro.kernels.segment_sum import segment_sum_op
    from repro.kernels.segment_sum.segment_sum import segment_sum_pallas

    ids = jnp.asarray([2, 2, 5], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 4.0], jnp.float32)
    out = segment_sum_pallas(vals, ids, 8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), [0, 0, 3.0, 0, 0, 4.0, 0, 0], atol=1e-7)
    # dispatcher default (CPU): jnp reference, same contract
    out2 = segment_sum_op(vals, ids, 8)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                               atol=1e-7)
