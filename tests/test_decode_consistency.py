"""Decode correctness: step-by-step decode must reproduce the teacher-
forced training logits (same prefix => same next-token distribution).

This is the guard for serving-path optimizations — e.g. the whisper
cross-KV hoist (§Perf cell 3) would diverge here if it were wrong."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (decode_step, init_params, make_decode_state,
                          prefill, train_forward)
from repro.models.common import Family, ModelConfig

CASES = {
    "dense": dict(family=Family.DENSE, n_layers=3, d_model=48, n_heads=4,
                  n_kv_heads=2, d_ff=96, vocab=128),
    "encdec": dict(family=Family.ENCDEC, n_layers=2, n_encoder_layers=2,
                   d_model=48, n_heads=4, n_kv_heads=4, d_ff=96, vocab=128,
                   encoder_frames=8, act="gelu", glu=False),
    "ssm": dict(family=Family.SSM, n_layers=3, d_model=48, n_heads=0,
                n_kv_heads=0, d_ff=0, vocab=128, ssm_state=8,
                ssm_head_dim=16, ssm_chunk=4, supports_long_context=True),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_decode_matches_teacher_forcing(name):
    cfg = ModelConfig(name=name, remat=False, **CASES[name])
    params = init_params(cfg, 0)
    B, S = 2, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == Family.ENCDEC:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_frames, cfg.d_model))
            * 0.02, jnp.float32)
    full_logits, _ = train_forward(params, batch, cfg)

    # prefill on the first half, decode the second half token-by-token
    half = S // 2
    state = make_decode_state(cfg, B, max_len=S + 2)
    pre_batch = dict(batch, tokens=toks[:, :half])
    lg, state = prefill(params, pre_batch, cfg, state)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full_logits[:, half - 1], np.float32),
        rtol=4e-2, atol=4e-2)
    for t in range(half, S - 1):
        lg, state = decode_step(params, toks[:, t:t + 1], cfg, state)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=4e-2, atol=4e-2,
            err_msg=f"{name}: decode diverges at position {t}")
