"""Golden seed-regression pins for the benchmark surface.

Each test runs a fig7/fig8/fig10 benchmark at small scale with a fixed
seed and compares a sha256 digest of the full (canonicalized) result
structure against a pinned value.  Any change to the simulator's RNG
stream, float pipeline, routing scores, or the benchmarks' own
protocol shows up as a digest flip — the point: refactors must either
be bit-identical or consciously re-pin (and say why in the PR).

Marked ``slow``: excluded from the tier-1 `pytest -x -q` pass (pyproject
addopts) and run by `make bench-smoke` instead — see docs/testing.md.
"""

import hashlib
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import fig7_routing_pingpong as fig7  # noqa: E402
from benchmarks import fig8_microbench as fig8        # noqa: E402
from benchmarks import fig10_applications as fig10    # noqa: E402
from repro.dragonfly import make_topology             # noqa: E402

pytestmark = pytest.mark.slow

#: the small machine every golden pin runs on (1/3 the paper's groups)
SMALL = "aries:n_groups=4,chassis_per_group=2,blades_per_chassis=4"


def _canon(obj):
    """Canonical, json-able mirror of a benchmark result structure."""
    if isinstance(obj, dict):
        return {str(k): _canon(obj[k])
                for k in sorted(obj, key=str)}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [_canon(v) for v in obj.tolist()]
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.integer,)):
        return int(obj)
    return obj


def _digest(obj) -> str:
    blob = json.dumps(_canon(obj), sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def test_fig7_pingpong_golden():
    res = fig7.run(iters=2, seeds=1, topology=SMALL)
    assert _digest(res) == "54f968d4db46a28d"


def test_fig8_microbench_golden(monkeypatch):
    # two representative sweep rows keep the pin fast; the full sweep
    # shares the exact same code path
    monkeypatch.setattr(fig8, "SWEEP", {
        "alltoall": [dict(size_per_pair=1024)],
        "halo3d": [dict(nx=256)],
    })
    res = fig8.run(machine="cori", iters=2, seed=0, full_scale=False,
                   policy="app_aware", topology=SMALL)
    assert _digest(res) == "698e18f146f8dd7b"


def test_fig10_application_golden():
    topo = make_topology(SMALL)
    res = fig10.run_app(topo, "bfs", "alltoall",
                        dict(size_per_pair=2048), 64, 0.5, iters=2,
                        seed=0, policy="app_aware")
    assert _digest(res) == "8a9ac248b52532ba"


def test_golden_digests_are_reproducible():
    """The pin mechanism itself: two identical runs digest identically
    (catches any un-seeded randomness creeping into the protocol)."""
    topo = make_topology(SMALL)
    a = fig10.run_app(topo, "bfs", "alltoall", dict(size_per_pair=2048),
                      64, 0.5, iters=1, seed=3)
    topo = make_topology(SMALL)
    b = fig10.run_app(topo, "bfs", "alltoall", dict(size_per_pair=2048),
                      64, 0.5, iters=1, seed=3)
    assert _digest(a) == _digest(b)
