"""Congestion-notification channel: flag lifecycle (raise / delay /
hysteresis / clear), reset and fault-epoch hygiene, plan-cache keying,
counter crediting, and the NotificationPolicy regime automaton."""

import numpy as np
import pytest

from repro.core.counters import CounterDelta
from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TopologyParams)
from repro.dragonfly.routing import RoutingPolicy, apply_notifications
from repro.dragonfly.topology import make_allocation
from repro.faults import FaultSchedule, link_down
from repro.policy import (DecisionBatch, Feedback, NotificationConfig,
                          NotificationPolicy, POLICY_NAMES, make_engine)

TOPO = DragonflyTopology(TopologyParams(n_groups=4, chassis_per_group=2,
                                        blades_per_chassis=4))
POL = RoutingPolicy(RoutingMode.ADAPTIVE_0)

#: noise-free estimates: est_queue_s == the value we write into
#: link_queue_s / est_memory_s, so threshold crossings are exact
QUIET = dict(bg_enable=False, phantom_sigma=0.0, phantom_ghost_s=0.0)
THR = 1e-3


def _sim(**kw):
    p = dict(seed=0, notify_threshold_s=THR, **QUIET)
    p.update(kw)
    return DragonflySimulator(TOPO, SimParams(**p))


def _set_est(sim, value):
    """Pin the next phase's noise-free estimate to `value` exactly."""
    sim.link_queue_s[:] = value
    sim.est_memory_s[:] = value


def _phase(sim, n=8, seed=3, alloc=None):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, TOPO.n_nodes, size=n)
    dst = (src + rng.integers(1, TOPO.n_nodes, size=n)) % TOPO.n_nodes
    return sim.run_phase(src, dst, np.full(n, 4096.0), POL, alloc)


# --------------------------------------------------------------------------
# Channel lifecycle.
# --------------------------------------------------------------------------
def test_disabled_by_default():
    sim = DragonflySimulator(TOPO, SimParams(seed=0, bg_enable=False))
    assert not sim.params.notify_enabled
    res = _phase(sim, n=8)
    assert res.notified is None                  # no signal, not "calm"
    assert (sim.link_notify_age == -1).all()
    assert sim.notify_epoch() == 0


def test_raise_propagation_delay_then_visible():
    sim = _sim(notify_delay_phases=1)
    _set_est(sim, 2 * THR)
    r1 = _phase(sim, n=8)
    # raised at END of phase 1 (age 0) -> not yet visible during it
    assert r1.notified is not None and not r1.notified.any()
    assert (sim.link_notify_age == 0).all()
    assert not sim.notified_links.any()
    _set_est(sim, 2 * THR)
    r2 = _phase(sim, n=8)                        # age 0 < delay: still dark
    assert not r2.notified.any()
    assert sim.notified_links.all()              # aged past the delay now
    _set_est(sim, 2 * THR)
    r3 = _phase(sim, n=8)                        # flags visible this phase
    assert (r3.notified > 0.0).any()
    assert r3.notified.max() <= 1.0 + 1e-12


def test_two_level_hysteresis():
    sim = _sim()
    for _ in range(2):                           # raise + age to visible
        _set_est(sim, 2 * THR)
        _phase(sim)
    assert sim.notified_links.all()
    # mid band [clear_frac*thr, thr): below raise, above clear -> held
    _set_est(sim, 0.7 * THR)
    _phase(sim)
    assert sim.notified_links.all()
    # below the low-water mark -> cleared in one phase
    _set_est(sim, 0.4 * THR)
    _phase(sim)
    assert (sim.link_notify_age == -1).all()
    assert not sim.notified_links.any()


def test_notify_epoch_tracks_visible_set_changes():
    sim = _sim()
    e0 = sim.notify_epoch()
    _set_est(sim, 2 * THR)
    _phase(sim)                                  # raised, not visible yet
    assert sim.notify_epoch() == e0
    _set_est(sim, 2 * THR)
    _phase(sim)                                  # became visible
    e1 = sim.notify_epoch()
    assert e1 > e0
    _set_est(sim, 2 * THR)
    _phase(sim)                                  # same visible set: stable
    assert sim.notify_epoch() == e1
    _set_est(sim, 0.0)
    _phase(sim)                                  # set cleared: bumps again
    assert sim.notify_epoch() > e1


def test_plan_cache_keyed_on_notify_epoch():
    sim = _sim()
    rng = np.random.default_rng(1)
    src = rng.integers(0, TOPO.n_nodes, size=32)
    dst = (src + 1) % TOPO.n_nodes
    size = np.full(32, 2048.0)
    plan = sim.plan_for(src, dst, size)
    assert sim.plan_for(src, dst, size) is plan
    for _ in range(2):                           # flip the visible set
        _set_est(sim, 2 * THR)
        _phase(sim)
    assert sim.plan_for(src, dst, size) is not plan


# --------------------------------------------------------------------------
# Hygiene: reset_queues, fault epochs, dead links.
# --------------------------------------------------------------------------
def test_reset_queues_clears_notification_state():
    """Regression mirror of the PR-4 est_memory_s leak: a tenant swap
    must not inherit the previous tenant's congestion flags — even the
    legacy partial reset clears them (flags ARE queue state)."""
    for kw in (dict(include_estimates=False), dict()):
        sim = _sim()
        for _ in range(2):
            _set_est(sim, 2 * THR)
            _phase(sim)
        assert sim.notified_links.any()
        e = sim.notify_epoch()
        sim.reset_queues(**kw)
        assert (sim.link_notify_age == -1).all()
        assert sim.notify_epoch() > e            # consumers must replan


def test_dead_links_never_notify():
    lo, hi = TOPO.link_ranges()["global"]
    dead = [lo, lo + 1]
    sched = FaultSchedule.of(link_down(dead))
    sim = DragonflySimulator(
        TOPO, SimParams(seed=0, notify_threshold_s=THR, **QUIET),
        faults=sched)
    for _ in range(3):
        _set_est(sim, 2 * THR)
        _phase(sim)
    assert (sim.link_notify_age[dead] == -1).all()
    assert not sim.notified_links[dead].any()
    alive = np.ones(TOPO.n_links, dtype=bool)
    alive[dead] = False
    assert sim.notified_links[alive].all()


def test_fault_epoch_transition_clears_flags():
    """Flags raised on the pre-fault link set are stale the moment the
    machine changes: the transition wipes the channel."""
    sched = FaultSchedule.of(link_down(n_random=2, link_kind="global",
                                       start=2, seed=5))
    sim = DragonflySimulator(
        TOPO, SimParams(seed=0, notify_threshold_s=THR, **QUIET),
        faults=sched)
    for _ in range(2):                           # phases 0-1: healthy, raise
        _set_est(sim, 2 * THR)
        _phase(sim)
    assert sim.notified_links.any()
    e = sim.notify_epoch()
    _set_est(sim, 0.0)
    _phase(sim)                                  # phase 2: epoch flips
    # wiped at the transition; est stayed low so nothing re-raised
    assert (sim.link_notify_age == -1).all()
    assert sim.notify_epoch() > e


# --------------------------------------------------------------------------
# Counters: allocation-scoped crediting (§3.2).
# --------------------------------------------------------------------------
def test_notification_counter_credits_exposed_flows():
    sim = _sim()
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=3)
    for _ in range(2):
        _set_est(sim, 2 * THR)
        _phase(sim, n=16, alloc=al)
    _set_est(sim, 2 * THR)
    res = _phase(sim, n=16, alloc=al)            # visible flags this phase
    exposed = int((res.notified > 0.0).sum())
    assert exposed > 0
    nic = sim.counters[al.allocation_id]
    assert nic.congestion_notifications == exposed
    delta = CounterDelta(flits=nic.request_flits, stalled_cycles=0,
                         packets=nic.request_packets, latency_us_total=0.0,
                         window_s=1.0,
                         notifications=nic.congestion_notifications)
    assert 0.0 < delta.notified_fraction <= 1.0


def test_disabled_channel_counts_nothing():
    sim = DragonflySimulator(TOPO, SimParams(seed=0, bg_enable=False))
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=3)
    _phase(sim, n=16, alloc=al)
    assert sim.counters[al.allocation_id].congestion_notifications == 0


# --------------------------------------------------------------------------
# Routing helper.
# --------------------------------------------------------------------------
def test_apply_notifications_pure_and_additive():
    est = np.array([1e-6, 2e-6, 3e-6])
    vis = np.array([True, False, True])
    out = apply_notifications(est, vis, 300e-6)
    assert out is not est                        # caller's array untouched
    np.testing.assert_allclose(out, [301e-6, 2e-6, 303e-6])
    np.testing.assert_allclose(est, [1e-6, 2e-6, 3e-6])


# --------------------------------------------------------------------------
# NotificationPolicy regime automaton.
# --------------------------------------------------------------------------
def _fb(exposure, n=4):
    return Feedback.of(np.full(n, 100.0), np.zeros(n),
                       notified=np.full(n, float(exposure)))


def test_policy_calm_until_notified_then_congested():
    pol = NotificationPolicy()
    cfg = pol.config
    b = DecisionBatch.of(np.full(4, 65536.0), site="s")
    assert (pol.decide(b) == cfg.mode_calm).all()
    pol.update(b, _fb(1.0))                      # EMA jumps to 1.0
    assert (pol.decide(b) == cfg.mode_congested).all()
    st = pol.site_state("s")
    assert st.congested and st.n == 1


def test_policy_hysteresis_and_dwell():
    pol = NotificationPolicy(NotificationConfig(min_dwell=2))
    cfg = pol.config
    b = DecisionBatch.of(np.full(4, 65536.0), site="s")
    pol.decide(b)
    pol.update(b, _fb(1.0))
    assert pol.site_state("s").congested
    # exposure collapses to 0: EMA halves each update, but the regime
    # holds until BOTH the low-water mark and the dwell are satisfied
    flips = []
    for _ in range(12):
        pol.update(b, _fb(0.0))
        flips.append(pol.site_state("s").congested)
    assert flips[0] and not flips[-1]            # held, then released
    assert (pol.decide(b) == cfg.mode_calm).all()


def test_policy_none_signal_is_noop():
    pol = NotificationPolicy()
    b = DecisionBatch.of(np.full(4, 65536.0), site="s")
    pol.decide(b)
    fb = Feedback.of(np.full(4, 100.0), np.ones(4))   # notified=None
    pol.update(b, fb)
    assert pol.site_state("s") is None or not pol.site_state("s").congested
    assert (pol.decide(b) == pol.config.mode_calm).all()


def test_policy_sites_independent_and_resettable():
    pol = NotificationPolicy()
    ba = DecisionBatch.of(np.full(4, 65536.0), site="a")
    bb = DecisionBatch.of(np.full(4, 65536.0), site="b")
    pol.decide(ba)
    pol.update(ba, _fb(1.0))
    pol.decide(bb)
    pol.update(bb, _fb(0.0))
    assert pol.site_state("a").congested
    assert not pol.site_state("b").congested
    assert pol.reset_samples(lambda s: s == "a") == 1
    assert pol.site_state("a") is None           # back to calm regime
    assert pol.site_state("b") is not None


def test_engine_registration_and_factory():
    assert "notification" in POLICY_NAMES
    eng = make_engine("notification")
    assert isinstance(eng.policy, NotificationPolicy)
    b = DecisionBatch.of(np.full(4, 65536.0), site="s")
    assert (eng.decide(b) == eng.policy.config.mode_calm).all()
    # the bus pipes notified exposure straight into the automaton
    eng.bus.publish_flow_arrays(np.full(4, 5.0), np.zeros(4),
                                notified=np.ones(4))
    assert (eng.decide(b) == eng.policy.config.mode_congested).all()


def test_engine_broadcast_preserves_notified():
    """One aggregate (counter-window) sample fans out over the batch
    without losing the notification signal."""
    eng = make_engine("notification")
    b = DecisionBatch.of(np.full(8, 65536.0), site="s")
    eng.decide(b)
    eng.bus.publish_flow_arrays([5.0], [0.0], notified=[1.0])
    assert eng.policy.site_state("s").congested
