"""repro.tenancy guarantees: K=1 bit-identity with the single-app path,
per-tenant observables summing to the global link loads, background-flow
disjointness from the tenant union, the reset_queues contract, scoped
policy sites, and the interference-engine determinism properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TenantSegments, TopologyParams)
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import PATTERN_KIND, PATTERNS, moe_alltoall
from repro.policy import (DecisionBatch, KIND_ALLTOALL, make_engine,
                          scoped_site_filter)
from repro.tenancy import InterferenceEngine, TenancyMix, Workload, sweep

TOPO = DragonflyTopology(TopologyParams(n_groups=4, chassis_per_group=2,
                                        blades_per_chassis=4))


def _flows(alloc, seed=42, n=400):
    rng = np.random.default_rng(seed)
    nodes = np.asarray(alloc.nodes)
    src = nodes[rng.integers(0, len(nodes), size=n)]
    dst = nodes[rng.integers(0, len(nodes), size=n)]
    size = rng.pareto(1.2, size=n) * 65536 + 1024
    return src, dst, size


def _mix2(seed=0):
    return TenancyMix("mix2", (
        Workload("vic", "halo3d", 16, {"nx": 32, "vars_": 2},
                 arm=RoutingMode.ADAPTIVE_3),
        Workload("agg", "alltoall", 24, {"size_per_pair": 16384},
                 arm=RoutingMode.ADAPTIVE_0)))


# --------------------------------------------------------------------------
# K=1 bit-identity: a single-tenant TenantSegments replays the allocation=
# path seed-for-seed — same FlowResult, same queue state, same rng stream,
# same NIC counters.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [RoutingMode.ADAPTIVE_0,
                                  RoutingMode.ADAPTIVE_3])
def test_k1_tenants_bit_identical_to_allocation(mode):
    al = make_allocation(TOPO, 12, spread="inter_groups", seed=3)
    src, dst, size = _flows(al)
    pol = RoutingPolicy(mode)
    sp = SimParams(seed=0)
    sim_a = DragonflySimulator(TOPO, sp)
    sim_t = DragonflySimulator(TOPO, sp)
    seg = TenantSegments.of([al], [len(size)])
    for _ in range(3):               # carry queue state across phases too
        ra = sim_a.run_phase(src, dst, size, pol, allocation=al)
        rt = sim_t.run_phase(src, dst, size, pol, tenants=seg)
        assert np.array_equal(ra.t_us, rt.t_us)
        assert np.array_equal(ra.latency_us, rt.latency_us)
        assert np.array_equal(ra.stalls_per_flit, rt.stalls_per_flit)
        assert ra.nonmin_fraction == rt.nonmin_fraction
    assert np.array_equal(sim_a.link_queue_s, sim_t.link_queue_s)
    assert np.array_equal(sim_a.est_memory_s, sim_t.est_memory_s)
    assert (sim_a.rng.bit_generator.state
            == sim_t.rng.bit_generator.state)
    ca = sim_a.counters[al.allocation_id]
    ct = sim_t.counters[al.allocation_id]
    assert ca.request_flits == ct.request_flits
    assert ca.request_packets == ct.request_packets
    assert (ca.request_packets_cumulative_latency_us
            == ct.request_packets_cumulative_latency_us)
    # the K=1 result additionally carries the tenant breakdown
    assert rt.tenant_of is not None and ra.tenant_of is None
    assert np.array_equal(rt.tenant_slice(0), np.arange(len(rt.t_us)))


def test_k1_run_mix_slowdown_is_exactly_one():
    """Run-alone baseline == the K=1 mix itself (same seed, fresh sims)."""
    mix = TenancyMix("solo", (_mix2().workloads[0],))
    eng = InterferenceEngine(TOPO, SimParams(seed=5), seed=5)
    res = eng.run_mix(mix, rounds=3)
    assert res.victim_slowdown == 1.0


def test_run_phase_rejects_allocation_plus_tenants():
    al = make_allocation(TOPO, 8, spread="inter_groups", seed=1)
    src, dst, size = _flows(al, n=16)
    seg = TenantSegments.of([al], [16])
    sim = DragonflySimulator(TOPO, SimParams(seed=0))
    with pytest.raises(ValueError):
        sim.run_phase(src, dst, size, RoutingPolicy(RoutingMode.ADAPTIVE_0),
                      allocation=al, tenants=seg)


# --------------------------------------------------------------------------
# Per-tenant observables: the K+1 link-load rows sum to the global backlog,
# and the per-tenant NIC counters partition the app totals.
# --------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 3))
def test_tenant_link_loads_sum_to_global(seed, k):
    rng = np.random.default_rng(seed)
    allocs, used = [], set()
    for i in range(k):
        pool = np.asarray(sorted(set(range(TOPO.params.n_nodes)) - used))
        nodes = rng.choice(pool, size=8, replace=False)
        used.update(int(x) for x in nodes)
        from repro.dragonfly.topology import Allocation
        allocs.append(Allocation(f"t{i}", tuple(int(x) for x in nodes)))
    counts = [int(rng.integers(10, 80)) for _ in range(k)]
    srcs, dsts, sizes = zip(*[_flows(a, seed=seed + i, n=c)
                              for i, (a, c) in enumerate(zip(allocs,
                                                             counts))])
    seg = TenantSegments.of(allocs, counts)
    sim = DragonflySimulator(TOPO, SimParams(seed=seed % 1000))
    res = sim.run_phase(np.concatenate(srcs), np.concatenate(dsts),
                        np.concatenate(sizes),
                        RoutingPolicy(RoutingMode.ADAPTIVE_0), tenants=seg)
    assert res.tenant_link_loads.shape == (k + 1, TOPO.n_links)
    np.testing.assert_allclose(res.tenant_link_loads.sum(axis=0),
                               res.link_load_q, rtol=1e-9, atol=1e-6)
    # NIC counters partition the app totals across tenants exactly
    flits = sum(sim.counters[a.allocation_id].request_flits
                for a in allocs)
    packets = sum(sim.counters[a.allocation_id].request_packets
                  for a in allocs)
    assert flits == int(res.flits.sum())
    assert packets == int(res.packets.sum())
    # per-tenant nonmin fractions are fractions
    assert res.tenant_nonmin_fraction.shape == (k,)
    assert (res.tenant_nonmin_fraction >= 0).all()
    assert (res.tenant_nonmin_fraction <= 1 + 1e-12).all()


def test_tenant_of_survives_statistical_subsampling():
    al1 = make_allocation(TOPO, 8, spread="inter_groups", seed=1,
                          allocation_id="a")
    al2 = make_allocation(TOPO, 8, spread="contiguous", seed=9,
                          allocation_id="b")
    n1, n2 = 300, 200
    s1, d1, b1 = _flows(al1, seed=1, n=n1)
    s2, d2, b2 = _flows(al2, seed=2, n=n2)
    seg = TenantSegments.of([al1, al2], [n1, n2])
    sim = DragonflySimulator(TOPO, SimParams(seed=0, max_flows=128))
    res = sim.run_phase(np.concatenate([s1, s2]), np.concatenate([d1, d2]),
                        np.concatenate([b1, b2]),
                        RoutingPolicy(RoutingMode.ADAPTIVE_0), tenants=seg)
    assert res.tenant_of.shape == (128,)       # remapped, not truncated
    assert set(np.unique(res.tenant_of)) <= {0, 1}
    np.testing.assert_allclose(res.tenant_link_loads.sum(axis=0),
                               res.link_load_q, rtol=1e-9, atol=1e-6)


def test_bg_flows_avoid_tenant_union():
    al1 = make_allocation(TOPO, 10, spread="contiguous", seed=2,
                          allocation_id="a")
    al2 = make_allocation(TOPO, 10, spread="contiguous", seed=7,
                          allocation_id="b")
    seg = TenantSegments.of([al1, al2], [1, 1])
    union = set(seg.union_allocation.nodes)
    assert union == set(al1.nodes) | set(al2.nodes)
    sim = DragonflySimulator(TOPO, SimParams(seed=0))
    for _ in range(20):
        bg = sim._bg_flows(seg.union_allocation)
        assert not (set(bg[0].tolist()) & union)
        assert not (set(bg[1].tolist()) & union)


# --------------------------------------------------------------------------
# reset_queues contract (shared-vs-isolated)
# --------------------------------------------------------------------------
def test_reset_queues_clears_estimates_too():
    sim = DragonflySimulator(TOPO, SimParams(seed=0))
    # occupancy left behind by a previous tenant's phases
    sim.link_queue_s[:] = 1e-3
    sim.est_memory_s[:] = 2e-3
    sim.reset_queues(include_estimates=False)   # legacy partial reset
    assert not sim.link_queue_s.any()
    assert sim.est_memory_s.any()               # stale memory leaks through
    sim.reset_queues()                          # full isolation reset
    assert not sim.link_queue_s.any()
    assert not sim.est_memory_s.any()


# --------------------------------------------------------------------------
# policy layer: tuple-valued (tenant, site) keys and per-tenant slicing
# --------------------------------------------------------------------------
def test_decision_batch_groups_tuple_sites():
    b = DecisionBatch.of(np.ones(8), site=("tenantA", "alltoall"),
                         kind=KIND_ALLTOALL)
    groups = list(b.groups())
    assert len(groups) == 1
    site, kind, rows = groups[0]
    assert site == ("tenantA", "alltoall") and kind == KIND_ALLTOALL
    assert rows.shape == (8,)


def test_shared_engine_scoped_site_slicing():
    eng = make_engine("app_aware", granularity="phase")
    for tenant, nbytes in (("a", 1024.0), ("b", 4 << 20)):
        batch = DecisionBatch.of(np.full(16, nbytes),
                                 site=(tenant, "phase0"))
        eng.decide(batch)
        eng.bus.publish_flow_arrays(np.full(16, 5.0), np.zeros(16))
    pol = eng.policy
    keys = pol.site_keys()
    assert ("a", "phase0") in keys and ("b", "phase0") in keys
    # tenant a's tiny messages are gated to the small-message mode;
    # tenant b's 4MiB ones start on mode A — the scoped filters see the
    # two tenants' DIFFERENT ledgers inside the one shared table
    fa = pol.traffic_fraction(RoutingMode.ADAPTIVE_3,
                              site_filter=scoped_site_filter("a"))
    fb = pol.traffic_fraction(RoutingMode.ADAPTIVE_0,
                              site_filter=scoped_site_filter("b"))
    assert fa == 1.0 and fb == 1.0
    # the unfiltered view merges both (byte-weighted, dominated by b)
    merged = pol.traffic_fraction(RoutingMode.ADAPTIVE_0)
    assert 0.99 < merged < 1.0


def test_serve_scoped_kv_site_and_shared_engine():
    from repro.serve.engine import route_kv_transfer

    class _FakePerf:
        latency_cycles = 1000.0
        stall_cycles_per_flit = 0.1

    class _FakeCost:
        def predict(self, nbytes, mode):
            return _FakePerf()

    eng = make_engine("app_aware", mode_a="DIRECT", mode_b="HIER",
                      granularity="message")
    for alloc_id in ("job0", "job1"):
        mode = route_kv_transfer(eng, _FakeCost(), 1 << 20,
                                 site=(alloc_id, "kv_transfer"))
        assert mode == "DIRECT"
    keys = eng.policy.site_keys()
    assert ("job0", "kv_transfer") in keys
    assert ("job1", "kv_transfer") in keys


# --------------------------------------------------------------------------
# interference engine + sweep
# --------------------------------------------------------------------------
def test_interference_mix_reports_and_determinism():
    eng = InterferenceEngine(TOPO, SimParams(seed=11), seed=11)
    res1 = eng.run_mix(_mix2(), rounds=2)
    res2 = InterferenceEngine(TOPO, SimParams(seed=11),
                              seed=11).run_mix(_mix2(), rounds=2)
    assert [t.time_us for t in res1.tenants] \
        == [t.time_us for t in res2.tenants]
    assert res1.victim_report.name == "vic"
    assert all(t.slowdown is not None and t.slowdown > 0
               for t in res1.tenants)
    assert all(t.nic.request_flits > 0 for t in res1.tenants)
    assert res1.tenant_link_loads.shape == (3, TOPO.n_links)


def test_materialize_disjoint_and_deterministic():
    mix = _mix2()
    a1 = mix.materialize(TOPO, seed=4)
    a2 = mix.materialize(TOPO, seed=4)
    assert [a.nodes for a in a1] == [a.nodes for a in a2]
    assert not (set(a1[0].nodes) & set(a1[1].nodes))
    assert len(a1[0].nodes) == 16 and len(a1[1].nodes) == 24


def test_sweep_grid_records():
    arms = {"adaptive": RoutingMode.ADAPTIVE_0, "app_aware": "app_aware"}
    recs = sweep(TOPO, [_mix2()], arms,
                 params=SimParams(seed=2, bg_enable=False), rounds=2,
                 seed=2)
    assert len(recs) == 2
    assert {r["policy"] for r in recs} == set(arms)
    for r in recs:
        assert r["victim"] == "vic"
        assert r["victim_slowdown"] > 0
        assert set(r["aggressor_slowdowns"]) == {"agg"}


def test_engine_arm_tenant_uses_policy_engine():
    mix = TenancyMix("aa-mix", (
        Workload("vic", "alltoall", 12, {"size_per_pair": 8192},
                 arm="app_aware"),
        Workload("agg", "alltoall", 12, {"size_per_pair": 32768},
                 arm=RoutingMode.ADAPTIVE_0)))
    eng = InterferenceEngine(TOPO, SimParams(seed=6), seed=6)
    res = eng.run_mix(mix, rounds=2, baselines=False)
    assert res.victim_report.arm == "app_aware"
    assert res.victim_report.time_us > 0


# --------------------------------------------------------------------------
# moe_alltoall traffic pattern
# --------------------------------------------------------------------------
def test_moe_alltoall_pattern():
    phases = moe_alltoall(8, tokens_per_rank=128, token_bytes=64)
    assert len(phases) == 2                     # dispatch + combine
    (s1, d1, b1), (s2, d2, b2) = phases
    assert len(b1) == 8 * 7 and len(b2) == 8 * 7
    assert b1.max() > b1.min()                  # zipf skew
    # combine is the transpose of dispatch: same pair sizes, reversed
    m1 = {(int(a), int(b)): v for a, b, v in zip(s1, d1, b1)}
    m2 = {(int(a), int(b)): v for a, b, v in zip(s2, d2, b2)}
    assert m2 == {(b, a): v for (a, b), v in m1.items()}
    assert "moe_alltoall" in PATTERNS
    assert PATTERN_KIND["moe_alltoall"] == KIND_ALLTOALL
