"""RoutingMode bias/scoring edge-case matrix (ISSUE satellite).

Covers every RoutingMode member — including the ±inf deterministic
modes — through bias_s / score_candidates / spray_weights, plus the
degenerate inputs (all-inf score rows, zero-packet messages) that the
seed only exercised implicitly.
"""

import numpy as np
import pytest

from repro.core.strategies import ADAPTIVE_MODES, RoutingMode
from repro.dragonfly.routing import (RoutingPolicy, mode_bias_s,
                                     score_candidates, spray_weights)
from repro.dragonfly.topology import PAD

ALL_MODES = list(RoutingMode)
NONMIN = np.array([False, False, True, True])


def _links(n=3, ncand=4, hops=5):
    rng = np.random.default_rng(0)
    links = rng.integers(0, 50, size=(n, ncand, hops))
    links[:, :, 3:] = PAD  # ragged path lengths
    return links


EXPECTED_BIAS = {
    RoutingMode.ADAPTIVE_0: 0.0,
    RoutingMode.ADAPTIVE_1: 6.0 * 0.5,   # path-average of the ramp
    RoutingMode.ADAPTIVE_2: 2.0,
    RoutingMode.ADAPTIVE_3: 8.0,
    RoutingMode.MIN_HASH: np.inf,
    RoutingMode.NMIN_HASH: -np.inf,
    RoutingMode.IN_ORDER: np.inf,
}


@pytest.mark.parametrize("mode", ALL_MODES)
def test_bias_matrix_every_mode(mode):
    unit = 20e-6
    b = mode_bias_s(mode, unit)
    want = EXPECTED_BIAS[mode]
    if np.isinf(want):
        # deterministic modes: raw ±inf sentinel, never scaled by the unit
        assert b == want
    else:
        assert b == pytest.approx(want * unit)
    assert RoutingPolicy(mode, bias_unit_s=unit).bias_s == b


@pytest.mark.parametrize("mode", ALL_MODES)
def test_score_candidates_every_mode(mode):
    links = _links()
    est = np.random.default_rng(1).uniform(0, 1e-4, size=60)
    pol = RoutingPolicy(mode)
    sc = score_candidates(links, est, NONMIN, pol)
    assert sc.shape == (3, 4)
    assert not np.isnan(sc).any()
    b = pol.bias_s
    if np.isposinf(b):       # deterministic minimal: nonmin unusable
        assert np.isinf(sc[:, 2:]).all() and np.isfinite(sc[:, :2]).all()
    elif np.isneginf(b):     # deterministic non-minimal: min unusable
        assert np.isinf(sc[:, :2]).all() and np.isfinite(sc[:, 2:]).all()
    else:
        assert np.isfinite(sc).all()


@pytest.mark.parametrize("mode", ALL_MODES)
def test_batched_modes_match_scalar_policy_path(mode):
    """score_candidates(modes=[m]*n) == score_candidates(policy(m)) for
    every mode — the engine's per-flow path is score-identical to the
    legacy one-policy-per-phase path."""
    links = _links()
    est = np.random.default_rng(2).uniform(0, 1e-4, size=60)
    pol = RoutingPolicy(mode)
    scalar = score_candidates(links, est, NONMIN, pol)
    modes = np.full(3, mode, dtype=object)
    batched = score_candidates(links, est, NONMIN,
                               RoutingPolicy(RoutingMode.ADAPTIVE_0),
                               modes=modes)
    assert np.array_equal(scalar, batched)


def test_mixed_mode_batch_weight_placement():
    """MIN_HASH rows put zero weight on non-minimal candidates and
    NMIN_HASH rows zero on minimal, inside ONE batched call."""
    links = _links(n=4)
    est = np.zeros(60)
    modes = np.empty(4, dtype=object)
    modes[:] = [RoutingMode.MIN_HASH, RoutingMode.NMIN_HASH,
                RoutingMode.ADAPTIVE_0, RoutingMode.IN_ORDER]
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    sc = score_candidates(links, est, NONMIN, pol, modes=modes)
    w = spray_weights(sc, pol)
    assert w[0, 2:].sum() == 0.0 and w[0, :2].sum() == pytest.approx(1.0)
    assert w[1, :2].sum() == 0.0 and w[1, 2:].sum() == pytest.approx(1.0)
    assert w[2].sum() == pytest.approx(1.0)
    assert w[3, 2:].sum() == 0.0


def test_spray_weights_all_inf_row_is_graceful():
    """A row with no usable candidate (all scores inf) must not produce
    NaNs — it degrades to zero weight everywhere (no bytes routed)."""
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    sc = np.array([[np.inf, np.inf, np.inf],
                   [1e-6, 2e-6, np.inf]])
    w = spray_weights(sc, pol)
    assert not np.isnan(w).any()
    assert w[0].sum() == 0.0
    assert w[1].sum() == pytest.approx(1.0)
    # with per-packet jitter too
    w = spray_weights(sc, pol, np.random.default_rng(0),
                      packets=np.array([4.0, 4.0]))
    assert not np.isnan(w).any()
    assert w[0].sum() == 0.0


def test_spray_weights_zero_packet_messages():
    """packets=0 rows (empty messages) must not divide by zero."""
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    sc = np.full((2, 3), 1e-6)
    w = spray_weights(sc, pol, np.random.default_rng(0),
                      packets=np.zeros(2))
    assert not np.isnan(w).any()
    np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-9)


@pytest.mark.parametrize("mode", ADAPTIVE_MODES)
def test_adaptive_bias_ordering(mode):
    """Higher-bias adaptive modes concentrate strictly more weight on
    minimal candidates under identical congestion."""
    links = _links(n=1)
    est = np.full(60, 1e-5)
    w0 = spray_weights(score_candidates(
        links, est, NONMIN, RoutingPolicy(RoutingMode.ADAPTIVE_0)),
        RoutingPolicy(RoutingMode.ADAPTIVE_0))
    wm = spray_weights(score_candidates(
        links, est, NONMIN, RoutingPolicy(mode)), RoutingPolicy(mode))
    assert wm[0, :2].sum() >= w0[0, :2].sum() - 1e-12
