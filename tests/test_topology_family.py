"""Topology-family harness (ISSUE 7): cross-topology structural
invariants (property-tested), the Aries seed-regression pins, and the
fast-path/oracle differential across families.

Three layers:
  * every topology in the registry satisfies the structural contract
    (repro.dragonfly.invariants) for arbitrary candidate-draw seeds;
  * the canonical Aries machine is frozen — link layout, capacities,
    candidate paths, allocations and a seed-for-seed run_phase trace are
    pinned by digest, so a family refactor cannot silently move it;
  * run_phase stays equivalent to the pre-refactor oracle and
    self-consistent (plans, subsampling) on the NON-Aries families too.
"""

import hashlib

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TopologyParams, make_topology,
                             registered_topologies, small_topology)
from repro.dragonfly import invariants as inv
from repro.dragonfly.reference import reference_run_phase
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import PAD, Topology, make_allocation

MAX_HOPS = Topology.MAX_HOPS

ALL_NAMES = registered_topologies()
#: one small instance per family, shared across the module (construction
#: is cheap but capacity arrays are worth reusing)
SMALL = {name: small_topology(name) for name in ALL_NAMES}


def _digest(a) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()) \
        .hexdigest()[:16]


# --------------------------------------------------------------------------
# Registry contract.
# --------------------------------------------------------------------------
def test_registry_covers_the_family():
    assert {"aries", "dragonfly", "dragonfly_consecutive",
            "dragonfly_plus", "fattree"} <= set(ALL_NAMES)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_spec_str_roundtrips_through_make_topology(name):
    topo = SMALL[name]
    clone = make_topology(topo.spec_str())
    assert clone.describe() == topo.describe()
    assert np.array_equal(clone.capacity_gbs, topo.capacity_gbs)


def test_make_topology_rejects_unknown_family():
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("torus:k=4")


def test_make_topology_passes_instances_through():
    topo = SMALL["aries"]
    assert make_topology(topo) is topo


# --------------------------------------------------------------------------
# Structural invariants, property-tested over every registered topology.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_NAMES)
def test_link_ranges_partition(name):
    inv.check_link_ranges(SMALL[name])


@pytest.mark.parametrize("name", ALL_NAMES)
def test_router_radix_matches_spec(name):
    inv.check_router_radix(SMALL[name])


@pytest.mark.parametrize("name", ALL_NAMES)
@given(seed=st.integers(min_value=0, max_value=2**16 - 1))
def test_candidates_invariants_any_draw(name, seed):
    """Paths from candidates() are structurally valid for ARBITRARY
    pair samples and candidate-draw seeds: in-range physical links,
    contiguous router walks src->dst, hop bounds respected, Valiant
    legs transiting exactly one intermediate group."""
    topo = SMALL[name]
    src, dst = inv.sample_pairs(topo, n=48, seed=seed)
    inv.check_candidates(topo, src, dst,
                         rng=np.random.default_rng(seed + 1))


@pytest.mark.parametrize("name", ALL_NAMES)
@given(n_min=st.integers(min_value=1, max_value=4),
       n_nonmin=st.integers(min_value=0, max_value=3))
def test_candidates_shape_contract(name, n_min, n_nonmin):
    topo = SMALL[name]
    src, dst = inv.sample_pairs(topo, n=16, seed=3)
    links, is_nonmin = topo.candidates(src, dst, n_min=n_min,
                                       n_nonmin=n_nonmin)
    assert links.shape == (16, n_min + n_nonmin, MAX_HOPS)
    assert is_nonmin.tolist() == [False] * n_min + [True] * n_nonmin


@pytest.mark.parametrize("name", ALL_NAMES)
def test_candidates_default_rng_is_deterministic(name):
    """candidates(rng=None) is the front-door contract: a fresh
    deterministic generator, so two calls agree bit-for-bit."""
    topo = SMALL[name]
    src, dst = inv.sample_pairs(topo, n=32, seed=9)
    la, _ = topo.candidates(src, dst)
    lb, _ = topo.candidates(src, dst)
    assert np.array_equal(la, lb)


@pytest.mark.parametrize("name", ALL_NAMES)
def test_same_node_flows_have_no_hops(name):
    topo = SMALL[name]
    src = np.arange(min(8, topo.n_nodes), dtype=np.int64)
    links, _ = topo.candidates(src, src.copy())
    assert (links == PAD).all()


@pytest.mark.parametrize("name", ALL_NAMES)
def test_full_invariant_battery(name):
    """The same battery `scripts/ci_lint.py --topology` runs headlessly."""
    inv.check_all(SMALL[name], n_pairs=128)


def test_invariant_violation_is_detected():
    """The harness itself must be able to fail: a topology lying about
    its link count is caught, not silently accepted."""
    class Liar(DragonflyTopology):
        def link_ranges(self):
            r = dict(super().link_ranges())
            lo, hi = r["global"]
            r["global"] = (lo, hi - 1)      # leaves a one-link gap
            return r

    with pytest.raises(inv.InvariantViolation):
        inv.check_link_ranges(Liar(SMALL_ARIES_PARAMS))


# --------------------------------------------------------------------------
# Aries seed regression: the canonical machine is frozen by digest.
# Pinned on the pre-family code (PR-4 HEAD); a family refactor that
# moves ANY of these has broken bit-compatibility.
# --------------------------------------------------------------------------
SMALL_ARIES_PARAMS = TopologyParams(n_groups=4, chassis_per_group=2,
                                    blades_per_chassis=4)


def test_aries_default_layout_pinned():
    t = DragonflyTopology()
    assert {k: tuple(map(int, v)) for k, v in t.link_ranges().items()} \
        == {"chassis": (0, 36864), "row": (36864, 50688),
            "global": (50688, 51840), "nic": (51840, 56448)}
    assert t.n_links == 56448
    assert _digest(t.capacity_gbs) == "a0f5f5cb52070c17"


def test_aries_small_layout_pinned():
    t = DragonflyTopology(SMALL_ARIES_PARAMS)
    assert {k: tuple(map(int, v)) for k, v in t.link_ranges().items()} \
        == {"chassis": (0, 256), "row": (256, 384),
            "global": (384, 512), "nic": (512, 640)}
    assert _digest(t.capacity_gbs) == "da24b3b4878ed09b"


def _small_aries_pairs(n=200):
    t = DragonflyTopology(SMALL_ARIES_PARAMS)
    rng = np.random.default_rng(123)
    src = rng.integers(0, t.params.n_nodes, size=n)
    dst = (src + rng.integers(1, t.params.n_nodes, size=n)) \
        % t.params.n_nodes
    return t, src, dst


def test_aries_candidate_paths_pinned():
    t, src, dst = _small_aries_pairs()
    links, is_nonmin = t.candidate_paths(src, dst,
                                         np.random.default_rng(7),
                                         n_min=4, n_nonmin=2)
    assert links.shape == (200, 6, MAX_HOPS)
    assert is_nonmin.tolist() == [False] * 4 + [True] * 2
    assert _digest(links.astype(np.int64)) == "83e48d69d7778b5d"


def test_aries_scalar_enumerators_pinned():
    t, src, dst = _small_aries_pairs()
    acc = []
    for s, d in zip(src[:64], dst[:64]):
        acc += t.minimal_path(int(s), int(d), k=1, order_seed=2) + [-7]
        acc += t.nonminimal_path(int(s), int(d), gi=3, k1=1, k2=2) + [-9]
    assert _digest(np.asarray(acc, dtype=np.int64)) == "9f7f9565865ab23f"


def test_aries_allocation_pinned():
    t = DragonflyTopology(SMALL_ARIES_PARAMS)
    al = make_allocation(t, 8, spread="inter_groups", seed=3)
    assert al.nodes[:4] == (96, 64, 32, 0)
    assert _digest(np.asarray(al.nodes, dtype=np.int64)) \
        == "c78be5273afe2a92"


def test_aries_run_phase_trace_pinned():
    """Seed-for-seed simulator trace on the small Aries: two phases of
    600 flows over an 8-rank inter-group allocation, hashed with the
    post-phase queue/memory/clock state."""
    t = DragonflyTopology(SMALL_ARIES_PARAMS)
    sim = DragonflySimulator(t, SimParams(seed=0))
    al = make_allocation(t, 8, spread="inter_groups", seed=3)
    fr = np.random.default_rng(42)
    fs = fr.integers(0, 8, 600)
    fd = (fs + fr.integers(1, 8, 600)) % 8
    fb = fr.pareto(1.2, 600) * 65536 + 1024
    nodes = np.array(al.nodes)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    h = hashlib.sha256()
    for _ in range(2):
        r = sim.run_phase(nodes[fs], nodes[fd], fb, pol)
        for a in (r.t_us, r.latency_us, r.stalls_per_flit):
            h.update(np.ascontiguousarray(a).tobytes())
    h.update(np.ascontiguousarray(sim.link_queue_s).tobytes())
    h.update(np.ascontiguousarray(sim.est_memory_s).tobytes())
    h.update(np.float64(sim.clock_s).tobytes())
    assert h.hexdigest()[:16] == "3534ff5a6f7e4fe1"


# --------------------------------------------------------------------------
# Differential: the vectorized fast path vs the frozen oracle, on every
# family the oracle can drive (it is topology-agnostic by construction).
# --------------------------------------------------------------------------
DIFF_NAMES = ["aries", "dragonfly", "dragonfly_plus"]


def _family_flows(topo, seed=42, n=400):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, topo.n_nodes, size=n)
    dst = (src + rng.integers(1, topo.n_nodes, size=n)) % topo.n_nodes
    size = rng.pareto(1.2, size=n) * 65536 + 1024
    return src, dst, size


@pytest.mark.parametrize("name", DIFF_NAMES)
def test_fast_path_bit_identical_to_oracle(name):
    topo = SMALL[name]
    src, dst, size = _family_flows(topo)
    al = make_allocation(topo, 8, spread="inter_groups", seed=3)
    sp = SimParams(seed=0)
    ref_sim = DragonflySimulator(topo, sp)
    fast_sim = DragonflySimulator(topo, sp)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    for _ in range(2):
        ra = reference_run_phase(ref_sim, src, dst, size, pol, al)
        rb = fast_sim.run_phase(src, dst, size, pol, al)
        assert np.array_equal(ra.t_us, rb.t_us)
        assert np.array_equal(ra.latency_us, rb.latency_us)
        assert np.array_equal(ra.stalls_per_flit, rb.stalls_per_flit)
        assert ra.nonmin_fraction == rb.nonmin_fraction
        assert np.array_equal(ref_sim.link_queue_s, fast_sim.link_queue_s)
    assert ref_sim.clock_s == fast_sim.clock_s


@pytest.mark.parametrize("name", ["dragonfly", "dragonfly_consecutive",
                                  "dragonfly_plus", "fattree"])
def test_non_aries_seed_determinism(name):
    """Same seed, same flows -> bit-identical runs on every new family."""
    topo = SMALL[name]
    src, dst, size = _family_flows(topo, seed=5)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    results = []
    for _ in range(2):
        sim = DragonflySimulator(topo, SimParams(seed=11))
        results.append(sim.run_phase(src, dst, size, pol))
    assert np.array_equal(results[0].t_us, results[1].t_us)
    assert np.array_equal(results[0].latency_us, results[1].latency_us)


@pytest.mark.parametrize("name", ["dragonfly", "dragonfly_plus"])
def test_non_aries_plan_vs_planless_consistency(name):
    """A PhasePlan run is a different RNG trajectory but the same
    physics on the new families too."""
    topo = SMALL[name]
    src, dst, size = _family_flows(topo, seed=8)
    al = make_allocation(topo, 8, spread="inter_groups", seed=4)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    sim_a = DragonflySimulator(topo, SimParams(seed=5))
    sim_b = DragonflySimulator(topo, SimParams(seed=5))
    ra = sim_a.run_phase(src, dst, size, pol, al)
    rb = sim_b.run_phase(None, None, None, pol, al,
                         plan=sim_b.plan_for(src, dst, size))
    assert rb.t_us.shape == ra.t_us.shape
    assert np.median(rb.t_us) == pytest.approx(np.median(ra.t_us),
                                               rel=0.25)


@pytest.mark.parametrize("name", ["dragonfly", "dragonfly_plus"])
def test_non_aries_subsample_consistency(name):
    """max_flows subsampling keeps shapes on the new families and the
    subsampled phase still produces finite positive flow times (the
    kept flows carry the dropped flows' bytes, so per-flow medians
    shift by design — only the structure is asserted)."""
    topo = SMALL[name]
    src, dst, size = _family_flows(topo, seed=2, n=300)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    full = DragonflySimulator(topo, SimParams(seed=1)) \
        .run_phase(src, dst, size, pol)
    sub = DragonflySimulator(topo, SimParams(seed=1, max_flows=100)) \
        .run_phase(src, dst, size, pol)
    assert full.t_us.shape == (300,)
    assert sub.t_us.shape == (100,)
    for r in (full, sub):
        assert np.isfinite(r.t_us).all() and (r.t_us > 0).all()
