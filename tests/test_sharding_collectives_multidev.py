"""Sharding rules + collective schedules under a multi-device host mesh.

These need >1 device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=16 (conftest must NOT set
it globally — smoke tests see 1 device by design)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=16",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_param_specs_divisibility_rules():
    out = run_sub("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs import get_smoke_config, get_config
        from repro.models import init_params
        from repro.sharding.partition import param_specs, default_policy
        mesh = compat.make_mesh((4, 4), ("data", "model"))
        cfg = get_config("llama3-8b")
        params = jax.eval_shape(lambda: init_params(cfg, 0))
        specs = param_specs(params, cfg, mesh)
        blocks = specs["blocks"]
        assert blocks["attn"]["wq"].spec == P(None, None, "model"), blocks["attn"]["wq"].spec
        assert blocks["attn"]["wo"].spec == P(None, "model", None)
        assert blocks["mlp"]["w_in"].spec == P(None, None, "model")
        assert specs["embed"].spec == P("model", None)
        assert specs["ln_f"].spec == P()
        # paligemma kv=1: wk head dim = 1*256 = 256 divisible by 4 -> sharded
        cfg2 = get_config("paligemma-3b")
        p2 = jax.eval_shape(lambda: init_params(cfg2, 0))
        s2 = param_specs(p2, cfg2, mesh)
        assert s2["blocks"]["attn"]["wk"].spec == P(None, None, "model")
        print("OK")
        """)
    assert "OK" in out


def test_moe_expert_parallel_specs():
    out = run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.configs import get_config
        from repro.models import init_params
        from repro.sharding.partition import param_specs
        mesh = compat.make_mesh((4, 4), ("data", "model"))
        cfg = get_config("granite-moe-3b-a800m")   # 40 experts % 4 == 0
        params = jax.eval_shape(lambda: init_params(cfg, 0))
        specs = param_specs(params, cfg, mesh)
        assert specs["blocks"]["moe"]["w_in"].spec == P(None, "model", None, None)
        assert specs["blocks"]["moe"]["router"].spec == P(None, None, None)
        print("OK")
        """)
    assert "OK" in out


def test_allreduce_schedules_agree():
    out = run_sub("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.collectives import allreduce_direct, allreduce_hierarchical
        mesh = compat.make_mesh((2, 2, 4), ("pod", "data", "model"))
        x = np.random.default_rng(0).standard_normal((16, 8, 3)).astype(np.float32)
        def run(fn):
            return compat.shard_map(fn, mesh=mesh,
                                 in_specs=P(("pod", "data", "model")),
                                 out_specs=P(("pod", "data", "model")),
                                 check_vma=False)(x)
        d = run(lambda v: allreduce_direct(v, ("pod", "data")))
        h = run(lambda v: allreduce_hierarchical(v, "pod", "data", 2))
        np.testing.assert_allclose(np.asarray(d), np.asarray(h), rtol=1e-6)
        print("OK")
        """)
    assert "OK" in out


def test_alltoall_schedules_roundtrip():
    out = run_sub("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.collectives import alltoall_direct, alltoall_hierarchical
        mesh = compat.make_mesh((2, 2, 4), ("pod", "data", "model"))
        y = np.arange(64*4, dtype=np.float32).reshape(64, 4)
        da = compat.shard_map(lambda v: alltoall_direct(v, "model"), mesh=mesh,
                              in_specs=P(("pod", "data", "model")),
                              out_specs=P(("pod", "data", "model")),
                              check_vma=False)(y)
        # a2a is an involution on 2 axes of equal split: applying the
        # direct exchange twice restores the input
        da2 = compat.shard_map(lambda v: alltoall_direct(alltoall_direct(v, "model"), "model"),
                               mesh=mesh, in_specs=P(("pod", "data", "model")),
                               out_specs=P(("pod", "data", "model")),
                               check_vma=False)(y)
        np.testing.assert_allclose(np.asarray(da2), y)
        h = compat.shard_map(lambda v: alltoall_hierarchical(v, "pod", "data"),
                             mesh=mesh, in_specs=P(("pod", "data", "model")),
                             out_specs=P(("pod", "data", "model")),
                             check_vma=False)(y)
        assert np.asarray(h).shape == y.shape
        print("OK")
        """)
    assert "OK" in out


def test_grad_allreduce_means_over_dp():
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.collectives import grad_allreduce
        from repro.collectives.modes import CollectiveMode
        mesh = compat.make_mesh((2, 2, 4), ("pod", "data", "model"))
        g = {"w": jnp.ones((8, 4))}
        for mode in (CollectiveMode.DIRECT, CollectiveMode.HIERARCHICAL):
            out = grad_allreduce(g, mesh, mode=mode)
            np.testing.assert_allclose(np.asarray(out["w"]), 1.0)
        print("OK")
        """)
    assert "OK" in out


def test_elastic_reshard_to_new_mesh():
    out = run_sub("""
        import jax, numpy as np
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.ckpt.elastic import reshard_checkpoint
        cfg = get_smoke_config("llama3-8b")
        params = init_params(cfg, 0)
        host = jax.tree_util.tree_map(np.asarray, params)
        mesh_small = compat.make_mesh((2, 2), ("data", "model"))
        mesh_big = compat.make_mesh((4, 4), ("data", "model"))
        a = reshard_checkpoint(host, cfg, mesh_small)
        b = reshard_checkpoint(host, cfg, mesh_big)
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK")
        """)
    assert "OK" in out
