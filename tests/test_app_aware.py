"""Algorithm 1 (application-aware routing) behaviour tests."""

import pytest
from hypothesis import given, strategies as st

from repro.core.app_aware import AppAwareRouter, RouterConfig
from repro.core.strategies import ModePerformance, RoutingMode

A, B = RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3


def router(**kw):
    return AppAwareRouter(RouterConfig(**kw))


def test_starts_adaptive():
    assert router().current == A


def test_small_messages_gated_to_high_bias():
    r = router(cumulative_threshold_bytes=4096)
    for _ in range(4):
        assert r.select(512) == B  # below the 4 KiB gate


def test_cumulative_gate_triggers_decision():
    r = router(cumulative_threshold_bytes=4096)
    # accumulate 8 x 512B = 4096 -> the 8th call runs the decision
    for i in range(7):
        r.select(512)
    r.observe(1000.0, 0.1)
    m = r.select(512)
    assert r.decisions == 1
    assert m in (A, B)


def test_switches_to_high_bias_for_latency_bound():
    """Small f + B has lower latency => B is selected (paper Fig. 8
    pingpong/barrier behaviour)."""
    r = router()
    r.select(8192)
    r.observe(latency_cycles=5000.0, stalls_per_flit=0.1)   # ADAPTIVE obs
    # B estimated via lambda=0.8 (lower L), sigma=1.6 (higher s):
    # for a small message latency dominates -> B
    m = r.select(8192)
    assert m == B


def test_stays_adaptive_for_bandwidth_bound():
    """Huge f => stall term dominates => ADAPTIVE (spread) wins."""
    r = router()
    r.select(8192)
    r.observe(latency_cycles=5000.0, stalls_per_flit=1.0)
    m = r.select(64 * 1024 * 1024)
    assert m == A


def test_alltoall_uses_increasingly_minimal():
    r = router()
    r.select(8192, alltoall=True)
    r.observe(5000.0, 2.0)
    m = r.select(64 * 1024 * 1024, alltoall=True)
    assert m == RoutingMode.ADAPTIVE_1  # default for alltoall, §4.2


def test_stale_samples_replaced_by_scaling():
    r = router(max_sample_age=2)
    r.select(8192)
    r.observe(1000.0, 0.5)           # A sample
    # age the B sample far beyond max_sample_age
    r.samples[B] = ModePerformance(1.0, 0.0, age=100)
    r.select(64 * 1024 * 1024)
    # decision must NOT trust the absurdly-good stale B sample
    assert r.current == A


def test_traffic_fraction_accounting():
    r = router()
    r.select(100)
    r.observe(1.0, 0.0)
    total = sum(r.sent_bytes_by_mode.values())
    assert total == 100
    assert r.traffic_fraction(B) == pytest.approx(1.0)


@given(sizes=st.lists(st.integers(64, 1 << 20), min_size=1, max_size=30))
def test_router_never_crashes_and_modes_valid(sizes):
    r = router()
    for s in sizes:
        m = r.select(s)
        assert m in (A, B, RoutingMode.ADAPTIVE_1)
        r.observe(1000.0, 0.2)
