"""Paper §2.4 performance model — unit + hypothesis property tests."""

import math

import pytest
from hypothesis import given, strategies as st

from repro.core.perf_model import (
    MessageShape, flit_threshold, flits_and_packets,
    predict_transmission_cycles, transmission_cycles_eq1,
    transmission_cycles_eq2, MAX_OUTSTANDING_PACKETS,
)


def test_put_flit_packet_counts():
    # 1 packet per 64B; PUT = 1 header + 4 payload flits
    f, p = flits_and_packets(64, is_put=True)
    assert p == 1 and f == 5
    f, p = flits_and_packets(128, is_put=True)
    assert p == 2 and f == 10


def test_get_flit_counts():
    f, p = flits_and_packets(256, is_put=False)
    assert p == 4 and f == 4  # GET requests carry no payload flits


def test_short_tail_packet():
    # 96B = one full packet + 32B tail (2 payload flits + header)
    f, p = flits_and_packets(96, is_put=True)
    assert p == 2
    assert f == 5 + 3


def test_eq1_eq2_agree_at_single_packet():
    # for p << 1024, Eq2's window term ~ L/2, recovering Eq1
    l, s, f, p = 2000.0, 0.3, 5, 1
    e1 = transmission_cycles_eq1(l, s, f)
    e2 = transmission_cycles_eq2(l, s, f, p)
    assert abs(e1 - e2) / e1 < 0.01


def test_eq2_window_term():
    # 1024 packets => one extra latency per window: coefficient 1.5
    t = transmission_cycles_eq2(1000.0, 0.0, 5 * 1024, 1024)
    assert t == pytest.approx(1.5 * 1000.0 + 5 * 1024)


@given(size=st.integers(64, 1 << 24), l=st.floats(100, 1e5),
       s=st.floats(0, 50))
def test_eq2_monotonic_in_stalls_and_latency(size, l, s):
    base = predict_transmission_cycles(size, l, s)
    assert predict_transmission_cycles(size, l * 1.1, s) > base
    assert predict_transmission_cycles(size, l, s + 0.5) > base
    assert predict_transmission_cycles(size * 2, l, s) > base


@given(l_a=st.floats(100, 1e5), l_b=st.floats(100, 1e5),
       s_a=st.floats(0, 20), s_b=st.floats(0, 20),
       size=st.integers(64, 1 << 22))
def test_flit_threshold_is_the_eq2_crossover(l_a, l_b, s_a, s_b, size):
    """f < threshold <=> Eq2(mode_b) < Eq2(mode_a), within Eq.(4)'s
    validity domain s_b > s_a (the paper's setting: the minimal-biased
    mode stalls more).  Outside it only the dominance corner is defined —
    the router compares Eq.(3) directly there."""
    f, p = flits_and_packets(size)
    thr = flit_threshold(l_a, s_a, l_b, s_b, p)
    tb = transmission_cycles_eq2(l_b, s_b, f, p)
    ta = transmission_cycles_eq2(l_a, s_a, f, p)
    if math.isinf(thr):
        # b dominates (never-worse) — Eq2 must agree
        assert tb <= ta + 1e-6 * max(ta, 1.0)
    elif s_b > s_a:
        if f < thr:
            assert tb < ta + 1e-6 * max(ta, 1.0)
        elif f > thr * (1 + 1e-9) + 1:
            assert tb >= ta - 1e-6 * max(ta, 1.0)


def test_window_never_below_half():
    # (p+512)/1024 >= ~0.5: the L/2 first-flit flight time survives
    assert MAX_OUTSTANDING_PACKETS == 1024
    t = transmission_cycles_eq2(1000.0, 0.0, 5, 1)
    assert t >= 500.0
