import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
# device; only launch/dryrun.py (its own process) requests 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
