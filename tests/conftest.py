import os
import sys

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
# device; only launch/dryrun.py (its own process) requests 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Container without hypothesis: install the deterministic stub so the
    # suite (incl. property tests, at reduced power) still runs.
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
    from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
