"""Shared test config: src-layout path + the hypothesis fallback shim.

When the real `hypothesis` is installed the suite runs at full
property-testing power (profile "ci", 25 examples).  In containers
without it, a deterministic stand-in module is built here and installed
into ``sys.modules`` so ``from hypothesis import given, strategies``
keeps importing — but every stub-driven test is marked
``hypothesis_stub`` and the report header says so, making the
degradation visible instead of silent (ISSUE 7 satellite: the old
``tests/_hypothesis_stub.py`` hid it).
"""

import inspect
import os
import random
import sys
import types

import pytest

# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
# device; only launch/dryrun.py (its own process) requests 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401

    HYPOTHESIS_FALLBACK = False
except ModuleNotFoundError:
    HYPOTHESIS_FALLBACK = True


def _build_stub() -> types.ModuleType:
    """A minimal deterministic `hypothesis` stand-in.

    Supports the subset the suite uses: ``@given`` with positional or
    keyword strategies, ``st.integers/floats/booleans/sampled_from/
    lists``, and ``settings`` profiles.  ``@given`` runs a boundary pass
    (min/max/representative values) plus a seeded random pass — far
    weaker than real shrinking, hence the visible marker.
    """
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class settings:  # noqa: N801 — mirrors hypothesis' API
        _profiles: dict = {}
        max_examples = 25

        def __init__(self, **kw):
            self.kw = kw

        def __call__(self, fn):
            return fn

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            for k, v in cls._profiles.get(name, {}).items():
                setattr(cls, k, v)

    class SearchStrategy:
        """Deterministic value source: boundary examples + random draws."""

        def __init__(self, boundary, draw):
            self.boundary = boundary  # list of edge-case values
            self.draw = draw          # rnd -> one random value

    def integers(min_value=0, max_value=2**31 - 1):
        lo, hi = int(min_value), int(max_value)
        mid = (lo + hi) // 2
        return SearchStrategy([lo, hi, mid],
                              lambda r: r.randint(lo, hi))

    def floats(min_value=0.0, max_value=1.0, **_):
        lo, hi = float(min_value), float(max_value)
        return SearchStrategy([lo, hi, (lo + hi) / 2],
                              lambda r: r.uniform(lo, hi))

    def booleans():
        return SearchStrategy([False, True],
                              lambda r: bool(r.getrandbits(1)))

    def sampled_from(elements):
        seq = list(elements)
        return SearchStrategy([seq[0], seq[-1]],
                              lambda r: r.choice(seq))

    def lists(elem, min_size=0, max_size=8):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elem.draw(r) for _ in range(n)]

        return SearchStrategy([[elem.boundary[0]] * max(min_size, 1)
                               if max_size else []], draw)

    def given(*arg_strategies, **kw_strategies):
        """Bind positional strategies to the RIGHTMOST free parameters.

        Leading unbound parameters stay in the wrapper's signature so
        ``@given`` composes with ``@pytest.mark.parametrize`` fixtures
        exactly like the real decorator.
        """

        def deco(fn):
            sig = inspect.signature(fn)
            free = [p for p in sig.parameters if p not in kw_strategies]
            pos_names = free[len(free) - len(arg_strategies):]
            strat_map = dict(zip(pos_names, arg_strategies),
                             **kw_strategies)
            leading = [sig.parameters[p] for p in sig.parameters
                       if p not in strat_map]

            def wrapper(*args, **kwargs):
                rnd = random.Random(0xD5A607)
                names = list(strat_map)
                # boundary pass: walk each strategy's edge list in step
                width = max(len(s.boundary) for s in strat_map.values())
                for i in range(width):
                    ex = {n: s.boundary[i % len(s.boundary)]
                          for n, s in strat_map.items()}
                    fn(*args, **kwargs, **ex)
                # random pass up to the profile budget
                for _ in range(max(settings.max_examples - width, 0)):
                    ex = {n: s.draw(rnd) for n, s in strat_map.items()}
                    fn(*args, **kwargs, **ex)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = sig.replace(parameters=leading)
            wrapper.hypothesis_stub = True
            wrapper.pytestmark = list(getattr(fn, "pytestmark", []))
            return wrapper

        return deco

    st.SearchStrategy = SearchStrategy
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.lists = lists
    mod.strategies = st
    mod.settings = settings
    mod.given = given
    mod.SearchStrategy = SearchStrategy
    return mod


if HYPOTHESIS_FALLBACK:
    _stub = _build_stub()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def pytest_report_header(config):
    if HYPOTHESIS_FALLBACK:
        return ("hypothesis: NOT INSTALLED — deterministic stub active "
                "(property tests run at reduced power; items marked "
                "'hypothesis_stub')")
    return "hypothesis: real package active (profile 'ci')"


def pytest_collection_modifyitems(config, items):
    if not HYPOTHESIS_FALLBACK:
        return
    for item in items:
        fn = getattr(item, "function", None)
        if getattr(fn, "hypothesis_stub", False):
            item.add_marker(pytest.mark.hypothesis_stub)
