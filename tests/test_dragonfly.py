"""Dragonfly topology + simulator: path parity, tiers, crossovers."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TopologyParams)
from repro.dragonfly.routing import RoutingPolicy, score_candidates, spray_weights
from repro.dragonfly.topology import PAD, make_allocation
from repro.dragonfly.traffic import (PATTERNS, alltoall, halo3d, pingpong,
                                     run_iteration, sweep3d)

TOPO = DragonflyTopology(TopologyParams(n_groups=8))


@given(st.integers(0, TOPO.params.n_nodes - 1),
       st.integers(0, TOPO.params.n_nodes - 1),
       st.integers(0, 3), st.integers(0, 3), st.integers(0, 31))
def test_vectorized_paths_match_scalar(src, dst, k, seed, gi):
    if src == dst:
        return
    g1, c1, b1, _ = TOPO.node_coords(np.array([src]))
    g2, c2, b2, _ = TOPO.node_coords(np.array([dst]))
    vec = TOPO._minimal_vec(g1, c1, b1, g2, c2, b2,
                            np.array([k]), np.array([seed]))[0]
    vec = [int(x) for x in vec if x != PAD]
    assert vec == TOPO.minimal_path(src, dst, k=k, order_seed=seed)
    vecn = TOPO._nonmin_vec(g1, c1, b1, g2, c2, b2, np.array([gi]),
                            np.array([k]), np.array([(k + 1) % 4]))[0]
    vecn = [int(x) for x in vecn if x != PAD]
    assert vecn == TOPO.nonminimal_path(src, dst, gi=gi, k1=k,
                                        k2=(k + 1) % 4)


@given(st.integers(0, TOPO.params.n_nodes - 1),
       st.integers(0, TOPO.params.n_nodes - 1))
def test_minimal_path_hop_bounds(src, dst):
    """<=2 hops intra-group, <=5 inter-group (Fig. 1's 5-hop example)."""
    if src == dst:
        return
    p = TOPO.minimal_path(src, dst)
    g1 = TOPO.node_coords(np.array([src]))[0]
    g2 = TOPO.node_coords(np.array([dst]))[0]
    assert len(p) <= (2 if g1 == g2 else 5)
    for link in p:
        assert 0 <= link < TOPO.n_links


def test_links_are_directed():
    a = TOPO.chassis_link(0, 0, 1, 2)
    b = TOPO.chassis_link(0, 0, 2, 1)
    assert a != b and abs(int(a) - int(b)) == 1


def test_allocation_spreads():
    al = make_allocation(TOPO, 4, spread="inter_nodes", seed=0)
    gs = {int(TOPO.node_coords(np.array([n]))[0][0]) for n in al.nodes}
    assert len(gs) == 1
    al = make_allocation(TOPO, 16, spread="groups:4", seed=0)
    gs = {int(TOPO.node_coords(np.array([n]))[0][0]) for n in al.nodes}
    assert len(gs) == 4
    assert len(set(al.nodes)) == 16


def test_sim_deterministic():
    res = []
    for _ in range(2):
        sim = DragonflySimulator(TOPO, SimParams(seed=5))
        al = make_allocation(TOPO, 2, spread="inter_groups", seed=1)
        r = run_iteration(sim, al, pingpong(2, 65536),
                          RoutingPolicy(RoutingMode.ADAPTIVE_0))
        res.append(r.time_us)
    assert res[0] == res[1]


def test_fig3_tier_tails():
    """inter_nodes stays clean; inter_groups grows tails (Fig. 3)."""
    stats = {}
    for spread in ("inter_nodes", "inter_groups"):
        ts = []
        for seed in range(3):
            sim = DragonflySimulator(TOPO, SimParams(seed=seed))
            al = make_allocation(TOPO, 2, spread=spread, seed=seed)
            for _ in range(60):
                ts.append(run_iteration(
                    sim, al, pingpong(2, 16384),
                    RoutingPolicy(RoutingMode.ADAPTIVE_0)).time_us)
        ts = np.asarray(ts)
        stats[spread] = (np.median(ts), ts.max())
    assert stats["inter_groups"][0] > stats["inter_nodes"][0]
    assert stats["inter_groups"][1] > 5 * stats["inter_nodes"][1]


def test_fig7_intra_group_stall_crossover():
    """4MiB intra-group: HIGH BIAS concentrates on the few minimal paths ->
    more stalls -> slower than ADAPTIVE (paper Fig. 7a/b)."""
    med = {}
    for mode in (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3):
        ts, ss = [], []
        for seed in range(3):
            sim = DragonflySimulator(TOPO, SimParams(seed=seed,
                                                     bg_enable=False))
            al = make_allocation(TOPO, 2, spread="inter_chassis", seed=seed)
            for _ in range(25):
                r = run_iteration(sim, al, pingpong(2, 4 << 20),
                                  RoutingPolicy(mode))
                ts.append(r.time_us)
                ss.append(r.mean_stalls)
        med[mode] = (np.median(ts), np.median(ss))
    assert med[RoutingMode.ADAPTIVE_3][1] > med[RoutingMode.ADAPTIVE_0][1]
    assert med[RoutingMode.ADAPTIVE_3][0] > med[RoutingMode.ADAPTIVE_0][0]


def test_nic_counters_populated():
    sim = DragonflySimulator(TOPO, SimParams(seed=0))
    al = make_allocation(TOPO, 2, spread="inter_groups", seed=0)
    run_iteration(sim, al, pingpong(2, 65536),
                  RoutingPolicy(RoutingMode.ADAPTIVE_0), )
    c = sim.counters[al.allocation_id]
    f, p = 65536 // 64 * 5, 65536 // 64
    assert c.request_flits == 2 * f      # both pingpong directions
    assert c.request_packets == 2 * p
    assert c.request_packets_cumulative_latency_us > 0


def test_patterns_shapes():
    for name, fn in PATTERNS.items():
        args = {"pingpong": dict(size=1024), "allreduce": dict(elements=64),
                "alltoall": dict(size_per_pair=512),
                "barrier": {}, "broadcast": dict(size=2048),
                "halo3d": dict(nx=64), "sweep3d": dict(nx=64),
                "moe_alltoall": dict(tokens_per_rank=64,
                                     token_bytes=128)}[name]
        phases = fn(16, **args)
        assert len(phases) >= 1
        for s, d, b in phases:
            assert s.shape == d.shape == b.shape
            assert (s != d).all()
            assert (s < 16).all() and (d < 16).all()


def test_alltoall_flow_count():
    (s, d, b), = alltoall(8, 128)
    assert s.size == 8 * 7


def test_spray_weights_sum_to_one():
    rng = np.random.default_rng(0)
    scores = rng.random((50, 6)) * 1e-5
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
    w = spray_weights(scores, pol, rng, packets=np.full(50, 1e4))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-9)
    # deterministic minimal: no weight on nonmin candidates
    pol = RoutingPolicy(RoutingMode.MIN_HASH)
    nonmin = np.array([False] * 4 + [True] * 2)
    sc = score_candidates(np.zeros((5, 6, 8), np.int64), np.zeros(TOPO.n_links),
                          nonmin, pol)
    w = spray_weights(sc, pol)
    assert w[:, 4:].sum() == 0.0
