"""repro.policy — unified policy engine tests.

Includes the acceptance-criterion trace test: AppAwarePolicy driven one
message at a time (batch=1, "message" granularity) must be decision-for-
decision identical to the SEED AppAwareRouter on recorded traces.  The
seed implementation is frozen below as `_SeedRouter` (copied verbatim
from the pre-refactor repro/core/app_aware.py) so the equivalence is
anchored against the original, not against the shim that now delegates
to the very code under test.
"""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.app_aware import AppAwareRouter, RouterConfig
from repro.core.perf_model import flits_and_packets, transmission_cycles_eq2
from repro.core.strategies import ModePerformance, RoutingMode
from repro.policy import (AppAwareConfig, AppAwarePolicy, DecisionBatch,
                          EpsilonGreedyPolicy, Feedback, KIND_ALLTOALL,
                          KIND_PT2PT, PolicyEngine, StaticPolicy,
                          TelemetryBus, TrafficLedger, make_engine)

A, B = RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3
A1 = RoutingMode.ADAPTIVE_1


# --------------------------------------------------------------------------
# Frozen seed implementation (reference for the equivalence property).
# --------------------------------------------------------------------------
class _SeedRouter:
    def __init__(self, config=None):
        self.config = config or AppAwareConfig()
        self.current = self.config.mode_a
        self.samples = {}
        self.cumulative_bytes = 0
        self.sent_bytes_by_mode = {}
        self.decisions = 0
        self._pending_mode = None

    def select(self, msg_size_bytes, *, alltoall=False):
        cfg = self.config
        mode_a = cfg.mode_a_alltoall if alltoall else cfg.mode_a
        self.cumulative_bytes += msg_size_bytes
        if self.cumulative_bytes < cfg.cumulative_threshold_bytes:
            chosen = cfg.mode_b
        else:
            self.cumulative_bytes = 0
            self.decisions += 1
            chosen = self._decide(msg_size_bytes, mode_a)
            self.current = chosen
        self._pending_mode = chosen
        self.sent_bytes_by_mode[chosen] = (
            self.sent_bytes_by_mode.get(chosen, 0) + msg_size_bytes)
        return chosen

    def _decide(self, msg_size_bytes, mode_a):
        cfg = self.config
        f, p = flits_and_packets(msg_size_bytes, cfg.is_put)
        if self.current == cfg.mode_b:
            perf_b = self.samples.get(cfg.mode_b)
            if perf_b is None:
                return cfg.mode_b
            perf_a = self._estimate_other(
                perf_b, 1.0 / max(cfg.lambda_latency, 1e-9),
                1.0 / max(cfg.sigma_stalls, 1e-9), mode_a)
        else:
            perf_a = self.samples.get(self.current) \
                or self.samples.get(mode_a)
            if perf_a is None:
                return mode_a
            perf_b = self._estimate_other(
                perf_a, cfg.lambda_latency, cfg.sigma_stalls, cfg.mode_b)
        t_a = transmission_cycles_eq2(
            perf_a.latency_cycles, perf_a.stall_cycles_per_flit, f, p)
        t_b = transmission_cycles_eq2(
            perf_b.latency_cycles, perf_b.stall_cycles_per_flit, f, p)
        return cfg.mode_b if t_b < t_a else mode_a

    def _estimate_other(self, known, lam, sig, other_mode):
        stored = self.samples.get(other_mode)
        if stored is not None and stored.age <= self.config.max_sample_age:
            return stored
        return ModePerformance(
            latency_cycles=known.latency_cycles * lam,
            stall_cycles_per_flit=known.stall_cycles_per_flit * sig)

    def observe(self, latency_cycles, stalls_per_flit):
        if self._pending_mode is None:
            return
        self.samples = {m: perf.aged() for m, perf in self.samples.items()}
        self.samples[self._pending_mode] = ModePerformance(
            latency_cycles, stalls_per_flit, age=0)
        self._pending_mode = None


def _trace_from(seed: int, n: int):
    """A recorded trace: (size, alltoall, L, s) tuples."""
    rng = np.random.default_rng(seed)
    sizes = (2.0 ** rng.uniform(6, 24, size=n)).astype(int)
    a2a = rng.random(n) < 0.3
    lat = rng.uniform(100, 5e4, size=n)
    stalls = rng.uniform(0, 5, size=n)
    return list(zip(sizes, a2a, lat, stalls))


@given(seed=st.integers(0, 10_000))
def test_appaware_policy_batch1_matches_seed_router_on_trace(seed):
    """Acceptance criterion: batch-of-1 AppAwarePolicy == seed Algorithm 1,
    decision for decision, on a recorded trace."""
    ref = _SeedRouter()
    pol = AppAwarePolicy(AppAwareConfig(), granularity="message")
    eng = PolicyEngine(pol)
    for size, a2a, lat, stalls in _trace_from(seed, 40):
        kind = KIND_ALLTOALL if a2a else KIND_PT2PT
        got = eng.decide(DecisionBatch.single(size, kind=kind))[0]
        want = ref.select(int(size), alltoall=bool(a2a))
        assert got is want
        ref.observe(lat, stalls)
        eng.update(Feedback.single(lat, stalls))
    site = pol.site("default")
    assert site.decisions == ref.decisions
    assert site.current is ref.current
    assert site.ledger.sent == pytest.approx(ref.sent_bytes_by_mode)


@given(seed=st.integers(0, 10_000))
def test_legacy_shim_matches_seed_router_on_trace(seed):
    """The deprecated AppAwareRouter shim replays the seed exactly too."""
    ref = _SeedRouter()
    shim = AppAwareRouter(RouterConfig())
    for size, a2a, lat, stalls in _trace_from(seed, 40):
        assert shim.select(int(size), alltoall=bool(a2a)) \
            is ref.select(int(size), alltoall=bool(a2a))
        ref.observe(lat, stalls)
        shim.observe(lat, stalls)
    assert shim.decisions == ref.decisions
    assert shim.current is ref.current


# --------------------------------------------------------------------------
# Vectorized engine behaviour.
# --------------------------------------------------------------------------
def test_engine_phase_granularity_one_automaton_step_per_group():
    eng = make_engine("app_aware")
    n = 5000
    sizes = np.full(n, 1 << 20)
    eng.decide(DecisionBatch.of(sizes, site="s1"))
    pol = eng.policy
    assert pol.site("s1").decisions == 1       # ONE step for 5000 rows
    assert eng.decide_calls == 1 and eng.rows_decided == n

    # mixed sites in one batch: one step each, rows routed per site
    site = np.empty(4, dtype=object)
    site[:] = ["a", "b", "a", "b"]
    modes = eng.decide(DecisionBatch(np.full(4, 1 << 20), site,
                                     np.array(["pt2pt"] * 4, dtype=object)))
    assert len(modes) == 4
    assert pol.site("a").decisions == 1
    assert pol.site("b").decisions == 1


def test_engine_decide_returns_row_aligned_modes():
    eng = make_engine("static", static_mode=B)
    modes = eng.decide(DecisionBatch.of([1, 2, 3]))
    assert modes.shape == (3,) and all(m is B for m in modes)


def test_engine_broadcasts_single_sample_feedback():
    eng = make_engine("app_aware")
    eng.decide(DecisionBatch.of(np.full(8, 1 << 20), site="x"))
    # a counter-window read produces ONE aggregate sample for the batch
    eng.update(Feedback.single(1234.0, 0.5))
    site = eng.policy.site("x")
    assert len(site.samples) == 1
    (perf,) = site.samples.values()
    assert perf.latency_cycles == pytest.approx(1234.0)


def test_alltoall_kind_routes_to_increasingly_minimal():
    eng = make_engine("app_aware")
    eng.decide(DecisionBatch.of([1 << 20], site="a2a", kind=KIND_ALLTOALL))
    eng.update(Feedback.single(5000.0, 2.0))
    modes = eng.decide(DecisionBatch.of([64 << 20], site="a2a",
                                        kind=KIND_ALLTOALL))
    assert modes[0] is A1   # paper §4.2: alltoall default is INCR-MINIMAL


# --------------------------------------------------------------------------
# Satellite regression: gate-forced traffic is ledgered separately.
# --------------------------------------------------------------------------
def test_gated_bytes_tracked_separately_from_decisions():
    r = AppAwareRouter(RouterConfig(cumulative_threshold_bytes=4096))
    r.select(100)                       # below the gate -> forced mode_b
    # physical accounting unchanged (the bytes really went out mode_b)
    assert r.sent_bytes_by_mode == {B: 100}
    assert r.traffic_fraction(B) == pytest.approx(1.0)
    # ...but it was no decision: the gated ledger holds it instead
    assert r.gated_bytes_by_mode == {B: 100}
    assert r.decided_bytes_by_mode == {}
    assert r.traffic_fraction(B, include_gated=False) == 0.0
    assert r.gated_fraction() == pytest.approx(1.0)
    # `current` is untouched by the gate (the original bug's symptom)
    assert r.current is A

    # a real decision lands in `decided`, not `gated`
    r.observe(1000.0, 0.1)
    r.select(8192)
    assert sum(r.decided_bytes_by_mode.values()) == 8192
    assert sum(r.gated_bytes_by_mode.values()) == 100
    assert 0.0 < r.gated_fraction() < 1.0


def test_traffic_ledger_batch_accounting():
    led = TrafficLedger()
    modes = np.empty(4, dtype=object)
    modes[:] = [A, B, B, A]
    led.add_batch(modes, np.array([10.0, 20.0, 30.0, 40.0]),
                  gated=np.array([False, True, False, False]))
    assert led.sent == {A: 50.0, B: 50.0}
    assert led.gated == {B: 20.0}
    assert led.decided == {A: 50.0, B: 30.0}
    assert led.traffic_fraction(B) == pytest.approx(0.5)
    assert led.traffic_fraction(B, include_gated=False) \
        == pytest.approx(30.0 / 80.0)
    assert led.gated_fraction() == pytest.approx(0.2)


# --------------------------------------------------------------------------
# Baseline policies.
# --------------------------------------------------------------------------
def test_static_policy_ignores_feedback():
    pol = StaticPolicy(A)
    b = DecisionBatch.of([1, 2, 3])
    modes = pol.decide(b)
    pol.update(b, Feedback.of([1.0] * 3, [0.0] * 3))
    assert all(m is A for m in modes)


def test_eps_greedy_exploits_cheaper_arm():
    pol = EpsilonGreedyPolicy(mode_a=A, mode_b=B, epsilon=0.0, seed=0)
    eng = PolicyEngine(pol)
    # arm A: low cost; arm B: high cost (after both are bootstrapped)
    costs = {A: (100.0, 0.1), B: (100.0, 10.0)}
    for _ in range(4):
        modes = eng.decide(DecisionBatch.of(np.full(16, 1 << 16), site="s"))
        lat = np.array([costs[m][0] for m in modes])
        stl = np.array([costs[m][1] for m in modes])
        eng.update(Feedback.of(lat, stl))
    modes = eng.decide(DecisionBatch.of(np.full(64, 1 << 16), site="s"))
    assert all(m is A for m in modes)


def test_eps_greedy_explores_both_arms():
    pol = EpsilonGreedyPolicy(mode_a=A, mode_b=B, epsilon=1.0, seed=3)
    modes = pol.decide(DecisionBatch.of(np.full(256, 1 << 16), site="s"))
    assert {m for m in modes} == {A, B}


def test_eps_greedy_epsilon_decays_per_site():
    """eps0 / (1 + k·t) with t = prior decide() touches of the site."""
    pol = EpsilonGreedyPolicy(mode_a=A, mode_b=B, epsilon=1.0,
                              epsilon_decay=1.0, seed=0)
    assert pol.effective_epsilon("s") == pytest.approx(1.0)
    for t in range(1, 5):
        pol.decide(DecisionBatch.of(np.full(8, 1 << 16), site="s"))
        assert pol.effective_epsilon("s") == pytest.approx(1.0 / (1 + t))
    # sites decay independently; zero decay recovers constant ε
    assert pol.effective_epsilon("fresh") == pytest.approx(1.0)
    # a batch mixing kinds at one site is ONE schedule step, not two
    mixed = EpsilonGreedyPolicy(mode_a=A, mode_b=B, epsilon=1.0,
                                epsilon_decay=1.0, seed=0)
    kinds = np.array([KIND_PT2PT] * 4 + [KIND_ALLTOALL] * 4, dtype=object)
    mixed.decide(DecisionBatch.of(np.full(8, 1 << 16), site="s",
                                  kind=kinds))
    assert mixed.effective_epsilon("s") == pytest.approx(1.0 / 2.0)
    flat = EpsilonGreedyPolicy(mode_a=A, mode_b=B, epsilon=0.3,
                               epsilon_decay=0.0, seed=0)
    for _ in range(10):
        flat.decide(DecisionBatch.of(np.full(8, 1 << 16), site="s"))
    assert flat.effective_epsilon("s") == pytest.approx(0.3)


def test_eps_greedy_decay_stops_exploring():
    """With decay the converged policy routes (almost) everything to the
    winner (the fig8 failure mode was ε of the traffic exploring
    forever)."""
    pol = EpsilonGreedyPolicy(mode_a=A, mode_b=B, epsilon=1.0,
                              epsilon_decay=10.0, seed=1)
    eng = PolicyEngine(pol)
    costs = {A: (100.0, 0.1), B: (100.0, 10.0)}
    for _ in range(50):
        modes = eng.decide(DecisionBatch.of(np.full(16, 1 << 16), site="s"))
        lat = np.array([costs[m][0] for m in modes])
        stl = np.array([costs[m][1] for m in modes])
        eng.update(Feedback.of(lat, stl))
    # schedule, exactly: 50 decide() touches -> eps0 / (1 + 10*50)
    assert pol.effective_epsilon("s") == pytest.approx(1.0 / 501.0)
    # behavior, with margin: the losing arm gets at most stray explores
    modes = eng.decide(DecisionBatch.of(np.full(256, 1 << 16), site="s"))
    assert np.mean([m is not A for m in modes]) < 0.02


# --------------------------------------------------------------------------
# TelemetryBus normalization.
# --------------------------------------------------------------------------
def test_bus_normalizes_counter_delta_to_cycles():
    from repro.core.counters import CounterDelta
    bus = TelemetryBus(clock_ghz=1.0)
    delta = CounterDelta(flits=500, stalled_cycles=250, packets=100,
                         latency_us_total=1000.0, window_s=1.0)
    fb = bus.from_counter_delta(delta)
    assert fb.latency_cycles[0] == pytest.approx(10.0 * 1e3)  # 10us @1GHz
    assert fb.stalls_per_flit[0] == pytest.approx(0.5)
    assert fb.source == "nic"


def test_bus_fans_out_to_subscribers():
    bus = TelemetryBus()
    got = []
    bus.subscribe(got.append)
    bus.subscribe(got.append)
    fb = bus.publish_flow_arrays([1.0], [0.0])
    assert got == [fb, fb]
    assert bus.history[-1] is fb


# --------------------------------------------------------------------------
# End-to-end: engine drives the Dragonfly simulator, one call per phase.
# --------------------------------------------------------------------------
def test_run_iteration_engine_one_decide_per_phase():
    from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                                 SimParams, TopologyParams)
    from repro.dragonfly.topology import make_allocation
    from repro.dragonfly.traffic import (PATTERN_KIND, PATTERNS,
                                         engine_for_arm,
                                         run_iteration_engine)
    topo = DragonflyTopology(TopologyParams(n_groups=8))
    sim = DragonflySimulator(topo, SimParams(seed=0))
    al = make_allocation(topo, 16, spread="groups:4", seed=0)
    phases = PATTERNS["alltoall"](16, size_per_pair=65536)
    eng = engine_for_arm("app_aware", sim)
    res = run_iteration_engine(sim, al, phases, eng, site="a2a",
                               kind=PATTERN_KIND["alltoall"])
    assert eng.decide_calls == len(phases)      # ONE engine call per phase
    assert eng.rows_decided == sum(p[0].size for p in phases)
    assert res.time_us > 0
    assert sum(res.mode_bytes.values()) == pytest.approx(
        sum(float(p[2].sum()) for p in phases))


def test_simulator_accepts_mixed_per_flow_modes():
    from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                                 SimParams, TopologyParams)
    from repro.dragonfly.routing import RoutingPolicy
    from repro.dragonfly.topology import make_allocation
    topo = DragonflyTopology(TopologyParams(n_groups=8))
    sim = DragonflySimulator(topo, SimParams(seed=0, bg_enable=False))
    al = make_allocation(topo, 8, spread="groups:4", seed=0)
    nodes = np.asarray(al.nodes)
    src = nodes[np.arange(0, 8)]
    dst = nodes[(np.arange(0, 8) + 1) % 8]
    modes = np.empty(8, dtype=object)
    modes[:] = [A, B, RoutingMode.MIN_HASH, RoutingMode.NMIN_HASH] * 2
    res = sim.run_phase(src, dst, np.full(8, 65536.0),
                        RoutingPolicy(A), al, modes=modes)
    assert res.t_us.shape == (8,)
    assert np.isfinite(res.t_us).all()


# --------------------------------------------------------------------------
# DecisionBatch plumbing.
# --------------------------------------------------------------------------
def test_decision_batch_groups_in_first_appearance_order():
    site = np.empty(5, dtype=object)
    site[:] = ["x", "y", "x", "z", "y"]
    b = DecisionBatch(np.arange(5, dtype=np.float64), site,
                      np.array(["pt2pt"] * 5, dtype=object))
    got = [(s, list(rows)) for s, _, rows in b.groups()]
    assert got == [("x", [0, 2]), ("y", [1, 4]), ("z", [3])]


def test_decision_batch_shape_validation():
    with pytest.raises(ValueError):
        DecisionBatch(np.zeros(3), np.empty(2, dtype=object),
                      np.empty(3, dtype=object))
    with pytest.raises(ValueError):
        DecisionBatch.of([1, 2, 3], site=["a", "b"])


# --------------------------------------------------------------------------
# PR-3 satellite: the vectorized _SiteTable "phase" path is step-for-step
# equivalent to driving one SiteState automaton per group sequentially.
# --------------------------------------------------------------------------
def _site_state_phase_reference(policy_cfg, trace):
    """The pre-vectorization phase-granularity loop, re-implemented over
    SiteState (kept for the "message" path) as the equivalence oracle."""
    from repro.policy.app_aware import SiteState

    sites, log = {}, []
    for batch, feedback in trace:
        pending = []
        for site_key, kind, rows in batch.groups():
            stt = sites.setdefault(site_key, SiteState(policy_cfg))
            msg = float(batch.msg_bytes[rows].max())
            mode = stt.select(int(msg), alltoall=kind == KIND_ALLTOALL)
            pending.append((stt, rows, mode))
            log.append((site_key, mode))
        lat, st_, w = (feedback.latency_cycles, feedback.stalls_per_flit,
                       feedback.weight)
        for stt, rows, mode in pending:
            wr = w[rows]
            tot = float(wr.sum()) or 1.0
            stt.observe_for_mode(mode, float((lat[rows] * wr).sum() / tot),
                                 float((st_[rows] * wr).sum() / tot))
    return sites, log


@given(seed=st.integers(0, 2000))
def test_phase_table_matches_sequential_site_states(seed):
    rng = np.random.default_rng(seed)
    cfg = AppAwareConfig()
    pol = AppAwarePolicy(cfg, granularity="phase")
    trace = []
    site_pool = ["s0", "s1", "s2"]
    for _ in range(12):
        n = int(rng.integers(1, 6))
        sizes = (2.0 ** rng.uniform(6, 26, size=n))
        site = np.empty(n, dtype=object)
        site[:] = [site_pool[i] for i in rng.integers(0, 3, size=n)]
        kind = np.empty(n, dtype=object)
        kind[:] = [KIND_ALLTOALL if x else KIND_PT2PT
                   for x in rng.random(n) < 0.4]
        batch = DecisionBatch(np.asarray(sizes, dtype=np.float64),
                              site, kind)
        fb = Feedback.of(rng.uniform(100, 5e4, size=n),
                         rng.uniform(0, 5, size=n))
        trace.append((batch, fb))
    got_log = []
    for batch, fb in trace:
        modes = pol.decide(batch)
        for site_key, kind, rows in batch.groups():
            got_log.append((site_key, modes[rows[0]]))
        pol.update(batch, fb)
    ref_sites, ref_log = _site_state_phase_reference(cfg, trace)
    assert got_log == ref_log
    for key, ref in ref_sites.items():
        view = pol.site(key)
        assert view.current is ref.current
        assert view.decisions == ref.decisions
        assert view.cumulative_bytes == ref.cumulative_bytes
        assert set(view.samples) == set(ref.samples)
        for m, perf in ref.samples.items():
            assert view.samples[m].latency_cycles \
                == pytest.approx(perf.latency_cycles)
            assert view.samples[m].age == perf.age
