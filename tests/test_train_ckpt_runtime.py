"""Training loop, checkpoint/restart, fault tolerance, stragglers, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.data.synthetic import SyntheticLM, make_batch
from repro.configs.shapes import InputShape
from repro.models import init_params
from repro.models.common import ModelConfig, Family
from repro.runtime.elastic import ElasticConfig, ElasticPlanner
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           HeartbeatMonitor, NodeState,
                                           RestartPolicy)
from repro.runtime.fault_tolerance import RestartAction
from repro.runtime.straggler import StragglerConfig, StragglerMitigator
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule)
from repro.train.train_step import TrainConfig, loss_fn, train_step


def tiny_cfg():
    return ModelConfig(name="t", family=Family.DENSE, n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=128, remat=False)


def _batch(cfg, step=0, b=4, s=16):
    gen = SyntheticLM(vocab=cfg.vocab, seq_len=s)
    d = gen.batch(seed=0, step=step, shard=0, n_shards=1, batch_size=b)
    return {k: jnp.asarray(v) for k, v in d.items()}


# ------------------------------------------------------------------ train
def test_loss_decreases():
    cfg = tiny_cfg()
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=2,
                                             total_steps=60))
    fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg=cfg, tcfg=tcfg))
    losses = []
    for step in range(40):
        params, opt, m = fn(params, opt, _batch(cfg, step))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatch_matches_full_batch_grads():
    cfg = tiny_cfg()
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    b = _batch(cfg, b=8)
    full = train_step(params, opt, b, cfg=cfg,
                      tcfg=TrainConfig())
    micro = train_step(params, opt, b, cfg=cfg,
                       tcfg=TrainConfig(microbatch=2))
    for a, c in zip(jax.tree_util.tree_leaves(full[0]),
                    jax.tree_util.tree_leaves(micro[0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_loss_fn_matches_naive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((2, 4, 16)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 16, (2, 4)), jnp.int32)
    got = loss_fn(logits, labels)
    probs = jax.nn.log_softmax(logits, -1)
    want = -jnp.take_along_axis(probs, labels[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0            # warmup rises
    assert lrs[99] == pytest.approx(0.1, rel=0.05)   # decays to min ratio
    assert max(lrs) <= 1.0 + 1e-6


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_ratio=1.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.2


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    path = save_checkpoint(str(tmp_path), 7, (params, opt),
                           meta={"arch": "t"})
    assert os.path.exists(os.path.join(path, "arrays.npz.zst"))
    (p2, o2), step, meta = load_checkpoint(str(tmp_path), (params, opt))
    assert step == 7 and meta["arch"] == "t"
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_async_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.arange(5)}
    for s in (1, 2, 3):
        mgr.save_async(s, tree, meta={})
        mgr.wait()
    assert mgr.latest_step() == 3
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_2", "step_3"]     # retention pruned step_1


def test_restart_resumes_step_exact(tmp_path):
    """Train 10 steps w/ checkpoints, kill, resume at 5: states identical
    to an uninterrupted run (data pipeline replays the same stream)."""
    cfg = tiny_cfg()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=20))
    fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg=cfg, tcfg=tcfg))

    def run(start, stop, params, opt):
        for step in range(start, stop):
            params, opt, _ = fn(params, opt, _batch(cfg, step))
        return params, opt

    p0, o0 = init_params(cfg, 0), adamw_init(init_params(cfg, 0))
    pa, oa = run(0, 10, p0, o0)
    # interrupted: save at 5, reload, continue
    pb, ob = run(0, 5, p0, o0)
    save_checkpoint(str(tmp_path), 5, (pb, ob))
    (pr, orr), step, _ = load_checkpoint(str(tmp_path), (pb, ob))
    pr = jax.tree_util.tree_map(jnp.asarray, pr)
    orr = jax.tree_util.tree_map(jnp.asarray, orr)
    pc, oc = run(step, 10, pr, orr)
    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


# ---------------------------------------------------------------- runtime
def test_heartbeat_detects_dead_node():
    cfg = FaultToleranceConfig(heartbeat_interval_s=5.0)
    mon = HeartbeatMonitor(["n0", "n1"], cfg, now_s=0.0)
    t = 0.0
    for _ in range(20):
        t += 5.0
        mon.heartbeat("n0", t)
        mon.heartbeat("n1", t)
    # n1 goes silent
    for _ in range(20):
        t += 5.0
        mon.heartbeat("n0", t)
    assert mon.state("n0", t) == NodeState.HEALTHY
    assert mon.state("n1", t) == NodeState.DEAD
    assert mon.dead_nodes(t) == ["n1"]


def test_restart_policy_prefers_spares_then_shrinks():
    cfg = FaultToleranceConfig()
    pol = RestartPolicy(cfg, spares_available=1)
    assert pol.on_failure(["n1"], 10.0) == RestartAction.RESTART_IN_PLACE
    assert pol.on_failure(["n2"], 20.0) == RestartAction.ELASTIC_SHRINK


def test_restart_budget_aborts():
    cfg = FaultToleranceConfig(max_restarts_per_hour=2)
    pol = RestartPolicy(cfg, spares_available=10)
    assert pol.on_failure(["a"], 1.0) != RestartAction.ABORT
    assert pol.on_failure(["b"], 2.0) != RestartAction.ABORT
    assert pol.on_failure(["c"], 3.0) == RestartAction.ABORT


def test_straggler_rebalances_then_evicts():
    mit = StragglerMitigator(4, StragglerConfig(persistent_misses=3))
    # worker 3 is consistently 10x slower
    actions = {}
    for _ in range(6):
        actions = mit.record_step({0: 1.0, 1: 1.01, 2: 0.99, 3: 10.0})
    assert actions[3] == "evict"
    shares = mit.batch_shares()
    assert shares[3] < shares[0]
    assert sum(shares.values()) == pytest.approx(4.0)


def test_elastic_planner_shapes():
    pl = ElasticPlanner(ElasticConfig(model_axis=16,
                                      target_global_batch=256))
    full = pl.plan(512)
    assert full.mesh_shape == (2, 16, 16)
    shrunk = pl.plan(256)
    assert shrunk.mesh_shape == (16, 16)
    odd = pl.plan(272)          # 17 slices -> (17,16) data x model
    assert odd.mesh_shape == (17, 16)
    assert odd.global_batch % 17 == 0 or odd.grad_accum >= 1
    with pytest.raises(ValueError):
        pl.plan(16)


# ------------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_sharded():
    gen = SyntheticLM(vocab=100, seq_len=32)
    a = gen.batch(seed=1, step=3, shard=0, n_shards=4, batch_size=4)
    b = gen.batch(seed=1, step=3, shard=0, n_shards=4, batch_size=4)
    c = gen.batch(seed=1, step=3, shard=1, n_shards=4, batch_size=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_pipeline_prefetch_and_resume():
    cfg = get_smoke_config("qwen2-1.5b")
    shape = InputShape("t", 32, 4, "train")
    pipe = DataPipeline(cfg, shape, PipelineConfig(seed=0)).start(
        from_step=5)
    b1 = pipe.next()
    b2 = pipe.next()
    pipe.stop()
    assert b1["_step"] == 5 and b2["_step"] == 6
    direct = make_batch(cfg, shape, seed=0, step=5)
    np.testing.assert_array_equal(b1["tokens"], direct["tokens"])
