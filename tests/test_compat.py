"""repro.compat — version detection + shim dispatch on BOTH jax branches.

Two matrices:

  * the real installed jax (0.4.37 in the container): the legacy
    fallbacks must actually work — build meshes, activate them, run a
    shard_map collective;
  * a monkeypatched jax>=0.7 surface: the shims must route to the
    modern APIs with the translated kwargs (axis_types, check_vma),
    proving the same call sites stay correct when the container's jax
    is upgraded, without needing that jax installed.

Dispatch is read from `repro.compat.version.HAS_*` at call time, which
is what makes the monkeypatched matrix possible.
"""

import contextlib
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import version as compat_version


# --------------------------------------------------------------------------
# Version parsing / guard.
# --------------------------------------------------------------------------
def test_parse_version():
    assert compat.parse_version("0.4.37") == (0, 4, 37)
    assert compat.parse_version("0.7.0.dev20250101") == (0, 7, 0)
    assert compat.parse_version("0.7") == (0, 7, 0)
    assert compat.parse_version("1.2rc1") == (1, 2, 0)


def test_jax_version_at_least_matches_installed():
    assert compat.JAX_VERSION == compat.parse_version(jax.__version__)
    assert compat.jax_version_at_least("0.4")
    assert compat.jax_version_at_least(*compat.JAX_VERSION)
    assert not compat.jax_version_at_least("99.0")
    # string and int spellings agree
    assert compat.jax_version_at_least("0.7") == \
        compat.jax_version_at_least(0, 7)


def test_describe_reports_flags():
    d = compat.describe()
    assert d["jax"] == jax.__version__
    for key in ("set_mesh", "axis_type", "get_abstract_mesh",
                "toplevel_shard_map"):
        assert isinstance(d[key], bool)


# --------------------------------------------------------------------------
# Real-jax branch (whatever is installed; 0.4.37 in the container).
# --------------------------------------------------------------------------
def test_make_mesh_and_set_mesh_roundtrip():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert compat.abstract_axis_sizes() == {}          # outside set_mesh
    with compat.set_mesh(mesh) as active:
        assert active is mesh
        assert compat.abstract_axis_sizes() == {"data": 1, "model": 1}
        am = compat.get_abstract_mesh()
        assert tuple(am.axis_names) == ("data", "model")
    assert compat.abstract_axis_sizes() == {}


def test_axis_types_matches_capability():
    types_ = compat.axis_types(3)
    if compat_version.HAS_AXIS_TYPE:
        assert len(types_) == 3
    else:
        assert types_ is None


def test_shard_map_runs_collective():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "model"), mesh=mesh,
                         in_specs=P(), out_specs=P(), check_vma=False)
    np.testing.assert_allclose(np.asarray(f(jnp.ones((4,)))), 1.0)


# --------------------------------------------------------------------------
# Mocked jax>=0.7 branch: dispatch + kwarg translation.
# --------------------------------------------------------------------------
def test_set_mesh_routes_to_modern_api(monkeypatch):
    entered = []

    @contextlib.contextmanager
    def fake_set_mesh(mesh):
        entered.append(mesh)
        yield mesh

    monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
    monkeypatch.setattr(compat_version, "HAS_SET_MESH", True)
    sentinel = object()
    with compat.set_mesh(sentinel) as m:
        assert m is sentinel
    assert entered == [sentinel]


def test_make_mesh_passes_auto_axis_types(monkeypatch):
    seen = {}

    def fake_make_mesh(shapes, names, **kw):
        seen["args"] = (shapes, names, kw)
        return "mesh"

    monkeypatch.setattr(jax.sharding, "AxisType",
                        types.SimpleNamespace(Auto="AUTO"), raising=False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    monkeypatch.setattr(compat_version, "HAS_AXIS_TYPE", True)
    assert compat.make_mesh((2, 2), ("data", "model")) == "mesh"
    shapes, names, kw = seen["args"]
    assert shapes == (2, 2) and names == ("data", "model")
    assert kw["axis_types"] == ("AUTO", "AUTO")


def test_get_abstract_mesh_routes_to_modern_api(monkeypatch):
    fake = types.SimpleNamespace(axis_names=("data", "model"),
                                 shape={"data": 4, "model": 2})
    monkeypatch.setattr(jax.sharding, "get_abstract_mesh", lambda: fake,
                        raising=False)
    monkeypatch.setattr(compat_version, "HAS_GET_ABSTRACT_MESH", True)
    assert compat.get_abstract_mesh() is fake
    assert compat.abstract_axis_sizes() == {"data": 4, "model": 2}


def test_shard_map_modern_branch_uses_check_vma(monkeypatch):
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma):
        seen.update(mesh=mesh, check_vma=check_vma)
        return "modern"

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    monkeypatch.setattr(compat_version, "HAS_TOPLEVEL_SHARD_MAP", True)
    out = compat.shard_map(lambda x: x, mesh="m", in_specs=P(),
                           out_specs=P(), check_vma=False)
    assert out == "modern"
    assert seen == {"mesh": "m", "check_vma": False}


def test_shard_map_legacy_branch_translates_to_check_rep(monkeypatch):
    import jax.experimental.shard_map as esm
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_rep):
        seen.update(mesh=mesh, check_rep=check_rep)
        return "legacy"

    monkeypatch.setattr(esm, "shard_map", fake_shard_map)
    monkeypatch.setattr(compat_version, "HAS_TOPLEVEL_SHARD_MAP", False)
    out = compat.shard_map(lambda x: x, mesh="m", in_specs=P(),
                           out_specs=P(), check_vma=False)
    assert out == "legacy"
    assert seen == {"mesh": "m", "check_rep": False}


# --------------------------------------------------------------------------
# cost_analysis drift (list-of-dicts on 0.4.x, dict on >=0.7).
# --------------------------------------------------------------------------
@pytest.mark.parametrize("raw,expected", [
    ([{"flops": 7.0}], {"flops": 7.0}),        # 0.4.x list shape
    ({"flops": 7.0}, {"flops": 7.0}),          # >=0.7 dict shape
    ([], {}),
    (None, {}),
])
def test_cost_analysis_normalizes_both_shapes(raw, expected):
    compiled = types.SimpleNamespace(cost_analysis=lambda: raw)
    assert compat.cost_analysis(compiled) == expected


# --------------------------------------------------------------------------
# jit_compiled donation drift (donate_argnums unsupported on ancient jit
# signatures -> silently degrade to a plain jit).
# --------------------------------------------------------------------------
def test_jit_compiled_with_donation_runs():
    fn = compat.jit_compiled(lambda x: x * 2.0, donate_argnums=(0,))
    out = fn(jnp.ones(8, jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_jit_compiled_degrades_when_donation_unsupported(monkeypatch):
    calls = []

    def fake_jit(fun, **kw):
        calls.append(dict(kw))
        if "donate_argnums" in kw:      # pre-donation jit signature
            raise TypeError("unexpected keyword argument 'donate_argnums'")
        return fun
    monkeypatch.setattr(jax, "jit", fake_jit)
    fn = compat.jit_compiled(lambda x: x + 1, donate_argnums=(0,),
                             static_argnames=("n",))
    assert fn(1) == 2                   # plain-jit fallback still runs
    assert "donate_argnums" in calls[0]          # tried the modern path
    assert "donate_argnums" not in calls[-1]     # retried without
    assert calls[-1]["static_argnames"] == ("n",)


def test_jit_compiled_without_donation_skips_probe(monkeypatch):
    calls = []

    def fake_jit(fun, **kw):
        calls.append(dict(kw))
        return fun
    monkeypatch.setattr(jax, "jit", fake_jit)
    compat.jit_compiled(lambda x: x)
    assert calls == [{}]


# --------------------------------------------------------------------------
# TPU detection + the pallas_kernel knob's tri-state resolution.
# --------------------------------------------------------------------------
def test_on_tpu_matches_default_backend():
    assert compat.on_tpu() == (jax.default_backend() == "tpu")


def test_on_tpu_false_when_jax_unusable(monkeypatch):
    import repro.compat.runtime as rt
    monkeypatch.setattr(rt, "_JAX_OK", False)
    assert compat.on_tpu() is False


def test_resolve_pallas_kernel_auto_follows_tpu(monkeypatch):
    import repro.compat.runtime as rt
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    monkeypatch.setattr(rt, "_JAX_OK", True)
    monkeypatch.setattr(rt, "_PALLAS_OK", True)
    assert compat.resolve_pallas_kernel("auto") is True
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert compat.resolve_pallas_kernel("auto") is False


def test_resolve_pallas_kernel_forced_ignores_hardware(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert compat.resolve_pallas_kernel("on") is True
    assert compat.resolve_pallas_kernel("off") is False
    with pytest.raises(ValueError):
        compat.resolve_pallas_kernel("banana")
