"""Per-arch reduced-config smoke tests (deliverable f): one forward/train
step on CPU asserting output shapes + no NaNs, plus serve consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (decode_step, init_params, make_decode_state,
                          prefill, train_forward)
from repro.models.common import Family, param_count
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, train_step

B, S = 2, 16


def _batch(cfg, with_labels=True):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab, (B, S)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(1, cfg.vocab, (B, S)), jnp.int32)
    if cfg.family == Family.ENCDEC:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_frames, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.family == Family.VLM:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.img_tokens, cfg.d_model)) * 0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, 0)
    assert param_count(params) > 0
    logits, aux = train_forward(params, _batch(cfg, False), cfg)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, 0)
    opt = adamw_init(params)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=10))
    new_params, new_opt, metrics = train_step(
        params, opt, _batch(cfg), cfg=cfg, tcfg=tcfg)
    assert float(metrics["loss"]) > 0
    assert not np.isnan(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, 0)
    batch = _batch(cfg, False)
    extra = cfg.img_tokens if cfg.family == Family.VLM else 0
    state = make_decode_state(cfg, B, max_len=S + extra + 4)
    logits, aux = train_forward(params, batch, cfg)
    lg, state = prefill(params, batch, cfg, state)
    assert lg.shape == (B, 1, cfg.vocab_padded)
    # prefill's last-token logits agree with the training forward
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(logits[:, -1], np.float32), rtol=3e-2, atol=3e-2)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(2):
        lg, state = decode_step(params, tok, cfg, state)
        assert not bool(jnp.isnan(lg).any())
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_configs_match_assignment(arch):
    """The FULL configs carry the exact assigned dims (never instantiated
    here — dims only; the dry-run exercises them via ShapeDtypeStruct)."""
    cfg = get_config(arch)
    expected = {
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "granite-moe-3b-a800m":
        assert (cfg.n_experts, cfg.top_k) == (40, 8)
    if arch == "qwen2-moe-a2.7b":
        assert (cfg.n_experts, cfg.top_k, cfg.n_shared_experts) == (60, 4, 4)
    if arch == "zamba2-7b":
        assert cfg.ssm_state == 64 and cfg.supports_long_context
    if arch == "mamba2-130m":
        assert cfg.ssm_state == 128 and cfg.supports_long_context
