"""TPU adaptation: app-aware collective selector, HLO parsing, roofline."""

import numpy as np
import pytest

from repro.analysis.hlo_parse import (CollectiveOp, parse_hlo,
                                      parse_replica_groups, shape_bytes)
from repro.analysis.roofline import (classify_collective,
                                     model_flops_estimate,
                                     param_counts_analytic, roofline_terms)
from repro.collectives.hlo_counters import HloCounterBackend
from repro.collectives.modes import CollectiveMode, mode_for_routing
from repro.collectives.selector import AppAwareSelector, ICICostModel, MeshSpec
from repro.configs import SHAPES, get_config
from repro.core.strategies import RoutingMode


# ----------------------------------------------------------------- parser
def test_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], /*index=1*/s32[4])") == 24
    assert shape_bytes("pred[]") == 1


def test_replica_groups_iota():
    gs, g0 = parse_replica_groups("replica_groups=[2,4]<=[8]")
    assert gs == 4 and g0 == (0, 1, 2, 3)
    gs, g0 = parse_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
    assert gs == 2 and g0 == (0, 4)


def test_replica_groups_explicit():
    gs, g0 = parse_replica_groups("replica_groups={{0,2},{1,3}}")
    assert gs == 2 and g0 == (0, 2)


def test_parse_hlo_trip_count_scaling():
    """While body costs multiply by known_trip_count (the probed XLA
    undercount this module exists to fix)."""
    txt = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%body
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w0 = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w0), index=1
}
"""
    costs = parse_hlo(txt)
    assert costs.n_while == 1 and costs.trip_counts == [5]
    assert costs.flops == pytest.approx(5 * 2 * 8 * 8 * 8)
    assert len(costs.collectives) == 1
    c = costs.collectives[0]
    assert c.multiplier == 5 and c.group_size == 4
    # ring all-reduce: 2*(n-1)/n * payload
    assert c.wire_bytes() == pytest.approx(2 * 3 / 4 * 256)


# --------------------------------------------------------------- roofline
def test_classify_collective_pod_boundary():
    assert classify_collective((0, 1, 2), (2, 16, 16)) == "intra"
    assert classify_collective((0, 256), (2, 16, 16)) == "cross_pod"
    assert classify_collective((0, 1), (16, 16)) == "intra"


def test_param_counts_analytic_close_to_real():
    cfg = get_config("llama3-8b")
    total, active = param_counts_analytic(cfg)
    assert total == active
    assert 7.5e9 < total < 8.6e9     # llama3-8b ~ 8.03B
    moe = get_config("qwen2-moe-a2.7b")
    t, a = param_counts_analytic(moe)
    assert a < t                     # MoE active < total
    assert 12e9 < t < 16e9           # ~14.3B total
    assert 2e9 < a < 4e9             # ~2.7B active


def test_model_flops_train_rule():
    cfg = get_config("llama3-8b")
    sh = SHAPES["train_4k"]
    mf = model_flops_estimate(cfg, sh)
    total, _ = param_counts_analytic(cfg)
    assert mf == pytest.approx(6.0 * total * 256 * 4096)


def test_roofline_dominant_term():
    costs_like = parse_hlo("""
HloModule m, is_scheduled=true
ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  ROOT %d = f32[128,128]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
""")
    rep = roofline_terms(costs_like, arch="x", shape="train_4k",
                         mesh_shape=(16, 16), model_flops=1e15)
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.chips == 256
    assert rep.bound_s == max(rep.compute_s, rep.memory_s,
                              rep.collective_s)


# --------------------------------------------------------------- selector
def test_mode_mapping_table():
    assert mode_for_routing(RoutingMode.ADAPTIVE_3) == CollectiveMode.DIRECT
    assert mode_for_routing(RoutingMode.ADAPTIVE_0) == \
        CollectiveMode.HIERARCHICAL


def test_cost_model_crossover():
    """DIRECT (minimal) wins small messages on latency; HIERARCHICAL
    (spread) wins big messages on slow-link serialization — the paper's
    message-size crossover on the TPU mesh."""
    cm = ICICostModel(MeshSpec(n_pods=2, inner_chips=256))
    small_d = cm.predict(1024, CollectiveMode.DIRECT)
    small_h = cm.predict(1024, CollectiveMode.HIERARCHICAL)
    assert small_d.latency_cycles < small_h.latency_cycles
    big_d = cm.predict(256 << 20, CollectiveMode.DIRECT)
    big_h = cm.predict(256 << 20, CollectiveMode.HIERARCHICAL)
    assert big_h.stall_cycles_per_flit < big_d.stall_cycles_per_flit


def test_selector_switches_by_size():
    sel = AppAwareSelector(ICICostModel(MeshSpec(n_pods=2, inner_chips=256)))
    small = sel.select(2048)
    sel.observe_predicted(2048)
    assert small == CollectiveMode.DIRECT
    for _ in range(4):
        big = sel.select(64 << 20)
        sel.observe_predicted(64 << 20)
    assert big == CollectiveMode.HIERARCHICAL
    assert 0.0 <= sel.traffic_fraction_direct() < 0.5


def test_selector_single_pod_prefers_direct():
    sel = AppAwareSelector(ICICostModel(MeshSpec(n_pods=1, inner_chips=256)))
    for _ in range(4):
        m = sel.select(64 << 20)
        sel.observe_predicted(64 << 20)
    assert m == CollectiveMode.DIRECT   # no slow links to spare


def test_hlo_counter_backend_feeds_algorithm1():
    costs = parse_hlo("""
HloModule m, is_scheduled=true
ENTRY %main (a: f32[1048576]) -> f32[1048576] {
  %a = f32[1048576]{0} parameter(0)
  ROOT %ar = f32[1048576]{0} all-reduce(%a), replica_groups=[1,512]<=[512]
}
""")
    be = HloCounterBackend(mesh_shape=(2, 16, 16))
    be.observe_step(costs, compute_window_s=1e-3)
    c = be.read_counters()
    assert c.request_packets > 0
    assert c.request_packets_cumulative_latency_us > 0
