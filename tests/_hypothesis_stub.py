"""Minimal deterministic fallback for `hypothesis` (used when the real
package is not installed in the container).

Implements just the surface this test suite uses — ``given``,
``strategies.integers/floats/lists`` and the ``settings`` profile API —
with seeded random sampling plus boundary examples, so property tests
still exercise edge values.  The real hypothesis, when present, is always
preferred (see conftest.py).
"""

from __future__ import annotations

import functools
import inspect
import random


class settings:  # noqa: N801 - mirrors hypothesis' lowercase class
    _profiles: dict = {}
    max_examples: int = 25

    def __init__(self, max_examples: int | None = None, deadline=None, **kw):
        self._max = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self._max
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int = 25,
                         deadline=None, **kw) -> None:
        cls._profiles[name] = max_examples

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls.max_examples = cls._profiles.get(name, 25)


class SearchStrategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        #: deterministic edge examples tried before random sampling
        self.boundary = list(boundary)

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


class strategies:  # noqa: N801 - used as `from ... import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda r: r.randint(min_value, max_value),
                              boundary=[min_value, max_value])

    @staticmethod
    def floats(min_value: float, max_value: float, **kw) -> SearchStrategy:
        return SearchStrategy(lambda r: r.uniform(min_value, max_value),
                              boundary=[min_value, max_value])

    @staticmethod
    def lists(elements: SearchStrategy, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elements.draw(r) for _ in range(n)]
        return SearchStrategy(
            draw, boundary=[[b] * max(min_size, 1) for b in elements.boundary])


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(0xD5A607)
            n = getattr(fn, "_stub_max_examples", None) or settings.max_examples
            strats = list(arg_strategies) + list(kw_strategies.values())
            names = list(kw_strategies)
            # boundary pass: every strategy at each of its edge values
            n_edges = max((len(s.boundary) for s in strats), default=0)
            for i in range(n_edges):
                pos, kw = [], {}
                for j, s in enumerate(arg_strategies):
                    b = s.boundary or [s.draw(rnd)]
                    pos.append(b[i % len(b)])
                for name in names:
                    s = kw_strategies[name]
                    b = s.boundary or [s.draw(rnd)]
                    kw[name] = b[i % len(b)]
                fn(*args, *pos, **kwargs, **kw)
            # random pass
            for _ in range(max(n - n_edges, 1)):
                pos = [s.draw(rnd) for s in arg_strategies]
                kw = {name: kw_strategies[name].draw(rnd) for name in names}
                fn(*args, *pos, **kwargs, **kw)
        # pytest must not treat the strategy params as fixtures: hide the
        # original signature (hypothesis does the equivalent internally).
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_stub = True
        return wrapper
    return deco
