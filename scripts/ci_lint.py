"""Dependency-free CI checks.

Default mode: line length + trailing whitespace over the Python tree.
``--docs`` mode (the Makefile `docs` target): README/docs internal-link
integrity + no stray __pycache__/*.pyc tracked in git.
``--bench`` mode (the Makefile `bench-perf` / `bench-interference` /
`bench-faults` targets): BENCH_sim.json exists and parses against its
schema (docs/performance.md); BENCH_interference.json — when present —
matches bench_interference/v1 or /v2 (docs/interference.md; v2 records
the topology per cell); BENCH_faults.json — when present — matches
bench_faults/v1 (docs/faults.md); BENCH_notifications.json — when
present — matches bench_notifications/v1 (docs/policy_api.md).
``--topology`` mode (`make lint` / bench-smoke): instantiates every
registered topology at small scale and runs the structural invariant
battery headlessly (docs/topology.md), including the fault-mask checks
under a seeded fault state (docs/faults.md) — needs numpy + src.
"""

import argparse
import json
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")

# version-drifting jax symbols that must be reached via repro.compat
# (docs/compat.md); the shim package and its tests are the only homes
_BARE_JAX_RE = re.compile(
    r"jax\.set_mesh|jax\.sharding\.AxisType"
    r"|jax\.sharding\.get_abstract_mesh|jax\.shard_map"
    r"|jax\.experimental\.shard_map")
_SHIM_EXEMPT = ("src/repro/compat/", "tests/test_compat.py")


def lint_style() -> list:
    bad = []
    for root in ("src", "benchmarks", "examples"):
        for p in (ROOT / root).rglob("*.py"):
            if "__pycache__" in p.parts:
                continue
            for i, line in enumerate(p.read_text().splitlines(), 1):
                rel = p.relative_to(ROOT)
                if len(line) > 100:
                    bad.append(f"{rel}:{i}: line too long ({len(line)} > 100)")
                if re.search(r"[ \t]+$", line):
                    bad.append(f"{rel}:{i}: trailing whitespace")
    return bad


def lint_docs_links() -> list:
    """Every relative markdown link in README.md / docs/*.md resolves."""
    bad = []
    pages = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for page in pages:
        if not page.exists():
            bad.append(f"{page.relative_to(ROOT)}: missing")
            continue
        for i, line in enumerate(page.read_text().splitlines(), 1):
            for target in _LINK_RE.findall(line):
                if target.startswith(_EXTERNAL) or target.startswith("#"):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                if not (page.parent / path).resolve().exists():
                    bad.append(f"{page.relative_to(ROOT)}:{i}: "
                               f"broken link -> {target}")
    return bad


def lint_bare_jax_calls() -> list:
    """No version-gated jax API used outside the repro.compat shims."""
    bad = []
    for root in ("src", "benchmarks", "examples", "tests", "scripts"):
        for p in (ROOT / root).rglob("*.py"):
            if "__pycache__" in p.parts:
                continue
            rel = p.relative_to(ROOT).as_posix()
            if rel.startswith(_SHIM_EXEMPT[0]) or rel == _SHIM_EXEMPT[1]:
                continue
            for i, line in enumerate(p.read_text().splitlines(), 1):
                m = _BARE_JAX_RE.search(line)
                if m:
                    bad.append(f"{rel}:{i}: bare {m.group(0)} — go through "
                               f"repro.compat (docs/compat.md)")
    return bad


def lint_tracked_pycache() -> list:
    """No __pycache__ dirs or *.pyc files committed to the repo."""
    try:
        out = subprocess.run(["git", "ls-files"], cwd=ROOT, check=True,
                             capture_output=True, text=True).stdout
    except (OSError, subprocess.CalledProcessError):
        return []  # not a git checkout (e.g. sdist) — nothing to check
    return [f"{f}: __pycache__/*.pyc tracked in git (add to .gitignore)"
            for f in out.splitlines()
            if "__pycache__" in f or f.endswith(".pyc")]


#: BENCH_sim.json contract (emitted by benchmarks/perf_sim.py): top-level
#: fields -> type, and per-backend numeric fields
_BENCH_SCHEMA_TOP = {"schema": str, "flows": int, "phases_timed": int,
                     "topology": dict, "seed_exact": bool,
                     "backends": dict, "speedup": dict}
_BENCH_BACKEND_FIELDS = ("phase_s", "phases_per_s", "flows_per_s")


def lint_bench_schema(require: bool = False) -> list:
    """BENCH_sim.json parses and matches bench_sim/v1 or /v2.

    v2 (benchmarks/perf_sim.py since the device-resident engine) adds a
    required numeric ``compile_s`` per backend — the one-time first-call
    cost split out of ``phase_s`` — and requires non-empty ``stages_s``
    for jax* backends (an empty dict there means the jitted pipeline
    silently fell back / never profiled)."""
    path = ROOT / "BENCH_sim.json"
    if not path.exists():
        return ["BENCH_sim.json: missing (run `make bench-perf`)"] \
            if require else []
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"BENCH_sim.json: unparseable ({e})"]
    bad = []
    for key, typ in _BENCH_SCHEMA_TOP.items():
        if key not in doc:
            bad.append(f"BENCH_sim.json: missing key {key!r}")
        elif not isinstance(doc[key], typ):
            bad.append(f"BENCH_sim.json: {key!r} should be {typ.__name__}")
    schema = doc.get("schema")
    if schema not in (None, "bench_sim/v1", "bench_sim/v2"):
        bad.append(f"BENCH_sim.json: unknown schema {schema!r}")
    v2 = schema == "bench_sim/v2"
    fields = _BENCH_BACKEND_FIELDS + (("compile_s",) if v2 else ())
    for name, entry in (doc.get("backends") or {}).items():
        for f in fields:
            if not isinstance(entry.get(f), (int, float)):
                bad.append(f"BENCH_sim.json: backends.{name}.{f} "
                           f"missing or non-numeric")
        stages = entry.get("stages_s", {})
        if not isinstance(stages, dict):
            bad.append(f"BENCH_sim.json: backends.{name}.stages_s "
                       f"should be a dict")
        elif v2 and name.startswith("jax") and not stages:
            bad.append(f"BENCH_sim.json: backends.{name}.stages_s empty "
                       f"(jax arm must record stage timings)")
    for name, v in (doc.get("speedup") or {}).items():
        if not isinstance(v, (int, float)):
            bad.append(f"BENCH_sim.json: speedup.{name} non-numeric")
    return bad


#: BENCH_interference.json contract (benchmarks/interference_matrix.py):
#: top-level fields -> type, and per-cell numeric fields
_BENCH_INT_SCHEMA_TOP = {"schema": str, "rounds": int, "seed": int,
                         "topology": dict, "mixes": list, "policies": list,
                         "matrix": dict, "checks": dict}
_BENCH_INT_CELL_FIELDS = ("victim_slowdown", "victim_time_us",
                          "victim_alone_us", "victim_nonmin_fraction")


def lint_bench_interference_schema(require: bool = False) -> list:
    """BENCH_interference.json parses and matches bench_interference/v1."""
    path = ROOT / "BENCH_interference.json"
    if not path.exists():
        return ["BENCH_interference.json: missing "
                "(run `make bench-interference`)"] if require else []
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"BENCH_interference.json: unparseable ({e})"]
    bad = []
    for key, typ in _BENCH_INT_SCHEMA_TOP.items():
        if key not in doc:
            bad.append(f"BENCH_interference.json: missing key {key!r}")
        elif not isinstance(doc[key], typ):
            bad.append(f"BENCH_interference.json: {key!r} should be "
                       f"{typ.__name__}")
    schema = doc.get("schema")
    if schema not in (None, "bench_interference/v1",
                      "bench_interference/v2"):
        bad.append(f"BENCH_interference.json: unknown schema {schema!r}")
    # v2: every cell must say which topology it ran on
    want_topology = schema == "bench_interference/v2"
    for mix, row in (doc.get("matrix") or {}).items():
        for policy in (doc.get("policies") or list(row)):
            cell = row.get(policy)
            if not isinstance(cell, dict):
                bad.append(f"BENCH_interference.json: matrix.{mix} missing "
                           f"policy {policy!r}")
                continue
            for f in _BENCH_INT_CELL_FIELDS:
                if not isinstance(cell.get(f), (int, float)):
                    bad.append(f"BENCH_interference.json: matrix.{mix}."
                               f"{policy}.{f} missing or non-numeric")
            if want_topology and not isinstance(cell.get("topology"), str):
                bad.append(f"BENCH_interference.json: matrix.{mix}."
                           f"{policy}.topology missing or not a string "
                           f"(required by {schema})")
            if not isinstance(cell.get("aggressor_slowdowns", {}), dict):
                bad.append(f"BENCH_interference.json: matrix.{mix}."
                           f"{policy}.aggressor_slowdowns should be a dict")
    return bad


#: BENCH_faults.json contract (benchmarks/fault_matrix.py): top-level
#: fields -> type, and per-cell numeric fields (docs/faults.md)
_BENCH_FAULTS_SCHEMA_TOP = {"schema": str, "rounds": int, "seed": int,
                            "topologies": list, "scenarios": dict,
                            "policies": list, "matrix": dict,
                            "checks": dict}
_BENCH_FAULTS_CELL_FIELDS = ("victim_slowdown", "victim_time_us",
                             "victim_alone_us", "victim_recovery_rounds",
                             "victim_recovery_time_us", "stranded_flows")


def lint_bench_faults_schema(require: bool = False) -> list:
    """BENCH_faults.json parses and matches bench_faults/v1."""
    path = ROOT / "BENCH_faults.json"
    if not path.exists():
        return ["BENCH_faults.json: missing (run `make bench-faults`)"] \
            if require else []
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"BENCH_faults.json: unparseable ({e})"]
    bad = []
    for key, typ in _BENCH_FAULTS_SCHEMA_TOP.items():
        if key not in doc:
            bad.append(f"BENCH_faults.json: missing key {key!r}")
        elif not isinstance(doc[key], typ):
            bad.append(f"BENCH_faults.json: {key!r} should be "
                       f"{typ.__name__}")
    if doc.get("schema") not in (None, "bench_faults/v1"):
        bad.append(f"BENCH_faults.json: unknown schema "
                   f"{doc.get('schema')!r}")
    for cellkey, row in (doc.get("matrix") or {}).items():
        for policy in (doc.get("policies") or list(row)):
            cell = row.get(policy)
            if not isinstance(cell, dict):
                bad.append(f"BENCH_faults.json: matrix.{cellkey} missing "
                           f"policy {policy!r}")
                continue
            for f in _BENCH_FAULTS_CELL_FIELDS:
                if not isinstance(cell.get(f), (int, float)):
                    bad.append(f"BENCH_faults.json: matrix.{cellkey}."
                               f"{policy}.{f} missing or non-numeric")
            if not isinstance(cell.get("topology"), str):
                bad.append(f"BENCH_faults.json: matrix.{cellkey}."
                           f"{policy}.topology missing or not a string")
            if not isinstance(cell.get("scenario"), str):
                bad.append(f"BENCH_faults.json: matrix.{cellkey}."
                           f"{policy}.scenario missing or not a string")
            if not isinstance(cell.get("tenant_recovery", {}), dict):
                bad.append(f"BENCH_faults.json: matrix.{cellkey}."
                           f"{policy}.tenant_recovery should be a dict")
    return bad


#: BENCH_notifications.json contract (benchmarks/notification_matrix.py):
#: top-level fields -> type, per-tenancy-cell and per-workload-arm
#: numeric fields (docs/policy_api.md)
_BENCH_NOTIF_SCHEMA_TOP = {"schema": str, "rounds": int, "seed": int,
                           "topology": str, "notify_params": dict,
                           "policies": list, "workloads": dict,
                           "matrix": dict, "checks": dict}
_BENCH_NOTIF_CELL_FIELDS = ("victim_slowdown", "victim_time_us",
                            "victim_alone_us", "notification_events")


def lint_bench_notifications_schema(require: bool = False) -> list:
    """BENCH_notifications.json parses, matches bench_notifications/v1."""
    path = ROOT / "BENCH_notifications.json"
    if not path.exists():
        return ["BENCH_notifications.json: missing "
                "(run `make bench-notifications`)"] if require else []
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"BENCH_notifications.json: unparseable ({e})"]
    bad = []
    for key, typ in _BENCH_NOTIF_SCHEMA_TOP.items():
        if key not in doc:
            bad.append(f"BENCH_notifications.json: missing key {key!r}")
        elif not isinstance(doc[key], typ):
            bad.append(f"BENCH_notifications.json: {key!r} should be "
                       f"{typ.__name__}")
    if doc.get("schema") not in (None, "bench_notifications/v1"):
        bad.append(f"BENCH_notifications.json: unknown schema "
                   f"{doc.get('schema')!r}")
    for mix, row in (doc.get("matrix") or {}).items():
        for policy in (doc.get("policies") or list(row)):
            cell = row.get(policy)
            if not isinstance(cell, dict):
                bad.append(f"BENCH_notifications.json: matrix.{mix} "
                           f"missing policy {policy!r}")
                continue
            for f in _BENCH_NOTIF_CELL_FIELDS:
                if not isinstance(cell.get(f), (int, float)):
                    bad.append(f"BENCH_notifications.json: matrix.{mix}."
                               f"{policy}.{f} missing or non-numeric")
    for name, cell in (doc.get("workloads") or {}).items():
        arms = cell.get("arms") if isinstance(cell, dict) else None
        if not isinstance(arms, dict):
            bad.append(f"BENCH_notifications.json: workloads.{name}.arms "
                       f"should be a dict")
            continue
        for policy in (doc.get("policies") or list(arms)):
            arm = arms.get(policy)
            if not isinstance(arm, dict) \
                    or not isinstance(arm.get("median_us"), (int, float)):
                bad.append(f"BENCH_notifications.json: workloads.{name}."
                           f"arms.{policy}.median_us missing or "
                           f"non-numeric")
    checks = doc.get("checks") or {}
    if not isinstance(checks.get("wins_with_events_cells", []), list):
        bad.append("BENCH_notifications.json: checks."
                   "wins_with_events_cells should be a list")
    return bad


def lint_topology_invariants() -> list:
    """Every registered topology passes the invariant battery at its
    small scale (repro.dragonfly.invariants.check_all), plus the
    fault-mask battery under a deterministic seeded fault state
    (docs/faults.md)."""
    sys.path.insert(0, str(ROOT / "src"))
    try:
        import numpy as np

        from repro.dragonfly.invariants import (InvariantViolation,
                                                check_all,
                                                check_capacity_scale,
                                                check_fault_mask,
                                                sample_pairs)
        from repro.dragonfly.topology import (registered_topologies,
                                              small_topology)
        from repro.faults import (FaultSchedule, link_degrade, link_down,
                                  router_down)
    except ImportError as e:
        return [f"--topology: cannot import repro.dragonfly ({e})"]
    bad = []
    for name in registered_topologies():
        try:
            topo = small_topology(name)
            check_all(topo, n_pairs=128)
            # deterministic fault state: 2 random global links down, one
            # more degraded, router 0 down — then the mask battery
            sched = FaultSchedule.of(
                link_down(n_random=2, seed=11),
                link_degrade(0.25, n_random=1, seed=12),
                router_down([0])).bind(topo)
            state = sched.state_at(0)
            check_capacity_scale(topo, state)
            src, dst = sample_pairs(topo, n=64, seed=2)
            check_fault_mask(topo, state.dead, src, dst,
                             rng=np.random.default_rng(8))
            check_fault_mask(topo, np.zeros(topo.n_links, dtype=bool),
                             src, dst, rng=np.random.default_rng(8))
        except InvariantViolation as e:
            bad.append(f"topology {name!r}: {e}")
        except Exception as e:  # construction/battery crash
            bad.append(f"topology {name!r}: {type(e).__name__}: {e}")
        else:
            print(f"# topology {name}: ok ({topo.spec_str()})",
                  file=sys.stderr)
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", action="store_true",
                    help="check README/docs links, tracked __pycache__, "
                         "and bare version-gated jax calls instead of "
                         "Python style")
    ap.add_argument("--bench", action="store_true",
                    help="require BENCH_sim.json and check its schema")
    ap.add_argument("--topology", action="store_true",
                    help="run the topology-family invariant battery on "
                         "every registered topology at small scale")
    args = ap.parse_args(argv)
    if args.topology:
        bad = lint_topology_invariants()
    elif args.bench:
        bad = (lint_bench_schema(require=True)
               + lint_bench_interference_schema()
               + lint_bench_faults_schema()
               + lint_bench_notifications_schema())
    elif args.docs:
        bad = (lint_docs_links() + lint_tracked_pycache()
               + lint_bare_jax_calls() + lint_bench_schema()
               + lint_bench_interference_schema()
               + lint_bench_faults_schema()
               + lint_bench_notifications_schema())
    else:
        bad = lint_style()
    print("\n".join(bad))
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
