"""Dependency-free lint: line length + trailing whitespace over src/."""

import pathlib
import re
import sys

bad = []
for root in ("src", "benchmarks", "examples"):
    for p in pathlib.Path(root).rglob("*.py"):
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if len(line) > 100:
                bad.append(f"{p}:{i}: line too long ({len(line)} > 100)")
            if re.search(r"[ \t]+$", line):
                bad.append(f"{p}:{i}: trailing whitespace")
print("\n".join(bad))
sys.exit(1 if bad else 0)
