"""Render the EXPERIMENTS.md §Roofline table from dryrun_final.jsonl."""
import json
import sys

rows = [json.loads(l) for l in open("reports/dryrun_final.jsonl")]
mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
ok = [r for r in rows if r["status"] == "ok" and r["mesh"] == mesh]
skip = [r for r in rows if r["status"] == "skipped" and r["mesh"] == mesh]
hdr = ("| arch | shape | mem GB | compute ms | memory ms | coll ms | "
       "dominant | useful | roofline frac |")
sep = "|---|---|---|---|---|---|---|---|---|"
print(hdr)
print(sep)
order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
for r in sorted(ok, key=lambda r: (order[r["shape"]], r["arch"])):
    print(f"| {r['arch']} | {r['shape']} | {r['mem_total_gb']:.1f} | "
          f"{r['compute_ms']:.1f} | {r['memory_ms']:.1f} | "
          f"{r['collective_ms']:.1f} | {r['dominant']} | "
          f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} |")
for r in sorted(skip, key=lambda r: r["arch"]):
    print(f"| {r['arch']} | {r['shape']} | — | — | — | — | documented skip "
          f"(full attention) | — | — |")
