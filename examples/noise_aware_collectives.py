"""The paper's contribution end-to-end (deliverable b, scenario example),
driven through the unified repro.policy API.

1. Dragonfly substrate: one PolicyEngine per strategy arm — Algorithm 1
   ("app_aware") and the ε-greedy bandit baseline — picks per-flow
   routing modes on a simulated Aries system with ONE vectorized
   decide() per phase (the Fig. 8 protocol, reduced).
2. TPU substrate: the SAME Policy class arbitrates DIRECT vs
   HIERARCHICAL collective schedules on a 2-pod mesh cost model, and
   reports DCN bytes saved for a llama3-8b gradient reduce — batched:
   one engine call decides every bucket.

    python examples/noise_aware_collectives.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.collectives.modes import CollectiveMode
from repro.collectives.selector import AppAwareSelector, ICICostModel, MeshSpec
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, DragonflyTopology, SimParams, TopologyParams
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import run_benchmark

# ---- 1: Dragonfly (faithful reproduction substrate) ----------------------
topo = DragonflyTopology(TopologyParams(n_groups=12))
alloc = make_allocation(topo, 128, spread="groups:6", seed=0)
print("== Dragonfly: alltoall sweep, 128 ranks over 6 groups ==")
for size in (1024, 65536):
    sim = DragonflySimulator(topo, SimParams(seed=0, max_flows=30000))
    res = run_benchmark(sim, alloc, "alltoall", dict(size_per_pair=size),
                        iterations=4,
                        modes=(RoutingMode.ADAPTIVE_0,
                               RoutingMode.ADAPTIVE_3,
                               "app_aware", "eps_greedy"),
                        use_plans=True)   # alltoall rounds share one plan
    meds = {}
    for mode, rs in res.items():
        label = mode.value if isinstance(mode, RoutingMode) else mode
        meds[label] = np.median([r.time_us for r in rs])
    base = meds["ADAPTIVE_0"]
    row = "  ".join(f"{k}={v / base:5.2f}x" for k, v in meds.items())
    print(f"  {size:>7}B/pair: {row}")

# ---- 2: TPU pods (framework integration) ---------------------------------
print("\n== TPU 2x16x16: Algorithm 1 over collective schedules ==")
sel = AppAwareSelector(ICICostModel(MeshSpec(n_pods=2, inner_chips=256)))
for size in (4 << 10, 1 << 20, 32 << 20, 512 << 20):
    m = sel.select(size)
    sel.observe_predicted(size)
    print(f"  {size / 2**20:8.2f} MiB -> {m.value}")

mesh = MeshSpec(n_pods=2, inner_chips=256)
bucket, grads = 32 << 20, 16 << 30  # llama3-8b bf16 grads
n, p, i = mesh.total, mesh.n_pods, mesh.inner_chips
direct = 2 * (n - 1) / n * grads
aware = 0.0
# one engine call per training step, deciding all of the step's buckets
buckets_per_step = 16
n_steps = (grads // bucket) // buckets_per_step
for _ in range(n_steps):
    step_sizes = [bucket] * buckets_per_step
    modes = sel.decide_batch(step_sizes, site="grad_step")
    sel.update_predicted(step_sizes)     # dry-run telemetry, one batch
    aware += sum(2 * (p - 1) / p * bucket / i
                 if m is CollectiveMode.HIERARCHICAL
                 else 2 * (n - 1) / n * bucket for m in modes)
print(f"\n  grad-reduce DCN bytes: direct={direct / 2**30:.1f} GiB, "
      f"app-aware={aware / 2**30:.2f} GiB "
      f"({100 * (1 - aware / direct):.1f}% saved)")
print(f"  engine: {sel.engine.decide_calls} decide() calls for "
      f"{sel.engine.rows_decided} decisions; "
      f"{sel.engine.gated_fraction() * 100:.1f}% of bytes gate-forced")
