"""Batched serving example (deliverable b): prefill + decode with the
family-uniform engine; works for every --arch including enc-dec and VLM
(stub frontends).

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-7b
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    a, _ = ap.parse_known_args()
    serve_main(["--arch", a.arch, "--smoke", "--requests", "4",
                "--prompt-len", "12", "--new-tokens", "12"])
