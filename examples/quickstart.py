"""Quickstart — the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. build a reduced config, 2. train a few steps on synthetic data,
3. serve a batch of generations, 4. run Algorithm 1 on both substrates
(Dragonfly routing modes + TPU collective schedules)."""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, DragonflyTopology, SimParams, TopologyParams
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import run_benchmark
from repro.launch.train import train_loop
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine

# --- 1+2: train a reduced qwen2 on synthetic data ------------------------
cfg = get_smoke_config("qwen2-1.5b")
params, _, losses = train_loop(cfg, steps=30, batch=8, seq=64, seed=0,
                               ckpt_dir=None, ckpt_every=0, lr=3e-3)
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

# --- 3: serve ------------------------------------------------------------
engine = ServeEngine(cfg, params, ServeConfig(batch=4, max_len=48))
reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=8) for _ in range(4)]
for r in engine.run(reqs):
    print("generated:", r.out_tokens)

# --- 4: the paper's technique -------------------------------------------
topo = DragonflyTopology(TopologyParams(n_groups=8))
sim = DragonflySimulator(topo, SimParams(seed=0))
alloc = make_allocation(topo, 32, spread="groups:4", seed=0)
res = run_benchmark(sim, alloc, "alltoall", dict(size_per_pair=32768),
                    iterations=4, use_plans=True)
for mode, rs in res.items():
    label = mode.value if isinstance(mode, RoutingMode) else mode
    print(f"alltoall 32KiB x 32 ranks [{label:12s}] "
          f"median {np.median([r.time_us for r in rs]):9.1f} us")
