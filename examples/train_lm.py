"""End-to-end training driver (deliverable b).

Default: a ~15M-param dense LM for 200 steps on synthetic data with
checkpointing — sized for this CPU container.  --arch/--full select any of
the 10 assigned architectures (e.g. the true 130M mamba2):

    PYTHONPATH=src python examples/train_lm.py                  # ~15M dense
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --full \
        --steps 300                                             # real 130M
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.train import train_loop
from repro.models.common import Family, ModelConfig


def default_cfg():
    return ModelConfig(name="demo-15m", family=Family.DENSE, n_layers=6,
                       d_model=384, n_heads=6, n_kv_heads=2, d_ff=1024,
                       vocab=8192, tie_embeddings=True, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    if args.arch:
        cfg = get_config(args.arch) if args.full \
            else get_smoke_config(args.arch)
    else:
        cfg = default_cfg()
    _, _, losses = train_loop(cfg, steps=args.steps, batch=args.batch,
                              seq=args.seq, seed=0, ckpt_dir=args.ckpt_dir,
                              ckpt_every=50, lr=args.lr)
    print(f"final: first5={np.mean(losses[:5]):.4f} "
          f"last5={np.mean(losses[-5:]):.4f}")


if __name__ == "__main__":
    main()
