"""Congestion-notification matrix -> BENCH_notifications.json.

The notification-channel headline artifact: a four-way routing
comparison — static-minimal vs UGAL-adaptive vs app-aware (Algorithm 1)
vs notification-driven (SimParams.notify_* + NotificationPolicy,
docs/policy_api.md) — over two surfaces:

  * workload cells: the fig7/fig8 microbenchmark protocol (alternate
    arms on successive iterations inside ONE allocation) on a
    notification-enabled simulator, recording per-arm iteration medians
    and the cell's congestion_notifications NIC-counter total;
  * tenancy cells: the halo3d-victim / alltoall-aggressor mix from the
    interference matrix, but with a 64 KiB-per-pair aggressor heavy
    enough to push hot links past the notification threshold — victim
    slowdown per arm plus the victim's own notification count (§3.2:
    counters are allocation-scoped, so the victim only sees its flows).

Qualitative target (checked, not asserted): on at least one tenancy
cell the notification-driven victim beats the UGAL-adaptive victim
*while real notification events fired* — a zero-event "win" would just
be baseline jitter, so ``checks.wins_with_events_cells`` requires both.

Emits the ``name,us_per_call,derived`` CSV rows all benchmarks print,
plus ``BENCH_notifications.json`` (schema bench_notifications/v1,
checked by ``scripts/ci_lint.py --bench``; `make bench-notifications`
runs both).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from benchmarks.common import emit
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, SimParams, make_topology
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import run_benchmark
from repro.tenancy import InterferenceEngine, TenancyMix, Workload

SCHEMA = "bench_notifications/v1"

#: the machine every cell runs on (the calibrated notification
#: threshold below is specific to its link speeds — override with
#: --topology at your own risk, the checks may not hold elsewhere)
TOPOLOGY = "aries:n_groups=6,chassis_per_group=2,blades_per_chassis=8"

#: the four routing arms (matrix columns).  RoutingMode entries are the
#: static/adaptive hardware arms; strings are repro.policy engines.
ARMS = {
    "minimal": RoutingMode.ADAPTIVE_3,
    "adaptive": RoutingMode.ADAPTIVE_0,
    "app_aware": "app_aware",
    "notification": "notification",
}

#: notification-channel calibration (docs/architecture.md): hot links
#: under the heavy mix sit at 100s of µs of queue-to-drain, calm links
#: well under 100 µs — 250 µs separates them cleanly; the 0.5 clear
#: fraction + 1-phase delay are the two-level hysteresis defaults.
NOTIFY = dict(notify_threshold_s=250e-6, notify_clear_frac=0.5,
              notify_delay_phases=1, notify_penalty_s=300e-6)

#: fig7/fig8-surface workload cells: pattern, args, ranks, placement
WORKLOADS = {
    "fig7_pingpong_4MiB": ("pingpong", {"size": 4 << 20}, 2,
                           "inter_groups"),
    "fig8_alltoall_64KiB": ("alltoall", {"size_per_pair": 65536}, 64,
                            "scattered"),
    "fig8_halo3d": ("halo3d", {"nx": 64, "var_bytes": 8, "vars_": 4}, 64,
                    "scattered"),
}


def make_mix(scale: float = 1.0) -> TenancyMix:
    """Heavy interference mix: the fault-matrix victim, but the
    aggressor moves 64 KiB per pair — enough sustained load that hot
    global links genuinely cross the notification threshold (the 8 KiB
    interference-matrix mix never fires a flag at 250 µs)."""
    r = lambda n: max(8, int(n * scale))  # noqa: E731
    return TenancyMix("halo3d-vs-heavy-alltoall", (
        Workload("halo3d", "halo3d", r(64),
                 {"nx": 64, "var_bytes": 8, "vars_": 4}),
        Workload("alltoall", "alltoall", r(96),
                 {"size_per_pair": 65536},
                 arm=RoutingMode.ADAPTIVE_0)))


def run_workload_cells(topo_spec: str, iters: int, seed: int) -> dict:
    """fig7/fig8 protocol on a notification-enabled simulator: one sim
    and one allocation per cell, arms alternating per iteration."""
    topo = make_topology(topo_spec)
    cells: dict = {}
    for cell_name, (pattern, args, n_ranks, spread) in WORKLOADS.items():
        sim = DragonflySimulator(topo, SimParams(seed=seed, **NOTIFY))
        alloc = make_allocation(topo, n_ranks, spread=spread, seed=seed)
        res = run_benchmark(sim, alloc, pattern, args, iters,
                            modes=tuple(ARMS.values()))
        nic = sim.counters.get(alloc.allocation_id)
        events = int(nic.congestion_notifications) if nic else 0
        cell = {"topology": topo_spec, "pattern": pattern,
                "ranks": int(alloc.n_ranks), "spread": spread,
                "iterations": int(iters),
                "notification_events": events,
                "notify_epochs": int(sim.notify_epoch()), "arms": {}}
        for label, arm in ARMS.items():
            ts = [r.time_us for r in res[arm]]
            cell["arms"][label] = {
                "median_us": float(np.median(ts)),
                "p99_us": float(np.percentile(ts, 99)),
            }
            emit(f"notif.{cell_name}.{label}", float(np.median(ts)),
                 f"events={events}")
        cells[cell_name] = cell
    return cells


def run_tenancy_cells(topo_spec: str, rounds: int, scale: float,
                      seed: int) -> dict:
    """The four-way victim-slowdown comparison on the heavy mix.

    Ambient background OFF for the same reason as the other matrices:
    pareto bg draws would decorrelate the run-alone baseline's RNG
    stream and drown the notification signal.
    """
    params = SimParams(seed=seed, bg_enable=False, **NOTIFY)
    mix = make_mix(scale)
    cells: dict = {}
    for label, arm in ARMS.items():
        eng = InterferenceEngine(topo_spec, params, seed=seed)
        res = eng.run_mix(mix.with_victim_arm(arm), rounds=rounds)
        vic = res.victim_report
        events = int(vic.nic.congestion_notifications)
        cells[label] = {
            "topology": topo_spec,
            "mix": mix.name,
            "victim_slowdown": vic.slowdown,
            "victim_time_us": vic.time_us,
            "victim_alone_us": vic.alone_time_us,
            "victim_nonmin_fraction": vic.nonmin_fraction,
            "notification_events": events,
        }
        emit(f"notif.tenancy.{mix.name}.{label}", vic.time_us,
             f"slowdown={vic.slowdown:.3f};events={events}")
    return {mix.name: cells}


def run(rounds: int, scale: float, iters: int, seed: int,
        out_path: str | None, topo_spec: str | None = None) -> dict:
    topo_spec = topo_spec or TOPOLOGY
    workloads = run_workload_cells(topo_spec, iters, seed)
    tenancy = run_tenancy_cells(topo_spec, rounds, scale, seed)

    # checks: the notification win must coincide with real events —
    # run-alone baselines pay counter-read overhead, so a zero-event
    # cell that "wins" is measuring jitter, not routing
    beats = [m for m, row in tenancy.items()
             if row["notification"]["victim_slowdown"]
             < row["adaptive"]["victim_slowdown"]]
    fired = [m for m, row in tenancy.items()
             if row["notification"]["notification_events"] > 0]
    wins = sorted(set(beats) & set(fired))
    emit("notif.check.beats_adaptive", len(beats),
         f"{len(beats)}/{len(tenancy)} mixes")
    emit("notif.check.events_fired", len(fired),
         f"{len(fired)}/{len(tenancy)} mixes")
    emit("notif.check.wins_with_events", len(wins),
         f"{len(wins)}/{len(tenancy)} mixes")

    doc = {
        "schema": SCHEMA,
        "rounds": int(rounds),
        "iterations": int(iters),
        "seed": int(seed),
        "topology": topo_spec,
        "notify_params": {k: float(v) for k, v in NOTIFY.items()},
        "policies": list(ARMS),
        "workloads": workloads,
        "matrix": tenancy,
        "checks": {
            "notification_beats_adaptive_cells": beats,
            "notification_events_fired_cells": fired,
            "wins_with_events_cells": wins,
        },
    }
    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(doc, indent=2,
                                                     sort_keys=True) + "\n")
    return doc


def main(full: bool = False, smoke: bool = False,
         out: str | None = None, topology: str | None = None) -> dict:
    # default = the calibrated configuration the checks were validated
    # on (rounds=8, full mix); --full only widens the workload medians
    rounds, scale, iters = 8, 1.0, 6
    if smoke:
        rounds, scale, iters = 6, 0.5, 3
    if full:
        iters = 10
    return run(rounds, scale, iters, seed=7, out_path=out,
               topo_spec=topology)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI pass (shrunken mix, fewer rounds)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale pass (more workload iterations)")
    ap.add_argument("--out", default="BENCH_notifications.json",
                    help="output JSON path "
                         "(default: BENCH_notifications.json)")
    ap.add_argument("--topology", default=None,
                    help="make_topology spec replacing the calibrated "
                         "aries machine (checks may not hold elsewhere)")
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke, out=args.out,
         topology=args.topology)
