"""Fig. 10 — real-application traffic under the three routing strategies.

Applications are modeled as their dominant communication pattern plus a
compute/communication duty cycle (the paper's "noise absorption"): e.g.
MILC is halo3d's pattern at ~10% comm fraction, which is why its optimal
routing differs from the pure halo3d microbenchmark — reproduced here.
FFT at 256 vs 64 ranks reproduces the allocation-dependent flip."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (DAINT, MODE_LABEL, bench_topology, emit,
                               group_spread)
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, SimParams
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import (PATTERN_KIND, PATTERNS, engine_for_arm,
                                     run_iteration, run_iteration_engine)

# app -> (pattern, args, ranks, comm_fraction)
APPS = {
    "cp2k": ("allreduce", dict(elements=65536), 256, 0.35),
    "wrf-b": ("halo3d", dict(nx=512), 256, 0.25),
    "lammps": ("halo3d", dict(nx=384), 256, 0.3),
    "quantum-espresso": ("alltoall", dict(size_per_pair=32768), 256, 0.4),
    "nekbone": ("allreduce", dict(elements=16384), 256, 0.3),
    "milc": ("halo3d", dict(nx=768), 256, 0.1),
    "hpcg": ("allreduce", dict(elements=4096), 256, 0.2),
    "bfs": ("alltoall", dict(size_per_pair=2048), 256, 0.5),
    "fft-256": ("alltoall", dict(size_per_pair=131072), 256, 0.6),
    "fft-64": ("alltoall", dict(size_per_pair=131072), 64, 0.6),
}
def run_app(topo, name, pattern, args, ranks, comm_frac, iters, seed=0,
            policy: str = "app_aware"):
    modes = (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3, policy)
    ranks = min(ranks, topo.n_nodes)
    sim = DragonflySimulator(topo, SimParams(seed=seed, max_flows=40_000))
    al = make_allocation(topo, ranks, spread=group_spread(topo, 6),
                         seed=seed)
    phases = PATTERNS[pattern](ranks, **args)
    kind = PATTERN_KIND[pattern]
    engine = engine_for_arm(policy, sim, seed=seed)
    rng = np.random.default_rng(seed)
    out = {m: [] for m in modes}
    for _ in range(iters):
        for m in modes:
            if isinstance(m, str):
                r = run_iteration_engine(sim, al, phases, engine,
                                         site=name, kind=kind,
                                         use_plans=True)
            else:
                r = run_iteration(sim, al, phases, RoutingPolicy(m),
                                  use_plans=True)
            comm = r.time_us
            compute = comm * (1 - comm_frac) / max(comm_frac, 1e-3) \
                * rng.lognormal(0, 0.05)
            out[m].append(comm + compute)
    return out


def main(full: bool = False, policy: str = "app_aware", topology=None):
    topo = bench_topology(topology, DAINT)
    iters = 8 if full else 4
    modes = (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3, policy)
    apps = APPS if full else {k: APPS[k] for k in
                              ("cp2k", "milc", "fft-256", "fft-64", "bfs")}
    for name, (pattern, args, ranks, frac) in apps.items():
        res = run_app(topo, name, pattern, args, ranks, frac, iters,
                      policy=policy)
        med_def = np.median(res[RoutingMode.ADAPTIVE_0])
        for m in modes:
            ts = np.asarray(res[m])
            emit(f"fig10.{name}.{MODE_LABEL[m]}", float(np.median(ts)),
                 f"norm={float(np.median(ts) / med_def):.3f}")
    return None


if __name__ == "__main__":
    main(full=True)
