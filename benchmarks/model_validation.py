"""§2.4 validation — Eq.(2) estimates vs simulated ping-pong times across
allocations and message sizes (the paper reports 79% average correlation
over 40 allocations, 128B..16MiB)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DAINT, emit
from repro.core.perf_model import predict_transmission_cycles
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, DragonflyTopology, SimParams
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import pingpong, run_iteration

SIZES = (128, 1024, 16384, 262144, 4 << 20, 16 << 20)


def run(n_allocations: int = 40, iters: int = 6):
    topo = DragonflyTopology(DAINT)
    corrs = []
    for size in SIZES:
        meas, est = [], []
        for seed in range(n_allocations):
            spread = ("inter_groups", "inter_chassis",
                      "inter_blades", "scattered")[seed % 4]
            sim = DragonflySimulator(topo, SimParams(seed=seed))
            al = make_allocation(topo, 2, spread=spread, seed=seed)
            ts, es = [], []
            for _ in range(iters):
                r = run_iteration(sim, al, pingpong(2, size),
                                  RoutingPolicy(RoutingMode.ADAPTIVE_0))
                ts.append(r.time_us)
                es.append(predict_transmission_cycles(
                    size, r.mean_latency_us * 1e3, r.mean_stalls) / 1e3 * 2)
            meas.append(np.median(ts))
            est.append(np.median(es))
        c = float(np.corrcoef(meas, est)[0, 1])
        corrs.append(c)
        emit(f"model_validation.{size}B.corr", c * 100, "pct")
    emit("model_validation.mean_corr", float(np.mean(corrs)) * 100,
         "paper_reports_79pct")
    return corrs


def main(full: bool = False):
    return run(n_allocations=40 if full else 12, iters=6 if full else 4)


if __name__ == "__main__":
    main(full=True)
