"""Table 1 — correlation is not causation (§3.2).

An *idle* application observes the network for 1s vs 2s: the tile-counter
flit totals scale with the observation window (spurious correlation with
"execution time"), while the windowed flit RATE is invariant — the paper's
normalization fix."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DAINT, emit
from repro.dragonfly import DragonflySimulator, DragonflyTopology, SimParams


def run(idle_seconds=(1.0, 2.0)):
    topo = DragonflyTopology(DAINT)
    rows = []
    for idle_s in idle_seconds:
        sim = DragonflySimulator(topo, SimParams(seed=3))
        t0, f0 = sim.clock_s, sim.total_flits_all_jobs
        from repro.core.strategies import RoutingMode
        from repro.dragonfly.routing import RoutingPolicy
        pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)
        while sim.clock_s - t0 < idle_s:
            # the app sends NOTHING; only other jobs tick
            sim.run_phase(np.zeros(0, np.int64), np.zeros(0, np.int64),
                          np.zeros(0), pol, None)
        rows.append({"idle_s": sim.clock_s - t0,
                     "flits": sim.total_flits_all_jobs - f0})
    return rows


def main(full: bool = False):
    rows = run()
    r1, r2 = rows
    emit("table1.idle1s.flits", r1["flits"], f"window={r1['idle_s']:.2f}s")
    emit("table1.idle2s.flits", r2["flits"],
         f"raw_ratio={r2['flits'] / max(r1['flits'], 1e-9):.2f} (~2x: "
         "correlation without causation)")
    rate1 = r1["flits"] / r1["idle_s"]
    rate2 = r2["flits"] / r2["idle_s"]
    emit("table1.check.rate_invariant",
         abs(rate2 - rate1) / max(rate1, 1e-9) * 100,
         "pct_diff_of_normalized_rate (the 3.2 fix)")
    return rows


if __name__ == "__main__":
    main(full=True)
