"""Fig. 7 — routing-mode impact on a 4 MiB ping-pong, intra- vs inter-group.

Reproduces: (a) intra-group ADAPTIVE beats HIGH BIAS via stalls (7a/7b);
(b) inter-group HIGH BIAS wins with lower/steadier latency while ADAPTIVE
wanders on phantom congestion (7c); (c) the Eq.(2) model estimate tracks
the measured times (7d)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DAINT, bench_topology, boxstats, emit
from repro.core.perf_model import predict_transmission_cycles
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, SimParams
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import pingpong, run_iteration_engine
from repro.policy import PolicyEngine, StaticPolicy, TelemetryBus

SIZE = 4 << 20
MODES = (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3)


def run(iters: int = 40, seeds: int = 4, topology=None):
    topo = bench_topology(topology, DAINT)
    out = {}
    for tier, label in (("inter_chassis", "intra_group"),
                        ("inter_groups", "inter_groups")):
        res = {m: {"t": [], "l": [], "s": [], "est": []} for m in MODES}
        for seed in range(seeds):
            sim = DragonflySimulator(topo, SimParams(seed=seed))
            al = make_allocation(topo, 2, spread=tier, seed=seed)
            # static arms through the same engine API as the adaptive
            # ones: StaticPolicy, one vectorized decide per phase
            engines = {m: PolicyEngine(
                StaticPolicy(m),
                bus=TelemetryBus(clock_ghz=sim.params.nic_clock_ghz))
                for m in MODES}
            for _ in range(iters):
                for m in MODES:              # §5: alternate per iteration
                    r = run_iteration_engine(
                        sim, al, pingpong(2, SIZE), engines[m],
                        site=f"pingpong.{tier}",
                        counter_read_overhead_us=0.0,
                        use_plans=True)   # identical rounds share a plan
                    res[m]["t"].append(r.time_us)
                    res[m]["l"].append(r.mean_latency_us)
                    res[m]["s"].append(r.mean_stalls)
                    est = predict_transmission_cycles(
                        SIZE, r.mean_latency_us * 1e3, r.mean_stalls) \
                        / 1e3 * 2  # both directions
                    res[m]["est"].append(est)
        out[label] = res
    return out


def main(full: bool = False, topology=None):
    res = run(iters=50 if full else 25, seeds=4 if full else 3,
              topology=topology)
    for tier, modes in res.items():
        for m, d in modes.items():
            name = "adaptive" if m is RoutingMode.ADAPTIVE_0 else "highbias"
            st = boxstats(d["t"])
            emit(f"fig7.{tier}.{name}.time", st["median"],
                 f"qcd={st['qcd']:.3f}")
            lat_cv = float(np.std(d["l"]) / max(np.mean(d["l"]), 1e-9))
            emit(f"fig7.{tier}.{name}.latency",
                 float(np.median(d["l"])), f"qcd={lat_cv:.3f}")
            emit(f"fig7.{tier}.{name}.stalls",
                 float(np.median(d["s"]) * 1e3), "milli_cycles_per_flit")
            emit(f"fig7.{tier}.{name}.model_estimate",
                 float(np.median(d["est"])), "eq2")
    intra = res["intra_group"]
    ok_a = (np.median(intra[RoutingMode.ADAPTIVE_0]["t"])
            < np.median(intra[RoutingMode.ADAPTIVE_3]["t"]))
    ok_b = (np.median(intra[RoutingMode.ADAPTIVE_0]["s"])
            < np.median(intra[RoutingMode.ADAPTIVE_3]["s"]))
    emit("fig7.check.intra_adaptive_wins_via_stalls",
         1.0 if (ok_a and ok_b) else 0.0, "")
    # model correlation (7d): estimates track measurements per mode/tier
    pairs = []
    for tier, modes in res.items():
        for m, d in modes.items():
            pairs.append((np.median(d["t"]), np.median(d["est"])))
    t, e = np.array(pairs).T
    corr = float(np.corrcoef(t, e)[0, 1]) if len(pairs) > 2 else 1.0
    emit("fig7.check.model_tracks_measurement", corr * 100, "pct_corr")
    return res


if __name__ == "__main__":
    main(full=True)
