"""Fault-injection matrix -> BENCH_faults.json.

The repro.faults headline artifact: a (topology x fault-scenario x
victim-policy) grid on the multi-tenant engine.  Every cell runs the
halo3d-victim / alltoall-aggressor mix under a deterministic seeded
FaultSchedule (docs/faults.md) and records the victim's slowdown vs a
CLEAN run-alone baseline, its stranded-flow count, and its recovery
(rounds / time back to the pre-fault per-round baseline after the last
fault clears) — static-minimal vs adaptive vs app_aware, side by side.

Qualitative targets:
  * link failures inflate every policy's victim slowdown (faults are
    charged against a healthy-machine baseline, so slowdown > 1);
  * policies recover after the schedule clears (recovery_rounds >= 0
    in most cells — a -1 cell means that policy never re-converged).

Emits the ``name,us_per_call,derived`` CSV rows all benchmarks print,
plus ``BENCH_faults.json`` (schema bench_faults/v1, checked by
``scripts/ci_lint.py --bench``; `make bench-faults` runs both).
"""

from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import emit
from repro.core.strategies import RoutingMode
from repro.dragonfly import SimParams
from repro.faults import (FaultSchedule, link_degrade, link_down,
                          link_flap, router_down)
from repro.tenancy import InterferenceEngine, TenancyMix, Workload

SCHEMA = "bench_faults/v1"

#: the three machines the matrix spans (ISSUE: aries + dragonfly +
#: dragonfly_plus) — label -> make_topology spec
TOPOLOGIES = {
    "aries": "aries:n_groups=6,chassis_per_group=2,blades_per_chassis=8",
    "dragonfly": "dragonfly:p=2,a=8,h=4",
    "dragonfly_plus": "dragonfly_plus:p=4,a_leaf=8,a_spine=8,h=2,g=17",
}

#: the victim's candidate routing arms (the matrix columns)
ARMS = {
    "adaptive": RoutingMode.ADAPTIVE_0,
    "minimal": RoutingMode.ADAPTIVE_3,
    "app_aware": "app_aware",
}

#: fault scenarios, phase indices == ROUND indices.  Both clear before
#: the shortest pass ends (all_clear_phase == 6 < 8 rounds) so the
#: recovery fields are always numeric (schema contract).
CLEAR_ROUND = 6


def make_scenarios(seed: int) -> dict:
    """name -> FaultSchedule (deterministic in the benchmark seed)."""
    return {
        # two global links hard-down for rounds [2, 6)
        "link_down": FaultSchedule.of(
            link_down(start=2, end=CLEAR_ROUND, n_random=2,
                      link_kind="global", seed=seed)),
        # a flapping global link on top of two brown-out links at 30%
        # capacity, rounds [1, 6)
        "flap_degrade": FaultSchedule.of(
            link_flap(start=1, end=CLEAR_ROUND, period=2, duty=1,
                      n_random=1, link_kind="global", seed=seed + 1),
            link_degrade(0.3, start=1, end=CLEAR_ROUND, n_random=2,
                         link_kind="global", seed=seed + 2)),
        # two whole routers down for rounds [2, 6): their hosted nodes
        # lose their NIC links, stranding every flow that touches them
        # (the reroute-or-drop penalty shows up in stranded_flows)
        "router_down": FaultSchedule.of(
            router_down(start=2, end=CLEAR_ROUND, n_random=2,
                        seed=seed + 3)),
    }


def make_mix(scale: float = 1.0) -> TenancyMix:
    """The fixed job mix: a latency-sensitive stencil victim sharing
    the machine with one adaptive-heavy bulk-alltoall aggressor."""
    r = lambda n: max(8, int(n * scale))  # noqa: E731
    return TenancyMix("halo3d-vs-alltoall", (
        Workload("halo3d", "halo3d", r(64),
                 {"nx": 64, "var_bytes": 8, "vars_": 4}),
        Workload("alltoall", "alltoall", r(96),
                 {"size_per_pair": 8192},
                 arm=RoutingMode.ADAPTIVE_0)))


def run(rounds: int, scale: float, seed: int, out_path: str | None,
        topologies: dict | None = None):
    topologies = topologies or TOPOLOGIES
    # ambient background OFF for the same reason as the interference
    # matrix: the pareto bg draws would decorrelate the run-alone
    # baseline's RNG stream and drown the fault signal.
    params = SimParams(seed=seed, bg_enable=False)
    scenarios = make_scenarios(seed)
    mix = make_mix(scale)

    matrix: dict = {}
    for topo_label, topo_spec in topologies.items():
        for scen_name, sched in scenarios.items():
            key = f"{topo_label}|{scen_name}"
            for policy, arm in ARMS.items():
                cell_mix = mix.with_victim_arm(arm)
                eng = InterferenceEngine(topo_spec, params, seed=seed)
                res = eng.run_mix(cell_mix, rounds=rounds, faults=sched)
                vic = res.victim_report
                cell = {
                    "topology": topo_spec,
                    "scenario": scen_name,
                    "victim_slowdown": vic.slowdown,
                    "victim_time_us": vic.time_us,
                    "victim_alone_us": vic.alone_time_us,
                    "victim_recovery_rounds": vic.recovery_rounds,
                    "victim_recovery_time_us": vic.recovery_time_us,
                    "stranded_flows": vic.stranded_flows,
                    "tenant_recovery": {
                        t.name: {
                            "slowdown": t.slowdown,
                            "recovery_rounds": t.recovery_rounds,
                            "recovery_time_us": t.recovery_time_us,
                            "stranded_flows": t.stranded_flows,
                        } for t in res.tenants
                    },
                }
                matrix.setdefault(key, {})[policy] = cell
                emit(f"faults.{key}.{policy}", vic.time_us,
                     f"slowdown={vic.slowdown:.3f};"
                     f"rec={vic.recovery_rounds};"
                     f"stranded={vic.stranded_flows}")

    # qualitative checks: faults hurt (slowdown > 1 vs the clean
    # baseline) and policies come back once the schedule clears
    inflated = [k for k, row in matrix.items()
                if all(c["victim_slowdown"] > 1.0 for c in row.values())]
    recovered = [k for k, row in matrix.items()
                 if all(c["victim_recovery_rounds"] is not None
                        and c["victim_recovery_rounds"] >= 0
                        for c in row.values())]
    aa_wins = [k for k, row in matrix.items()
               if row["app_aware"]["victim_slowdown"]
               < row["adaptive"]["victim_slowdown"]]
    emit("faults.check.victims_inflated", len(inflated),
         f"{len(inflated)}/{len(matrix)} cells")
    emit("faults.check.all_policies_recover", len(recovered),
         f"{len(recovered)}/{len(matrix)} cells")
    emit("faults.check.app_aware_beats_adaptive", len(aa_wins),
         f"{len(aa_wins)}/{len(matrix)} cells")

    doc = {
        "schema": SCHEMA,
        "rounds": int(rounds),
        "seed": int(seed),
        "topologies": list(topologies.values()),
        "scenarios": {name: s.describe()
                      for name, s in scenarios.items()},
        "policies": list(ARMS),
        "matrix": matrix,
        "checks": {
            "victims_inflated_cells": inflated,
            "all_policies_recover_cells": recovered,
            "app_aware_beats_adaptive_cells": aa_wins,
        },
    }
    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(doc, indent=2,
                                                     sort_keys=True) + "\n")
    return doc


def main(full: bool = False, smoke: bool = False,
         out: str | None = None, topology: str | None = None) -> dict:
    topos, rounds, scale = dict(TOPOLOGIES), 10, 1.0
    if smoke:
        # CI pass: shrunken mix, one machine, still past CLEAR_ROUND so
        # the recovery fields stay numeric
        topos, rounds, scale = {"aries": TOPOLOGIES["aries"]}, 8, 0.375
    if full:
        rounds = 12
    if topology:
        topos = {"custom": topology}
    return run(rounds, scale, seed=7, out_path=out, topologies=topos)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI pass (shrunken mix, aries only)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale pass (12 rounds)")
    ap.add_argument("--out", default="BENCH_faults.json",
                    help="output JSON path (default: BENCH_faults.json)")
    ap.add_argument("--topology", default=None,
                    help="make_topology spec replacing the machine list "
                         "(default: aries + dragonfly + dragonfly_plus)")
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke, out=args.out,
         topology=args.topology)
