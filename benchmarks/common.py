"""Shared helpers for the paper-reproduction benchmarks.

Every module prints ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
contract) and can emit richer tables with --full."""

from __future__ import annotations

import sys

import numpy as np

from repro.core.noise import qcd
from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TopologyParams, make_topology)
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import PATTERNS, run_benchmark, run_iteration

# "Piz-Daint-like" (large) and "Cori-like" (small) topologies for Fig 8/9
DAINT = TopologyParams(n_groups=12)
CORI = TopologyParams(n_groups=8)


def bench_topology(spec, fallback: TopologyParams):
    """Resolve a benchmark's --topology axis (docs/topology.md).

    spec None keeps the suite's canonical Aries machine (`fallback`);
    otherwise any make_topology spec ("dragonfly_plus:p=4,...", a
    registered name, or a Topology instance) swaps the machine out."""
    if spec is None:
        return DragonflyTopology(fallback)
    return make_topology(spec)


def group_spread(topo, k: int) -> str:
    """'groups:k' clamped to machines with fewer than k groups."""
    return f"groups:{min(k, topo.n_groups)}"


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.3f},{derived}")


def boxstats(xs) -> dict:
    xs = np.asarray(xs, dtype=np.float64)
    return {
        "median": float(np.median(xs)),
        "mean": float(xs.mean()),
        "q1": float(np.percentile(xs, 25)),
        "q3": float(np.percentile(xs, 75)),
        "p99": float(np.percentile(xs, 99)),
        "max": float(xs.max()),
        "qcd": qcd(xs),
    }


MODES3 = (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3, "app_aware")
MODE_LABEL = {RoutingMode.ADAPTIVE_0: "default",
              RoutingMode.ADAPTIVE_1: "incmin",
              RoutingMode.ADAPTIVE_3: "highbias",
              "app_aware": "appaware",
              "eps_greedy": "epsgreedy",
              "notification": "notify",
              "static": "staticpol"}
