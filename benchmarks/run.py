"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --full runs paper-scale sweeps
(minutes); the default is a reduced pass suitable for CI."""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig3,fig7")
    ap.add_argument("--policy", default="app_aware",
                    choices=("static", "app_aware", "eps_greedy"),
                    help="adaptive arm for the policy-driven suites "
                         "(fig8, fig10): which repro.policy engine to run "
                         "against the static Default/HIGH-BIAS arms")
    ap.add_argument("--topology", default=None,
                    help="make_topology spec swapping the machine for the "
                         "topology-aware suites (fig7, fig8, fig10, "
                         "interference), e.g. 'dragonfly_plus:p=4,"
                         "a_leaf=8,a_spine=8,h=2,g=17' (docs/topology.md)")
    args = ap.parse_args(argv)

    from benchmarks import (fig3_allocation, fig4_fig5_hostnoise,
                            fig7_routing_pingpong, fig8_microbench,
                            fig10_applications, interference_matrix,
                            model_validation, perf_sim,
                            table1_correlation, tpu_selector)
    suites = {
        "fig3": fig3_allocation.main,
        "table1": table1_correlation.main,
        "fig4fig5": fig4_fig5_hostnoise.main,
        "fig7": fig7_routing_pingpong.main,
        "fig8": fig8_microbench.main,
        "fig10": fig10_applications.main,
        "model": model_validation.main,
        "tpu": tpu_selector.main,
        "perf": perf_sim.main,
        "interference": interference_matrix.main,
    }
    #: suites whose adaptive arm is a pluggable repro.policy engine
    policy_suites = {"fig8", "fig10"}
    #: suites that accept the --topology machine swap
    topology_suites = {"fig7", "fig8", "fig10", "interference"}
    chosen = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    for key in chosen:
        t0 = time.time()
        kw = {"policy": args.policy} if key in policy_suites else {}
        if key in topology_suites and args.topology:
            kw["topology"] = args.topology
        suites[key](full=args.full, **kw)
        print(f"# {key} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
