"""Simulator phase-kernel performance benchmark -> BENCH_sim.json.

Measures `DragonflySimulator.run_phase` wall-clock across backends on a
repeated heavy phase (the fig7/fig8/fig10 / train / serve shape: the
same traffic pattern, phase after phase):

  * reference   — the pre-refactor kernel (`repro.dragonfly.reference`),
                  the PR-3 baseline every speedup is measured against;
  * numpy       — the vectorized fast path, planless (candidates redrawn
                  per phase; seed-for-seed identical to reference);
  * numpy_plan  — fast path + PhasePlan reuse (the steady-state mode for
                  repeated collective rounds);
  * jax[_plan]  — the jitted backend (skipped when jax is unusable).

Emits the ``name,us_per_call,derived`` CSV rows all benchmarks print,
plus ``BENCH_sim.json`` at schema ``bench_sim/v2`` (documented in
docs/performance.md): per-backend phases/s, flows/s, per-stage timings,
and ``compile_s`` — the one-time first-call cost (jit tracing +
compilation on jax; cache warmup elsewhere) measured separately so
steady-state ``phase_s`` never includes it.  ``--smoke`` shrinks the
phase for CI; ``--require-jax`` makes a silent jax->numpy fallback a
hard error (asserts the jitted pipeline actually dispatched).
`make bench-perf` runs it and schema-checks the JSON via
``scripts/ci_lint.py --bench``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from benchmarks.common import emit
from repro.core.strategies import RoutingMode
from repro.dragonfly import (DragonflySimulator, DragonflyTopology,
                             SimParams, TopologyParams)
from repro.dragonfly.reference import reference_run_phase
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation

SCHEMA = "bench_sim/v2"


def _phase_inputs(topo: DragonflyTopology, n_flows: int, seed: int = 42):
    """A pareto-sized random many-to-many phase (alltoall-ish shape)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, topo.params.n_nodes, size=n_flows)
    dst = (src + rng.integers(1, topo.params.n_nodes, size=n_flows)) \
        % topo.params.n_nodes
    size = rng.pareto(1.2, size=n_flows) * 65536 + 1024
    return src, dst, size


def _time_backend(topo, src, dst, size, alloc, *, phases, backend="numpy",
                  use_plans=False, reference=False, seed=0):
    params = SimParams(seed=seed, backend=backend,
                       profile_stages=not reference)
    sim = DragonflySimulator(topo, params)
    pol = RoutingPolicy(RoutingMode.ADAPTIVE_0)

    def one():
        if reference:
            return reference_run_phase(sim, src, dst, size, pol, alloc)
        plan = sim.plan_for(src, dst, size) if use_plans else None
        return sim.run_phase(src, dst, size, pol, alloc, plan=plan)

    t0 = time.perf_counter()
    one()                         # cold call: jit trace/compile, caches
    first_s = time.perf_counter() - t0
    one()                         # settle: second call is steady state
    sim.stage_time_s.clear()
    t0 = time.perf_counter()
    res = None
    for _ in range(phases):
        res = one()
    dt = (time.perf_counter() - t0) / phases
    compile_s = max(0.0, first_s - dt)
    stages = {k: v / phases for k, v in sim.stage_time_s.items()}
    return dt, compile_s, stages, res


def run(n_flows: int, phases: int, out_path: str | None,
        require_jax: bool = False):
    topo = DragonflyTopology(TopologyParams(n_groups=12))
    src, dst, size = _phase_inputs(topo, n_flows)
    alloc = make_allocation(topo, min(64, n_flows), spread="inter_groups",
                            seed=3)
    arms = [("reference", dict(reference=True)),
            ("numpy", dict(backend="numpy")),
            ("numpy_plan", dict(backend="numpy", use_plans=True))]
    from repro.compat.runtime import resolve_backend
    jax_ok = resolve_backend("jax") == "jax"
    if require_jax and not jax_ok:
        raise RuntimeError("--require-jax: jax backend unavailable "
                           "(resolve_backend fell back to numpy)")
    if jax_ok:
        arms.append(("jax_plan", dict(backend="jax", use_plans=True)))

    if jax_ok:
        from repro.dragonfly.jax_backend import PIPELINE_CALLS
        calls_before = dict(PIPELINE_CALLS)
    results = {}
    checks = {}
    for name, kw in arms:
        dt, compile_s, stages, res = _time_backend(
            topo, src, dst, size, alloc, phases=phases, **kw)
        results[name] = {
            "phase_s": dt,
            "phases_per_s": 1.0 / dt,
            "flows_per_s": n_flows / dt,
            "compile_s": compile_s,
            "stages_s": stages,
        }
        checks[name] = res
        emit(f"perf_sim.{name}.phase", dt * 1e6,
             f"flows_per_s={n_flows / dt:.0f} compile_s={compile_s:.3f}")
    if require_jax:
        from repro.dragonfly.jax_backend import PIPELINE_CALLS
        dispatched = sum(PIPELINE_CALLS.values()) \
            - sum(calls_before.values())
        if dispatched <= 0:
            raise RuntimeError("--require-jax: jax arm never dispatched "
                               "the jitted pipeline (silent fallback?)")

    # seed-equivalence sanity: the numpy fast path must replay the
    # reference bit-for-bit on the same seed (the golden-trace property)
    a, b = checks["reference"], checks["numpy"]
    seed_exact = bool(np.array_equal(a.t_us, b.t_us)
                      and np.array_equal(a.latency_us, b.latency_us))
    emit("perf_sim.check.numpy_seed_exact", 1.0 if seed_exact else 0.0, "")

    ref = results["reference"]["phase_s"]
    speedups = {f"{k}_vs_reference": ref / v["phase_s"]
                for k, v in results.items() if k != "reference"}
    for k, v in speedups.items():
        emit(f"perf_sim.speedup.{k}", v, "x")

    device = None
    if jax_ok:
        import jax
        device = {"backend": jax.default_backend(),
                  "n_devices": int(jax.device_count())}
    doc = {
        "schema": SCHEMA,
        "flows": int(n_flows),
        "phases_timed": int(phases),
        "topology": {"n_groups": 12, "n_links": int(topo.n_links)},
        "seed_exact": seed_exact,
        "jax_device": device,
        "backends": results,
        "speedup": speedups,
    }
    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(doc, indent=2,
                                                     sort_keys=True) + "\n")
    return doc


def main(full: bool = False, smoke: bool = False,
         out: str | None = None, require_jax: bool = False) -> dict:
    n_flows, phases = (50_000, 5) if not smoke else (4_000, 3)
    if full:
        n_flows, phases = 120_000, 5
    return run(n_flows, phases, out, require_jax=require_jax)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI pass (4k flows)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale pass (120k flows)")
    ap.add_argument("--require-jax", action="store_true",
                    help="fail instead of silently skipping the jax arm")
    ap.add_argument("--out", default="BENCH_sim.json",
                    help="output JSON path (default: BENCH_sim.json)")
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke, out=args.out,
         require_jax=args.require_jax)
