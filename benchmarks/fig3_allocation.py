"""Fig. 3 — ping-pong (16 KiB) across allocation tiers on Piz-Daint-like.

Reproduces: flat-ish medians, massively growing variance with tier, and
outliers orders of magnitude above the median for inter-group placements
(which pull the mean into the outlier regime)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DAINT, boxstats, emit
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, DragonflyTopology, SimParams
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import pingpong, run_iteration

TIERS = ("inter_nodes", "inter_blades", "inter_chassis", "inter_groups")


def run(iters: int = 120, seeds: int = 4, size: int = 16384):
    topo = DragonflyTopology(DAINT)
    out = {}
    for tier in TIERS:
        ts = []
        for seed in range(seeds):
            sim = DragonflySimulator(topo, SimParams(seed=seed))
            al = make_allocation(topo, 2, spread=tier, seed=seed)
            for _ in range(iters):
                ts.append(run_iteration(
                    sim, al, pingpong(2, size),
                    RoutingPolicy(RoutingMode.ADAPTIVE_0)).time_us)
        out[tier] = boxstats(ts)
    return out


def main(full: bool = False):
    res = run(iters=150 if full else 60, seeds=4 if full else 2)
    for tier, st in res.items():
        emit(f"fig3.pingpong16k.{tier}", st["median"],
             f"mean={st['mean']:.1f};max={st['max']:.1f};iqr_q3={st['q3']:.1f}")
    # the paper's headline observations as derived checks
    ladder_ok = (res["inter_groups"]["median"]
                 >= res["inter_nodes"]["median"])
    tail = res["inter_groups"]["max"] / max(res["inter_groups"]["median"],
                                            1e-9)
    emit("fig3.check.median_ladder", 1.0 if ladder_ok else 0.0,
         f"tail_ratio={tail:.0f}x")
    return res


if __name__ == "__main__":
    main(full=True)
