"""Multi-tenant interference matrix -> BENCH_interference.json.

The repro.tenancy deliverable: a (job-mix x victim-policy) grid on one
shared Dragonfly.  Every mix pairs a latency/bandwidth-sensitive VICTIM
with adaptive-heavy AGGRESSORS (fully-adaptive routing, the "bad
neighbor" of the paper's production traces); the sweep swaps the
victim's routing arm and scores its slowdown vs a run-alone baseline.

Qualitative reproduction targets (Kang et al.):
  * adaptive-heavy aggressors inflate victims (slowdown > 1 in the mix);
  * biasing the victim toward minimal routing (HIGH-BIAS) and the
    app-aware arm keep the victim closer to run-alone than leaving it
    fully adaptive — in at least one mix app_aware < adaptive.

The matrix also carries the topology axis (docs/topology.md): the last
mix re-runs the first on a Dragonfly+ machine via `TenancyMix.topology`,
and ``--topology`` swaps the default machine for every other row.

Emits the ``name,us_per_call,derived`` CSV rows all benchmarks print,
plus ``BENCH_interference.json`` (schema bench_interference/v2 — every
cell records the topology it ran on — checked by
``scripts/ci_lint.py --bench``; `make bench-interference` runs both).
"""

from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import emit
from repro.core.strategies import RoutingMode
from repro.dragonfly import SimParams, make_topology
from repro.tenancy import TenancyMix, Workload, sweep

SCHEMA = "bench_interference/v2"

#: the default machine (the paper-like Aries layout) and the non-Aries
#: probe row's machine (a Dragonfly+ big enough for the same mix)
DEFAULT_TOPOLOGY = "aries:n_groups=6,chassis_per_group=2," \
                   "blades_per_chassis=8"
DPLUS_TOPOLOGY = "dragonfly_plus:p=4,a_leaf=8,a_spine=8,h=2,g=17"

#: the victim's candidate routing arms (the matrix columns)
ARMS = {
    "adaptive": RoutingMode.ADAPTIVE_0,
    "minimal": RoutingMode.ADAPTIVE_3,
    "app_aware": "app_aware",
}


def make_mixes(scale: float = 1.0) -> list:
    """The matrix rows: three victim/aggressor job mixes.

    scale < 1 shrinks ranks for the CI smoke pass (the qualitative
    ordering is what the full pass asserts, not the smoke numbers).
    """
    r = lambda n: max(8, int(n * scale))  # noqa: E731
    a2a = dict(arm=RoutingMode.ADAPTIVE_0)
    return [
        # nearest-neighbor stencil vs one bulk alltoall aggressor
        TenancyMix("halo3d-vs-alltoall", (
            Workload("halo3d", "halo3d", r(64),
                     {"nx": 64, "var_bytes": 8, "vars_": 4}),
            Workload("alltoall", "alltoall", r(96),
                     {"size_per_pair": 8192}, **a2a))),
        # bandwidth-bound allreduce vs a skewed expert-parallel alltoall
        TenancyMix("allreduce-vs-moe", (
            Workload("allreduce", "allreduce", r(64),
                     {"elements": 262144}),
            Workload("moe", "moe_alltoall", r(96),
                     {"tokens_per_rank": 1024, "token_bytes": 2048},
                     **a2a))),
        # wavefront sweep vs TWO alltoall aggressors (K=3)
        TenancyMix("sweep3d-vs-2xalltoall", (
            Workload("sweep3d", "sweep3d", r(64),
                     {"nx": 256, "var_bytes": 64}),
            Workload("alltoall_a", "alltoall", r(64),
                     {"size_per_pair": 16384}, **a2a),
            Workload("alltoall_b", "alltoall", r(64),
                     {"size_per_pair": 16384}, **a2a))),
        # the topology axis: the first mix again, on a Dragonfly+ machine
        TenancyMix("halo3d-vs-alltoall@dplus", (
            Workload("halo3d", "halo3d", r(64),
                     {"nx": 64, "var_bytes": 8, "vars_": 4}),
            Workload("alltoall", "alltoall", r(96),
                     {"size_per_pair": 8192}, **a2a)),
            topology=DPLUS_TOPOLOGY),
    ]


def run(rounds: int, scale: float, seed: int, out_path: str | None,
        topology: str | None = None):
    topo = make_topology(topology or DEFAULT_TOPOLOGY)
    # ambient background OFF: the matrix isolates CO-TENANT interference
    # (the heavy-tailed ambient bg is a different noise source, measured
    # by fig3/fig4; its pareto draws would also decorrelate the run-alone
    # baseline's RNG stream and drown the co-tenant delta).
    params = SimParams(seed=seed, bg_enable=False)
    mixes = make_mixes(scale)
    records = sweep(topo, mixes, ARMS, params=params, rounds=rounds,
                    seed=seed)

    matrix: dict = {}
    for rec in records:
        cell = {
            "topology": rec["topology"],
            "victim_slowdown": rec["victim_slowdown"],
            "victim_time_us": rec["victim_time_us"],
            "victim_alone_us": rec["victim_alone_us"],
            "victim_nonmin_fraction": rec["victim_nonmin_fraction"],
            "aggressor_slowdowns": rec["aggressor_slowdowns"],
        }
        matrix.setdefault(rec["mix"], {})[rec["policy"]] = cell
        emit(f"interference.{rec['mix']}.{rec['policy']}",
             rec["victim_time_us"],
             f"slowdown={rec['victim_slowdown']:.3f};"
             f"nmf={rec['victim_nonmin_fraction']:.3f}")

    # qualitative checks (the Kang findings this matrix reproduces):
    # (1) adaptive-heavy aggressors inflate minimal-routed victims;
    # (2) the app-aware arm keeps the victim closer to run-alone than
    #     leaving it fully adaptive.
    inflated = [m for m, row in matrix.items()
                if row["minimal"]["victim_slowdown"] > 1.0]
    aa_wins = [m for m, row in matrix.items()
               if row["app_aware"]["victim_slowdown"]
               < row["adaptive"]["victim_slowdown"]]
    emit("interference.check.minimal_victims_inflated",
         len(inflated), f"{len(inflated)}/{len(matrix)} mixes")
    emit("interference.check.app_aware_beats_adaptive",
         len(aa_wins), f"{len(aa_wins)}/{len(matrix)} mixes")

    doc = {
        "schema": SCHEMA,
        "rounds": int(rounds),
        "seed": int(seed),
        "topology": topo.describe(),
        "mixes": [m.name for m in mixes],
        "policies": list(ARMS),
        "matrix": matrix,
        "checks": {
            "minimal_victims_inflated_mixes": inflated,
            "app_aware_beats_adaptive_mixes": aa_wins,
            "app_aware_beats_adaptive": bool(aa_wins),
        },
    }
    if out_path:
        pathlib.Path(out_path).write_text(json.dumps(doc, indent=2,
                                                     sort_keys=True) + "\n")
    return doc


def main(full: bool = False, smoke: bool = False,
         out: str | None = None, topology: str | None = None) -> dict:
    rounds, scale = (8, 1.0) if not smoke else (3, 0.375)
    if full:
        rounds, scale = 12, 1.0
    return run(rounds, scale, seed=7, out_path=out, topology=topology)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI pass (shrunken mixes, 3 rounds)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale pass (12 rounds)")
    ap.add_argument("--out", default="BENCH_interference.json",
                    help="output JSON path "
                         "(default: BENCH_interference.json)")
    ap.add_argument("--topology", default=None,
                    help="make_topology spec for the default machine "
                         "(mixes with their own topology keep it); "
                         f"default: {DEFAULT_TOPOLOGY}")
    args = ap.parse_args()
    main(full=args.full, smoke=args.smoke, out=args.out,
         topology=args.topology)
