"""Fig. 8/9 — microbenchmarks x input sizes x routing strategies.

Piz-Daint-like: 1024 ranks over 6 of 12 groups (the paper: 1024 nodes, 257
routers, 6 groups).  Cori-like: 64 ranks over 5 of 8 groups.  Times are
normalized to the Default (ADAPTIVE/INCR-MINIMAL) median; the x-axis
annotation carries the %-of-traffic Application-Aware sent via Default."""

from __future__ import annotations

import numpy as np

from benchmarks.common import (CORI, DAINT, MODE_LABEL, bench_topology,
                               boxstats, emit, group_spread)
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, SimParams
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import run_benchmark

SWEEP = {
    "pingpong": [dict(size=1024), dict(size=1 << 20)],
    "allreduce": [dict(elements=1024), dict(elements=262144)],
    "alltoall": [dict(size_per_pair=1024), dict(size_per_pair=65536)],
    "barrier": [dict()],
    "broadcast": [dict(size=4096), dict(size=4 << 20)],
    "halo3d": [dict(nx=256), dict(nx=768)],
    "sweep3d": [dict(nx=256), dict(nx=768)],
}


def run(machine: str = "daint", iters: int = 8, seed: int = 0,
        max_flows: int = 60_000, full_scale: bool = True,
        policy: str = "app_aware", topology=None):
    """`policy` picks the adaptive arm ("app_aware" | "eps_greedy" |
    "static") — the repro.policy engine driving the third column.
    `topology` (a make_topology spec) swaps the machine out for both
    the daint- and cori-shaped passes; ranks are capped to fit."""
    modes = (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_3, policy)
    if machine == "daint":
        topo = bench_topology(topology, DAINT)
        n_ranks, groups = ((1024 if full_scale else 256),
                           group_spread(topo, 6))
    else:
        topo = bench_topology(topology, CORI)
        n_ranks, groups = 64, group_spread(topo, 5)
    n_ranks = min(n_ranks, topo.n_nodes)
    out = {}
    for bench, sweeps in SWEEP.items():
        for args in sweeps:
            sim = DragonflySimulator(topo, SimParams(seed=seed,
                                                     max_flows=max_flows))
            al = make_allocation(topo, n_ranks, spread=groups, seed=seed)
            res = run_benchmark(sim, al, bench, args, iters, modes=modes,
                                use_plans=True)
            key = f"{bench}." + (".".join(f"{v}" for v in args.values())
                                 or "na")
            med_def = np.median([r.time_us
                                 for r in res[RoutingMode.ADAPTIVE_0]])
            row = {"default_median_us": float(med_def)}
            for m in modes:
                ts = np.array([r.time_us for r in res[m]])
                row[MODE_LABEL[m]] = {
                    "norm_median": float(np.median(ts) / med_def),
                    "qcd": boxstats(ts)["qcd"],
                }
            aa = res[policy]
            frac = np.mean([
                sum(v for k, v in r.mode_bytes.items()
                    if k in (RoutingMode.ADAPTIVE_0, RoutingMode.ADAPTIVE_1))
                / max(sum(r.mode_bytes.values()), 1e-9) for r in aa])
            row["policy_pct_default_traffic"] = float(frac * 100)
            out[key] = row
    return out


def main(full: bool = False, policy: str = "app_aware", topology=None):
    label = MODE_LABEL[policy]
    for machine, tag in (("daint", "fig8"), ("cori", "fig9")):
        if not full and machine == "cori":
            continue
        res = run(machine, iters=10 if full else 4,
                  max_flows=80_000 if full else 30_000,
                  full_scale=full, policy=policy, topology=topology)
        wins = 0
        cells = 0
        for key, row in res.items():
            emit(f"{tag}.{key}.default", row["default_median_us"],
                 f"norm=1.0;qcd={row['default']['qcd']:.3f}")
            emit(f"{tag}.{key}.highbias",
                 row["default_median_us"] * row["highbias"]["norm_median"],
                 f"norm={row['highbias']['norm_median']:.3f}")
            emit(f"{tag}.{key}.{label}",
                 row["default_median_us"] * row[label]["norm_median"],
                 f"norm={row[label]['norm_median']:.3f};"
                 f"pct_default={row['policy_pct_default_traffic']:.0f}%")
            best = min(row["default"]["norm_median"] if False else 1.0,
                       row["highbias"]["norm_median"])
            cells += 1
            if row[label]["norm_median"] <= best * 1.10:
                wins += 1
        emit(f"{tag}.check.{label}_within10pct_of_best",
             wins / max(cells, 1) * 100, f"{wins}/{cells} cells")
    return None


if __name__ == "__main__":
    main(full=True)
