"""Beyond-paper — Algorithm 1 arbitrating TPU collective schedules.

Sweeps message sizes through the AppAwareSelector on the 2x16x16 mesh cost
model and reports the crossover, plus the pod-boundary (DCN) bytes saved
vs always-DIRECT for a llama3-8b-sized gradient reduction — the TPU
analogue of Fig. 8's 'Application-Aware sends X% via Default'."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.analysis.roofline import param_counts_analytic
from repro.collectives.modes import CollectiveMode
from repro.collectives.selector import AppAwareSelector, ICICostModel, MeshSpec
from repro.configs import get_config
from repro.train.grad_comm import GradCommConfig, bucketize


def crossover_sweep():
    cm = ICICostModel(MeshSpec(n_pods=2, inner_chips=256))
    sel = AppAwareSelector(cm)
    flips = []
    for size in [1 << k for k in range(10, 31)]:
        m = sel.select(size)
        sel.observe_predicted(size)
        flips.append((size, m))
        emit(f"tpu_selector.sweep.{size}B",
             cm.predict(size, m).latency_cycles / 1e3,
             m.value)
    first_h = next((s for s, m in flips
                    if m == CollectiveMode.HIERARCHICAL), None)
    emit("tpu_selector.crossover_bytes", float(first_h or 0),
         "first size routed hierarchically")
    return flips


def grad_reduce_savings():
    """llama3-8b grad buckets: DCN wire bytes DIRECT vs app-aware."""
    cfg = get_config("llama3-8b")
    total, _ = param_counts_analytic(cfg)
    grad_bytes = total * 2  # bf16 wire
    mesh = MeshSpec(n_pods=2, inner_chips=256)
    cm = ICICostModel(mesh)
    sel = AppAwareSelector(cm)
    bucket = 32 << 20
    n_buckets = int(np.ceil(grad_bytes / bucket))
    direct_dcn = hier_dcn = aware_dcn = 0.0
    n, p, i = mesh.total, mesh.n_pods, mesh.inner_chips
    for _ in range(n_buckets):
        d = 2 * (n - 1) / n * bucket                    # full ring on DCN
        h = 2 * (p - 1) / p * (bucket / i)              # shard on DCN
        direct_dcn += d
        hier_dcn += h
        m = sel.select(bucket)
        sel.observe_predicted(bucket)
        aware_dcn += h if m == CollectiveMode.HIERARCHICAL else d
    emit("tpu_selector.llama3_grad.direct_dcn_gb", direct_dcn / 2**30, "")
    emit("tpu_selector.llama3_grad.hier_dcn_gb", hier_dcn / 2**30, "")
    emit("tpu_selector.llama3_grad.appaware_dcn_gb", aware_dcn / 2**30,
         f"saving={100 * (1 - aware_dcn / max(direct_dcn, 1e-9)):.1f}%")


def main(full: bool = False):
    crossover_sweep()
    grad_reduce_savings()


if __name__ == "__main__":
    main(full=True)
