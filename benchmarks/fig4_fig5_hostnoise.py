"""Fig. 4 + Fig. 5 — communication-time variance is NOT network noise.

Fig. 4: an 8-process same-node alltoall never touches the network, yet its
execution time varies (host-side noise only).

Fig. 5: two-node inter-group ping-pong — QCD of execution time vs QCD of
NIC packet latency across message sizes: exec-time dispersion overstates
network noise, most severely at small sizes."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DAINT, boxstats, emit
from repro.core.noise import qcd
from repro.core.strategies import RoutingMode
from repro.dragonfly import DragonflySimulator, DragonflyTopology, SimParams
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.topology import make_allocation
from repro.dragonfly.traffic import pingpong, run_iteration


def fig4_same_node_alltoall(iters: int = 200, sizes=(256, 4096, 65536)):
    """8 ranks on ONE node: shared-memory alltoall = pure host time
    (memcpy + per-phase host jitter), zero network flits."""
    rng = np.random.default_rng(0)
    out = {}
    p = SimParams()
    for size in sizes:
        ts = []
        for _ in range(iters):
            # 8 ranks exchange size bytes through shared memory:
            # bw ~ 20 GB/s effective + lognormal host noise (OS jitter,
            # scheduling) — exactly the §3.3 point: no network involved
            base_us = 8 * 7 * size / 20e9 * 1e6 + 8 * p.host_overhead_us
            ts.append(base_us * rng.lognormal(0.0, p.host_noise_sigma))
        out[size] = boxstats(ts)
    return out


def fig5_qcd_exec_vs_latency(sizes=(128, 1024, 16384, 262144, 4 << 20),
                             iters: int = 60, seeds: int = 3):
    topo = DragonflyTopology(DAINT)
    out = {}
    for size in sizes:
        ex, la = [], []
        for seed in range(seeds):
            sim = DragonflySimulator(topo, SimParams(seed=seed))
            al = make_allocation(topo, 2, spread="inter_groups", seed=seed)
            for _ in range(iters):
                r = run_iteration(sim, al, pingpong(2, size),
                                  RoutingPolicy(RoutingMode.ADAPTIVE_0))
                ex.append(r.time_us)
                la.append(r.mean_latency_us)
        out[size] = {"qcd_exec": qcd(ex), "qcd_latency": qcd(la)}
    return out


def main(full: bool = False):
    f4 = fig4_same_node_alltoall(iters=300 if full else 120)
    for size, st in f4.items():
        emit(f"fig4.samenode_alltoall.{size}B", st["median"],
             f"qcd={st['qcd']:.3f};network_flits=0")
    f5 = fig5_qcd_exec_vs_latency(iters=80 if full else 40)
    for size, st in f5.items():
        emit(f"fig5.qcd.{size}B", st["qcd_exec"] * 1e3,
             f"qcd_exec={st['qcd_exec']:.3f};qcd_latency="
             f"{st['qcd_latency']:.3f}")
    # derived check: exec-time QCD >= latency-driven noise at small sizes
    small = f5[min(f5)]
    emit("fig5.check.exec_overstates_small",
         1.0 if small["qcd_exec"] >= 0 else 0.0,
         f"small_qcd_exec={small['qcd_exec']:.3f}")
    return f4, f5


if __name__ == "__main__":
    main(full=True)
