# CI entry points (see also pyproject.toml: `python -m pytest` needs no
# PYTHONPATH — pytest's pythonpath=["src"] handles the src layout).

PY ?= python

.PHONY: test bench-smoke lint docs

test:
	$(PY) -m pytest -q

# reduced benchmark pass (the CI perf smoke; --full is the paper-scale run)
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --only fig7,fig8,tpu --policy app_aware

lint:
	$(PY) -m compileall -q src benchmarks examples tests
	$(PY) scripts/ci_lint.py

# documentation health: README/docs internal links resolve, and no
# __pycache__/*.pyc is tracked in git
docs:
	$(PY) scripts/ci_lint.py --docs
