# CI entry points (see also pyproject.toml: `python -m pytest` needs no
# PYTHONPATH — pytest's pythonpath=["src"] handles the src layout).

PY ?= python

.PHONY: test bench-smoke bench-perf bench-interference bench-faults \
	bench-notifications lint docs

# coverage is OPTIONAL tooling: the floor is enforced only when
# pytest-cov is importable (docs/testing.md — the container may not
# ship it; the degradation is printed, never silent)
COV_AVAILABLE := $(shell $(PY) -c "import importlib.util as u; print(1 if u.find_spec('pytest_cov') else 0)" 2>/dev/null)
COV_FLOOR ?= 60
COVFLAGS := $(if $(filter 1,$(COV_AVAILABLE)),--cov=repro --cov-fail-under=$(COV_FLOOR),)

# tier-1 verify (ROADMAP): same selection as CI, plus the slowest-10
# duration report and the (gated) ratcheted coverage floor
test:
	@if [ "$(COV_AVAILABLE)" != "1" ]; then \
		echo "NOTE: pytest-cov not installed — coverage floor ($(COV_FLOOR)%) NOT enforced this run"; \
	fi
	$(PY) -m pytest -x -q --durations=10 $(COVFLAGS)

# reduced benchmark pass (the CI perf smoke; --full is the paper-scale run)
bench-smoke:
	$(PY) scripts/ci_lint.py --topology
	$(PY) -m pytest -q -m slow tests/test_benchmarks_golden.py
	PYTHONPATH=src $(PY) -m benchmarks.run --only fig7,fig8,tpu --policy app_aware
	PYTHONPATH=src $(PY) -m benchmarks.interference_matrix --smoke \
		--out BENCH_interference.json
	PYTHONPATH=src $(PY) -m benchmarks.fault_matrix --smoke \
		--out BENCH_faults.json
	PYTHONPATH=src $(PY) -m benchmarks.notification_matrix --smoke \
		--out BENCH_notifications.json
	PYTHONPATH=src $(PY) -m benchmarks.perf_sim --smoke --require-jax \
		--out /tmp/bench_sim_smoke.json

# simulator phase-kernel perf trajectory: write + schema-check
# BENCH_sim.json (paper scale — the committed numbers; see
# docs/performance.md for the 50k/120k crossover discussion)
bench-perf:
	PYTHONPATH=src $(PY) -m benchmarks.perf_sim --full --require-jax \
		--out BENCH_sim.json
	$(PY) scripts/ci_lint.py --bench

# multi-tenant interference matrix: write + schema-check
# BENCH_interference.json (docs/interference.md)
bench-interference:
	PYTHONPATH=src $(PY) -m benchmarks.interference_matrix \
		--out BENCH_interference.json
	$(PY) scripts/ci_lint.py --bench

# fault-injection matrix: write + schema-check BENCH_faults.json
# (docs/faults.md)
bench-faults:
	PYTHONPATH=src $(PY) -m benchmarks.fault_matrix \
		--out BENCH_faults.json
	$(PY) scripts/ci_lint.py --bench

# notification-channel four-way routing matrix: write + schema-check
# BENCH_notifications.json (docs/policy_api.md)
bench-notifications:
	PYTHONPATH=src $(PY) -m benchmarks.notification_matrix \
		--out BENCH_notifications.json
	$(PY) scripts/ci_lint.py --bench

lint:
	$(PY) -m compileall -q src benchmarks examples tests
	$(PY) scripts/ci_lint.py
	$(PY) scripts/ci_lint.py --topology

# documentation health: README/docs internal links resolve, and no
# __pycache__/*.pyc is tracked in git
docs:
	$(PY) scripts/ci_lint.py --docs
