# CI entry points (see also pyproject.toml: `python -m pytest` needs no
# PYTHONPATH — pytest's pythonpath=["src"] handles the src layout).

PY ?= python

.PHONY: test bench-smoke bench-perf bench-interference bench-faults \
	lint docs

# tier-1 verify (ROADMAP): same flags as CI
test:
	$(PY) -m pytest -x -q

# reduced benchmark pass (the CI perf smoke; --full is the paper-scale run)
bench-smoke:
	$(PY) scripts/ci_lint.py --topology
	PYTHONPATH=src $(PY) -m benchmarks.run --only fig7,fig8,tpu --policy app_aware
	PYTHONPATH=src $(PY) -m benchmarks.interference_matrix --smoke \
		--out BENCH_interference.json
	PYTHONPATH=src $(PY) -m benchmarks.fault_matrix --smoke \
		--out BENCH_faults.json

# simulator phase-kernel perf trajectory: write + schema-check BENCH_sim.json
bench-perf:
	PYTHONPATH=src $(PY) -m benchmarks.perf_sim --smoke --out BENCH_sim.json
	$(PY) scripts/ci_lint.py --bench

# multi-tenant interference matrix: write + schema-check
# BENCH_interference.json (docs/interference.md)
bench-interference:
	PYTHONPATH=src $(PY) -m benchmarks.interference_matrix \
		--out BENCH_interference.json
	$(PY) scripts/ci_lint.py --bench

# fault-injection matrix: write + schema-check BENCH_faults.json
# (docs/faults.md)
bench-faults:
	PYTHONPATH=src $(PY) -m benchmarks.fault_matrix \
		--out BENCH_faults.json
	$(PY) scripts/ci_lint.py --bench

lint:
	$(PY) -m compileall -q src benchmarks examples tests
	$(PY) scripts/ci_lint.py
	$(PY) scripts/ci_lint.py --topology

# documentation health: README/docs internal links resolve, and no
# __pycache__/*.pyc is tracked in git
docs:
	$(PY) scripts/ci_lint.py --docs
