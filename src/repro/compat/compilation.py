"""Compiled-artifact shims.

`Compiled.cost_analysis()` drifted alongside the mesh APIs: jax 0.4.x
returns a list of per-program property dicts, jax>=0.7 returns the
single flattened dict.  The dry-run reads scalar keys ("flops", ...),
so normalize to the modern dict shape on both.
"""

from __future__ import annotations


def cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` as a single dict on every jax."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
