"""Compiled-artifact shims.

`Compiled.cost_analysis()` drifted alongside the mesh APIs: jax 0.4.x
returns a list of per-program property dicts, jax>=0.7 returns the
single flattened dict.  The dry-run reads scalar keys ("flops", ...),
so normalize to the modern dict shape on both.

`jit_compiled` wraps `jax.jit` with graceful degradation of buffer
donation: the device-resident phase engine donates its largest
per-phase operand (the Gumbel noise block) so XLA can reuse the buffer
for outputs, but donation keyword support/semantics have drifted across
jax versions — a jax whose `jit` rejects the donation arguments still
gets a working (undonated) compiled function instead of a crash.
"""

from __future__ import annotations


def jit_compiled(fun, *, static_argnames=None, donate_argnums=None):
    """`jax.jit(fun)` that degrades donation instead of failing.

    Accepts the subset of jit options the repo uses.  When the
    installed jax rejects ``donate_argnums`` (or donation of these
    arguments), the function is re-wrapped without donation — the
    result is always callable, merely less memory-frugal."""
    import jax

    kw = {}
    if static_argnames:
        kw["static_argnames"] = tuple(static_argnames)
    if donate_argnums:
        try:
            return jax.jit(fun, donate_argnums=tuple(donate_argnums), **kw)
        except TypeError:            # pre-donation jit signature
            pass
    return jax.jit(fun, **kw)


def cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` as a single dict on every jax."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
