"""JAX version detection for the `repro.compat` shim layer.

The repo targets the jax>=0.7 mesh/sharding surface (`jax.set_mesh`,
`jax.sharding.AxisType`, `jax.sharding.get_abstract_mesh`,
`jax.shard_map`) but must run on the container's jax 0.4.37, where none
of those exist.  Everything here is plain feature detection: the
`HAS_*` flags answer "does the installed jax expose this symbol?" and
the shims in `mesh.py` / `shardmap.py` branch on them at *call* time,
so tests can monkeypatch a flag (plus a fake API) to exercise the
modern branch on an old jax.

`jax_version_at_least()` is the coarse guard for callers that need a
version-shaped question answered ("is this >= 0.7?") rather than a
single symbol; prefer the feature flags inside this package.
"""

from __future__ import annotations

import jax


def parse_version(text: str) -> tuple:
    """"0.4.37" / "0.7.0.dev20250101" -> (0, 4, 37) / (0, 7, 0)."""
    parts = []
    for token in str(text).split(".")[:3]:
        digits = ""
        for ch in token:
            if not ch.isdigit():
                break
            digits += ch
        if not digits:
            break
        parts.append(int(digits))
    while len(parts) < 3:
        parts.append(0)
    return tuple(parts)


#: (major, minor, patch) of the installed jax.
JAX_VERSION: tuple = parse_version(jax.__version__)


def jax_version_at_least(major, minor: int = 0, patch: int = 0) -> bool:
    """True when the installed jax is >= the given version.

    Accepts either a string (``jax_version_at_least("0.7")``) or
    integer components (``jax_version_at_least(0, 7)``).
    """
    if isinstance(major, str):
        want = parse_version(major)
    else:
        want = (int(major), int(minor), int(patch))
    return JAX_VERSION >= want


# ------------------------------------------------------- feature flags
# Evaluated once at import; the shims read them through the module
# (`version.HAS_SET_MESH`) so monkeypatching redirects dispatch.
HAS_SET_MESH: bool = hasattr(jax, "set_mesh")
HAS_AXIS_TYPE: bool = hasattr(jax.sharding, "AxisType")
HAS_GET_ABSTRACT_MESH: bool = hasattr(jax.sharding, "get_abstract_mesh")
HAS_TOPLEVEL_SHARD_MAP: bool = hasattr(jax, "shard_map")


def describe() -> dict:
    """Diagnostic snapshot of the detected surface (docs/compat.md)."""
    return {
        "jax": jax.__version__,
        "jax_version": JAX_VERSION,
        "set_mesh": HAS_SET_MESH,
        "axis_type": HAS_AXIS_TYPE,
        "get_abstract_mesh": HAS_GET_ABSTRACT_MESH,
        "toplevel_shard_map": HAS_TOPLEVEL_SHARD_MAP,
    }
