"""repro.compat — one version-gated shim layer over the jax API drift.

The reproduction is written against the jax>=0.7 mesh/sharding surface;
the container ships jax 0.4.37.  Every call site that would differ
between the two goes through this package instead of jax directly:

    from repro import compat

    mesh = compat.make_mesh((4, 4), ("data", "model"))   # Auto axes
    with compat.set_mesh(mesh):                          # set_mesh / ctx
        sizes = compat.abstract_axis_sizes()             # {"data": 4, ...}
    fn = compat.shard_map(body, mesh=mesh, in_specs=..., out_specs=...,
                          check_vma=False)               # check_rep on 0.4
    if compat.jax_version_at_least("0.7"):
        ...

See docs/compat.md for the full version matrix.  Dispatch happens at
call time on the `repro.compat.version.HAS_*` feature flags, so tests
monkeypatch a flag plus a fake jax attribute to exercise the modern
branch on an old jax (tests/test_compat.py).
"""

from repro.compat.compilation import cost_analysis, jit_compiled
from repro.compat.mesh import (abstract_axis_sizes, axis_types,
                               get_abstract_mesh, make_mesh, set_mesh)
from repro.compat.runtime import (jax_available, on_tpu, pallas_available,
                                  resolve_backend, resolve_pallas_kernel)
from repro.compat.shardmap import shard_map
from repro.compat.version import (JAX_VERSION, describe,
                                  jax_version_at_least, parse_version)

__all__ = [
    "JAX_VERSION", "jax_version_at_least", "parse_version", "describe",
    "abstract_axis_sizes", "axis_types", "get_abstract_mesh",
    "make_mesh", "set_mesh",
    "shard_map",
    "cost_analysis", "jit_compiled",
    "jax_available", "pallas_available", "resolve_backend",
    "on_tpu", "resolve_pallas_kernel",
]
