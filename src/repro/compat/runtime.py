"""Optional-accelerator feature detection for the simulator backends.

The Dragonfly simulator's ``SimParams.backend = "jax"`` fast path needs
a working jax (and, for the TPU segment-sum kernel, Pallas).  Feature
detection lives here — sibling to the version shims — so the simulator
itself never imports jax at module load and degrades to NumPy cleanly
on containers without a usable accelerator stack (docs/performance.md).
"""

from __future__ import annotations

import warnings

_JAX_OK: bool | None = None
_PALLAS_OK: bool | None = None
_WARNED_FALLBACK = False


def jax_available() -> bool:
    """Can `import jax` and build a trivial jitted function?"""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import jax
            import jax.numpy as jnp

            jax.jit(lambda x: x + 1)(jnp.zeros(()))
            _JAX_OK = True
        except Exception:            # noqa: BLE001 — any failure = absent
            _JAX_OK = False
    return _JAX_OK


def pallas_available() -> bool:
    """Is jax.experimental.pallas importable (TPU kernel path)?"""
    global _PALLAS_OK
    if _PALLAS_OK is None:
        if not jax_available():
            _PALLAS_OK = False
        else:
            try:
                from jax.experimental import pallas  # noqa: F401

                _PALLAS_OK = True
            except Exception:        # noqa: BLE001
                _PALLAS_OK = False
    return _PALLAS_OK


def on_tpu() -> bool:
    """Is the default jax backend a TPU?  False when jax is unusable."""
    if not jax_available():
        return False
    import jax

    return jax.default_backend() == "tpu"


#: SimParams.pallas_kernel knob values (docs/performance.md)
PALLAS_KNOBS = ("auto", "on", "off")


def resolve_pallas_kernel(knob: str) -> bool:
    """Resolve the ``SimParams.pallas_kernel`` knob to use-kernel or not.

    "auto" uses the Pallas segment-sum only where it can win — on TPU
    (interpret-mode Pallas is far slower than jax.ops.segment_sum on
    CPU); "on" forces it everywhere (interpret mode off-TPU — the parity
    testing path); "off" never uses it, even on TPU."""
    if knob == "on":
        return True
    if knob == "off":
        return False
    if knob != "auto":
        raise ValueError(f"unknown pallas_kernel knob {knob!r}; "
                         f"expected one of {PALLAS_KNOBS}")
    return pallas_available() and on_tpu()


def resolve_backend(requested: str) -> str:
    """Map a requested simulator backend to a usable one.

    "numpy" is always usable; "jax" degrades to "numpy" (warning once)
    when jax is missing or broken.  Unknown names raise."""
    if requested == "numpy":
        return "numpy"
    if requested != "jax":
        raise ValueError(f"unknown simulator backend {requested!r}; "
                         f"expected 'numpy' or 'jax'")
    # the jitted pipeline imports the Pallas segment-sum kernel at module
    # load, so a jax without pallas is just as unusable as no jax
    if jax_available() and pallas_available():
        return "jax"
    global _WARNED_FALLBACK
    if not _WARNED_FALLBACK:
        warnings.warn("simulator backend 'jax' unavailable in this "
                      "environment; falling back to 'numpy'",
                      RuntimeWarning, stacklevel=2)
        _WARNED_FALLBACK = True
    return "numpy"
