"""Mesh construction / activation shims.

jax>=0.7 meshes carry per-axis `AxisType`s, are activated with
`jax.set_mesh`, and are observable from anywhere via
`jax.sharding.get_abstract_mesh()`.  jax 0.4.x has none of that: meshes
are typeless, activation is the `Mesh` context manager, and the active
mesh lives in the pxla thread-resources env.  These shims present the
modern surface on both.
"""

from __future__ import annotations

import contextlib

import jax

from repro.compat import version as _v


def axis_types(n: int):
    """(AxisType.Auto,) * n where AxisType exists, else None.

    None means "build the mesh without the kwarg" — Auto is the only
    behaviour jax 0.4.x has, so omission is the faithful fallback.
    """
    if _v.HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """`jax.make_mesh` with Auto axis_types whenever jax knows them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    types = axis_types(len(tuple(axis_names)))
    if types is not None:
        kwargs["axis_types"] = types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """Context manager activating `mesh` (jax.set_mesh / Mesh ctx).

    On jax 0.4.x the `Mesh` context manager is the activation
    primitive: it installs the mesh in the thread-resources env, which
    is what `with_sharding_constraint` and `get_abstract_mesh()` (our
    fallback below) read.
    """
    if _v.HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def get_abstract_mesh():
    """The active mesh, or an empty mesh outside any `set_mesh`.

    jax 0.4.x has no AbstractMesh tracking; the physical mesh from the
    thread-resources env answers the same questions (`axis_names`,
    `shape`) and is accepted by `compat.shard_map`.
    """
    if _v.HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    from jax.interpreters.pxla import thread_resources
    return thread_resources.env.physical_mesh


def abstract_axis_sizes() -> dict:
    """{axis_name: size} of the active mesh ({} outside set_mesh)."""
    try:
        mesh = get_abstract_mesh()
    except Exception:  # pragma: no cover - defensive on exotic versions
        return {}
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return {}
    return {a: mesh.shape[a] for a in mesh.axis_names}
