"""`shard_map` shim.

jax>=0.7 exposes `jax.shard_map(..., check_vma=...)`; jax 0.4.x has
`jax.experimental.shard_map.shard_map(..., check_rep=...)`.  Same
semantics (per-shard replication/varying-mesh-axes checking), renamed
keyword.  All repo call sites go through here with the modern spelling.
"""

from __future__ import annotations

import jax

from repro.compat import version as _v


def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
              check_vma: bool = True):
    """`jax.shard_map` on both jax generations (check_vma == check_rep)."""
    if _v.HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
