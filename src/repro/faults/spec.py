"""Deterministic, seeded fault injection for the Dragonfly simulator.

Real Dragonfly deployments degrade *structurally*, not just through
congestion: links flap, routers die, and telemetry goes stale.  This
module is the declarative substrate — a :class:`FaultSpec` names one
fault (what, which targets, when), a :class:`FaultSchedule` is an
ordered bag of specs, and binding a schedule to a topology yields a
:class:`BoundFaultSchedule` whose ``state_at(phase)`` answers, for any
phase index, "which links are dead, how much capacity survives on the
degraded ones, and whose NIC counters are dark".

Time is *phase-indexed*: faults activate on half-open ``[start, end)``
windows of ``run_phase`` call indices (``end=None`` = forever), and
``link_flap`` toggles with a ``period``/``duty`` square wave inside its
window.  Everything is deterministic — random target draws are resolved
once per (spec, topology) from ``np.random.default_rng(spec.seed)``, so
the same schedule replays bit-identically.

The *fault epoch* counts changes of the active fault set over phases
0..p; the simulator keys its :class:`~repro.dragonfly.simulator.PhasePlan`
cache on it (a plan drawn before a fault must not be replayed across the
epoch boundary), and policy state contaminated by a fault is reset on
epoch transitions.  See docs/faults.md.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

#: recognised FaultSpec kinds
KINDS = ("link_down", "link_degrade", "router_down", "link_flap",
         "counter_dropout")

#: capacity scale at/below which a link counts as dead (exact 0.0 in
#: practice; the epsilon guards float products of stacked degrades)
DEAD_EPS = 1e-9


def random_links(topo, n: int, seed: int, kind: str | None = "global"):
    """Draw ``n`` distinct *physical* link ids from ``topo``, seeded.

    ``kind`` restricts to one ``link_ranges()`` class ("global",
    "local", ...); None — or a kind the topology does not have (e.g.
    "global" on a fattree) — draws from every non-NIC router-router
    link.  Arithmetic slots no physical link occupies (endpoints
    (-1, -1)) are never drawn.
    """
    sr, dr = topo.link_endpoints()
    physical = sr >= 0                      # router-router links only
    if kind is not None and kind not in topo.link_ranges():
        kind = None
    if kind is not None:
        lo, hi = topo.link_ranges()[kind]
        in_kind = np.zeros(topo.n_links, dtype=bool)
        in_kind[lo:hi] = True
        physical &= in_kind
    pool = np.flatnonzero(physical)
    if pool.size == 0:
        return ()
    rng = np.random.default_rng(seed)
    pick = rng.choice(pool, size=min(n, pool.size), replace=False)
    return tuple(int(x) for x in np.sort(pick))


def random_routers(topo, n: int, seed: int):
    """Draw ``n`` distinct router ids, seeded."""
    rng = np.random.default_rng(seed)
    pick = rng.choice(topo.n_routers, size=min(n, int(topo.n_routers)),
                      replace=False)
    return tuple(int(x) for x in np.sort(pick))


@dataclass(frozen=True)
class FaultSpec:
    """One fault: what breaks, which targets, and when.

    kind            one of :data:`KINDS`
    start, end      half-open active phase window [start, end);
                    ``end=None`` means the fault never clears
    links           explicit link ids (link_* kinds)
    routers         explicit router ids (router_down)
    capacity_frac   surviving capacity fraction (link_degrade; 0 < f < 1)
    period, duty    link_flap square wave: within the window the links
                    are DOWN for ``duty`` phases out of every ``period``
    allocations     counter_dropout scope: allocation ids whose NIC
                    counters stop arriving ("*" = every allocation)
    n_random        additionally draw this many random targets from the
                    topology at bind time (global links, or routers for
                    router_down), seeded by ``seed``
    link_kind       link_ranges() class the random draw samples from
    seed            RNG seed of the random target draw
    """

    kind: str
    start: int = 0
    end: int | None = None
    links: tuple = ()
    routers: tuple = ()
    capacity_frac: float = 0.0
    period: int = 2
    duty: int = 1
    allocations: tuple = ("*",)
    n_random: int = 0
    link_kind: str | None = "global"
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.end is not None and self.end <= self.start:
            raise ValueError("fault window is empty (end <= start)")
        if self.kind == "link_degrade" and not (
                0.0 < self.capacity_frac < 1.0):
            raise ValueError("link_degrade needs 0 < capacity_frac < 1")
        if self.kind == "link_flap" and not (
                1 <= self.duty <= self.period):
            raise ValueError("link_flap needs 1 <= duty <= period")

    def active_at(self, phase: int) -> bool:
        """Is this fault active at ``phase``?  (flap-aware)"""
        if phase < self.start:
            return False
        if self.end is not None and phase >= self.end:
            return False
        if self.kind == "link_flap":
            return (phase - self.start) % self.period < self.duty
        return True

    def describe(self) -> dict:
        """JSON-able summary (benchmark records, docs)."""
        d = {"kind": self.kind, "start": self.start, "end": self.end}
        if self.kind == "router_down":
            d["routers"] = list(self.routers)
        elif self.kind == "counter_dropout":
            d["allocations"] = list(self.allocations)
        else:
            d["links"] = list(self.links)
        if self.kind == "link_degrade":
            d["capacity_frac"] = self.capacity_frac
        if self.kind == "link_flap":
            d["period"], d["duty"] = self.period, self.duty
        if self.n_random:
            d["n_random"] = self.n_random
            d["seed"] = self.seed
        return d


# ----------------------------------------------------- spec constructors
def link_down(links=(), *, start=0, end=None, n_random=0,
              link_kind="global", seed=0) -> FaultSpec:
    """Hard link failure: zero capacity, paths crossing it are masked."""
    return FaultSpec("link_down", start=start, end=end,
                     links=tuple(links), n_random=n_random,
                     link_kind=link_kind, seed=seed)


def link_degrade(capacity_frac: float, links=(), *, start=0, end=None,
                 n_random=0, link_kind="global", seed=0) -> FaultSpec:
    """Soft failure: the links survive at ``capacity_frac`` capacity."""
    return FaultSpec("link_degrade", start=start, end=end,
                     links=tuple(links), capacity_frac=capacity_frac,
                     n_random=n_random, link_kind=link_kind, seed=seed)


def router_down(routers=(), *, start=0, end=None, n_random=0,
                seed=0) -> FaultSpec:
    """Whole-router failure: every incident link (including the NIC
    links of its hosted nodes) goes dead, and — through
    repro.faults.detection — its nodes stop heartbeating."""
    return FaultSpec("router_down", start=start, end=end,
                     routers=tuple(routers), n_random=n_random, seed=seed)


def link_flap(links=(), *, start=0, end=None, period=2, duty=1,
              n_random=0, link_kind="global", seed=0) -> FaultSpec:
    """Flapping link: inside [start, end) the links cycle DOWN for
    ``duty`` phases out of every ``period``."""
    return FaultSpec("link_flap", start=start, end=end,
                     links=tuple(links), period=period, duty=duty,
                     n_random=n_random, link_kind=link_kind, seed=seed)


def counter_dropout(allocations=("*",), *, start=0, end=None) -> FaultSpec:
    """Telemetry fault: the allocations' NIC counters stop arriving
    (no ``NICCounters.observe`` — readers see a frozen snapshot, and
    the PolicyEngine staleness guard eventually trips)."""
    return FaultSpec("counter_dropout", start=start, end=end,
                     allocations=tuple(allocations))


@dataclass(frozen=True)
class FaultState:
    """Resolved machine state for one phase (one active fault set).

    capacity_scale  float64 [n_links]: 1.0 healthy, (0, 1) degraded,
                    0.0 dead.  Shared read-only across phases with the
                    same active set — do not mutate.
    dead            bool [n_links] (capacity_scale <= DEAD_EPS)
    down_routers    router ids currently down
    counters_dark   allocation ids with counter dropout ("*" = all)
    epoch           fault epoch at this phase (see module docstring)
    """

    epoch: int
    capacity_scale: np.ndarray
    dead: np.ndarray
    down_routers: tuple = ()
    counters_dark: frozenset = frozenset()

    @property
    def any_dead(self) -> bool:
        return bool(self.dead.any())

    def counters_blocked(self, allocation_id: str) -> bool:
        """Is this allocation's NIC telemetry dark right now?"""
        return "*" in self.counters_dark \
            or allocation_id in self.counters_dark


@dataclass(frozen=True)
class FaultSchedule:
    """An ordered, topology-independent bag of :class:`FaultSpec`.

    Falsy when empty — ``FaultSchedule()`` is the explicit "no faults"
    schedule, and the simulator guarantees bit-identical output with it
    (tests/test_faults.py)."""

    specs: tuple = ()

    @staticmethod
    def of(*specs) -> "FaultSchedule":
        return FaultSchedule(tuple(specs))

    def __bool__(self) -> bool:
        return bool(self.specs)

    def __len__(self) -> int:
        return len(self.specs)

    def bind(self, topo) -> "BoundFaultSchedule":
        """Resolve random targets against ``topo`` and return the
        phase-queryable bound schedule."""
        return BoundFaultSchedule(self, topo)

    def first_start(self) -> int | None:
        """Earliest phase any fault activates (None when empty)."""
        return min((s.start for s in self.specs), default=None)

    def all_clear_phase(self) -> int | None:
        """First phase at/after which every fault has cleared, or None
        when empty / when some fault never ends."""
        if not self.specs:
            return None
        ends = [s.end for s in self.specs]
        return None if any(e is None for e in ends) else max(ends)

    def describe(self) -> list:
        return [s.describe() for s in self.specs]


class BoundFaultSchedule:
    """A :class:`FaultSchedule` resolved against one topology.

    ``state_at(phase)`` returns the :class:`FaultState` for that phase,
    or None when no fault is active (the simulator's exact-fast-path
    guarantee hangs on that None).  ``epoch_at(phase)`` counts active-set
    changes over phases 0..phase; both walk forward incrementally and
    memoise, so sequential queries are O(1) amortised.
    """

    def __init__(self, schedule: FaultSchedule, topo):
        self.schedule = schedule
        self.topo = topo
        n = topo.n_links
        for spec in schedule.specs:
            bad = [l for l in spec.links if not 0 <= l < n]
            if bad:
                raise ValueError(f"link ids {bad} out of range for "
                                 f"{topo.spec_str()} (n_links={n})")
            badr = [r for r in spec.routers
                    if not 0 <= r < int(topo.n_routers)]
            if badr:
                raise ValueError(f"router ids {badr} out of range for "
                                 f"{topo.spec_str()}")
        self._resolved = [self._resolve(s) for s in schedule.specs]
        self._keys: list = []       # phase -> active spec-index tuple
        self._epochs: list = []     # phase -> epoch
        self._states: dict = {}     # active key -> FaultState sans epoch

    # ------------------------------------------------------------ resolve
    def _resolve(self, spec: FaultSpec):
        """(link_ids int64[], router_ids tuple) for one spec, with
        random targets drawn once from the spec's own seed."""
        topo = self.topo
        routers = tuple(spec.routers)
        links = list(spec.links)
        if spec.n_random:
            if spec.kind == "router_down":
                routers = tuple(sorted(set(routers) | set(
                    random_routers(topo, spec.n_random, spec.seed))))
            else:
                links += list(random_links(topo, spec.n_random, spec.seed,
                                           kind=spec.link_kind))
        if spec.kind == "router_down" and routers:
            sr, dr = topo.link_endpoints()
            down = np.zeros(topo.n_links, dtype=bool)
            for r in routers:
                # router-router links either way, plus NIC links
                # (src == -1, dst == router) of its hosted nodes
                down |= (sr == r) | (dr == r)
            links = list(np.flatnonzero(down))
        return np.asarray(sorted(set(int(l) for l in links)),
                          dtype=np.int64), routers

    # ------------------------------------------------------------- queries
    def _advance_to(self, phase: int) -> None:
        while len(self._keys) <= phase:
            ph = len(self._keys)
            key = tuple(i for i, s in enumerate(self.schedule.specs)
                        if s.active_at(ph))
            prev = self._keys[-1] if self._keys else ()
            prev_ep = self._epochs[-1] if self._epochs else 0
            self._keys.append(key)
            self._epochs.append(prev_ep + (1 if key != prev and ph > 0
                                           else 0))

    def epoch_at(self, phase: int) -> int:
        """Fault epoch at ``phase`` (0 until the first active-set
        change; +1 on every activation/deactivation/flap toggle)."""
        self._advance_to(phase)
        return self._epochs[phase]

    def state_at(self, phase: int) -> FaultState | None:
        """The resolved machine state at ``phase``; None = healthy."""
        self._advance_to(phase)
        key = self._keys[phase]
        if not key:
            return None
        cached = self._states.get(key)
        if cached is None:
            scale = np.ones(self.topo.n_links, dtype=np.float64)
            down_routers: set = set()
            dark: set = set()
            for i in key:
                spec = self.schedule.specs[i]
                links, routers = self._resolved[i]
                if spec.kind == "link_degrade":
                    scale[links] *= spec.capacity_frac
                elif spec.kind in ("link_down", "link_flap",
                                   "router_down"):
                    scale[links] = 0.0
                    down_routers.update(routers)
                elif spec.kind == "counter_dropout":
                    dark.update(spec.allocations)
            cached = self._states[key] = FaultState(
                epoch=0, capacity_scale=scale,
                dead=scale <= DEAD_EPS,
                down_routers=tuple(sorted(down_routers)),
                counters_dark=frozenset(dark))
        ep = self._epochs[phase]
        return cached if cached.epoch == ep else replace(cached, epoch=ep)

    def down_nodes_at(self, phase: int) -> np.ndarray:
        """int64 node ids unreachable at ``phase``: nodes hosted on a
        down router or whose NIC link is dead (detection front end)."""
        state = self.state_at(phase)
        topo = self.topo
        if state is None:
            return np.empty(0, dtype=np.int64)
        nodes = np.arange(topo.n_nodes, dtype=np.int64)
        bad = state.dead[np.asarray(topo.nic_link(nodes))]
        if state.down_routers:
            bad |= np.isin(np.asarray(topo.router_of_node(nodes)),
                           np.asarray(state.down_routers))
        return nodes[bad]
