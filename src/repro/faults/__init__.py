"""repro.faults — deterministic fault injection for the Dragonfly stack.

Declarative :class:`FaultSpec`/:class:`FaultSchedule` (docs/faults.md)
with phase-indexed activation windows, bound to a topology for
per-phase machine state, plus the heartbeat-driven detection front end
over ``runtime.fault_tolerance``.
"""

from repro.faults.detection import (DetectionReport, HeartbeatDriver,
                                    remap_allocation)
from repro.faults.spec import (BoundFaultSchedule, FaultSchedule, FaultSpec,
                               FaultState, counter_dropout, link_degrade,
                               link_down, link_flap, random_links,
                               random_routers, router_down)

__all__ = [
    "FaultSpec", "FaultSchedule", "BoundFaultSchedule", "FaultState",
    "link_down", "link_degrade", "router_down", "link_flap",
    "counter_dropout", "random_links", "random_routers",
    "HeartbeatDriver", "DetectionReport", "remap_allocation",
]
