"""Fault detection front end: heartbeats driven by the fault schedule.

``runtime.fault_tolerance`` ships a phi-accrual :class:`HeartbeatMonitor`
and a :class:`RestartPolicy` that were tested but wired to nothing.
This module closes the loop against :mod:`repro.faults.spec`:

  * :class:`HeartbeatDriver` ticks the monitor once per phase —
    ``router_down`` (and dead NIC links) *suppress* the affected nodes'
    heartbeats, so after enough silent phases phi-accrual flags them
    DEAD without any oracle channel from the injector to the detector;
  * when the restart policy answers ``ELASTIC_SHRINK``, the allocation
    is re-materialised from the unused-node pool
    (:func:`remap_allocation`): dead ranks move to healthy free nodes,
    and only when the pool runs dry does the job truly shrink.

Everything is deterministic given the schedule and seed.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.dragonfly.topology import Allocation
from repro.faults.spec import BoundFaultSchedule
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           HeartbeatMonitor, RestartAction,
                                           RestartPolicy)


def remap_allocation(topo, allocation: Allocation, dead_nodes, *,
                     down_nodes=(), used_nodes=(), seed: int = 0,
                     tag: str = "remap") -> Allocation:
    """Re-materialise ``allocation`` with its dead ranks moved onto
    healthy nodes from the unused pool.

    The pool is every machine node minus the allocation itself, minus
    ``used_nodes`` (other tenants), minus ``down_nodes`` (nodes the
    fault schedule currently makes unreachable — replacements must not
    land on a dead router).  Replacement nodes are drawn seeded; when
    the pool is smaller than the number of dead ranks the remainder is
    dropped (a true elastic shrink).  Rank order of surviving nodes is
    preserved.
    """
    dead = set(int(n) for n in dead_nodes)
    if not dead:
        return allocation
    blocked = set(int(n) for n in allocation.nodes)
    blocked |= set(int(n) for n in used_nodes)
    blocked |= set(int(n) for n in down_nodes)
    pool = np.setdiff1d(np.arange(topo.n_nodes, dtype=np.int64),
                        np.asarray(sorted(blocked), dtype=np.int64))
    rng = np.random.default_rng(seed)
    take = min(len(dead), int(pool.size))
    repl = list(rng.choice(pool, size=take, replace=False)) if take else []
    nodes = []
    for n in allocation.nodes:
        if int(n) in dead:
            if repl:
                nodes.append(int(repl.pop(0)))
            # else: pool exhausted — drop the rank (shrink)
        else:
            nodes.append(int(n))
    return Allocation(
        allocation_id=f"{allocation.allocation_id}@{tag}",
        nodes=tuple(nodes))


@dataclass
class DetectionReport:
    """One ``poll`` outcome: what died, what the policy decided, and the
    (possibly re-materialised) allocation going forward."""

    phase: int
    dead_nodes: tuple
    action: RestartAction
    allocation: Allocation


class HeartbeatDriver:
    """Drives phi-accrual detection from the bound fault schedule.

    One driver watches one allocation.  Call :meth:`tick` once per
    phase: healthy nodes heartbeat, nodes silenced by the schedule
    (down router / dead NIC link) do not.  :meth:`poll` asks the
    monitor for dead nodes and turns the restart policy's answer into a
    concrete allocation — ``RESTART_IN_PLACE`` keeps the node set
    (spare swaps in on the same slot), ``ELASTIC_SHRINK``
    re-materialises via :func:`remap_allocation`.
    """

    def __init__(self, bound: BoundFaultSchedule, allocation: Allocation,
                 cfg: FaultToleranceConfig | None = None, *,
                 spares: int = 0, phase_duration_s: float | None = None,
                 seed: int = 0):
        self.bound = bound
        self.topo = bound.topo
        self.allocation = allocation
        self.cfg = cfg or FaultToleranceConfig()
        # default cadence: one heartbeat per phase
        self.phase_duration_s = (phase_duration_s
                                 if phase_duration_s is not None
                                 else self.cfg.heartbeat_interval_s)
        self.monitor = HeartbeatMonitor(allocation.nodes, self.cfg,
                                        now_s=0.0)
        self.restart = RestartPolicy(self.cfg, spares_available=spares)
        self.seed = seed
        self.now_s = 0.0
        self._remaps = 0

    def tick(self, phase: int) -> tuple:
        """Advance one phase: every reachable node heartbeats, nodes the
        schedule silences stay quiet.  Returns the silenced node ids."""
        self.now_s += self.phase_duration_s
        down = set(int(n) for n in self.bound.down_nodes_at(phase))
        for node in self.allocation.nodes:
            if int(node) not in down:
                self.monitor.heartbeat(node, self.now_s)
        return tuple(sorted(down & set(int(n)
                                       for n in self.allocation.nodes)))

    def poll(self, phase: int, *, used_nodes=()) -> DetectionReport:
        """Detect, decide, and (for ELASTIC_SHRINK) re-materialise."""
        dead = [n for n in self.monitor.dead_nodes(self.now_s)
                if n in self.allocation.nodes]
        action = self.restart.on_failure(dead, self.now_s)
        alloc = self.allocation
        if action == RestartAction.ELASTIC_SHRINK:
            self._remaps += 1
            alloc = remap_allocation(
                self.topo, alloc, dead,
                down_nodes=self.bound.down_nodes_at(phase),
                used_nodes=used_nodes,
                seed=self.seed + self._remaps,
                tag=f"remap{self._remaps}")
            self.allocation = alloc
            # fresh slate for the re-materialised node set
            self.monitor = HeartbeatMonitor(alloc.nodes, self.cfg,
                                            now_s=self.now_s)
        return DetectionReport(phase=phase, dead_nodes=tuple(dead),
                               action=action, allocation=alloc)
