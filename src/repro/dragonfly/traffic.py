"""Communication patterns of the paper's microbenchmarks (§5.1) and the
benchmark runner that alternates routing modes per iteration (§5 protocol).

A pattern is a generator of *phases*; one phase is a (src_ranks, dst_ranks,
bytes) triple of concurrent flows.  Rank->node resolution happens against a
fixed Allocation (§3.1).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.strategies import RoutingMode
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.simulator import DragonflySimulator, FlowResult
from repro.dragonfly.topology import Allocation
from repro.policy import (AppAwareConfig, DecisionBatch, KIND_ALLREDUCE,
                          KIND_ALLTOALL, KIND_BROADCAST, KIND_PT2PT,
                          PolicyEngine, TelemetryBus, make_engine)

Phase = tuple[np.ndarray, np.ndarray, np.ndarray]  # (src_ranks, dst_ranks, bytes)


# --------------------------------------------------------------- primitives
def _phase(srcs, dsts, size) -> Phase:
    s = np.asarray(srcs, dtype=np.int64)
    d = np.asarray(dsts, dtype=np.int64)
    b = np.full(s.shape, float(size)) if np.isscalar(size) \
        else np.asarray(size, dtype=np.float64)
    return s, d, b


def pingpong(n_ranks: int, size: int) -> list[Phase]:
    assert n_ranks >= 2
    return [_phase([0], [1], size), _phase([1], [0], size)]


def allreduce(n_ranks: int, elements: int, elem_bytes: int = 4) -> list[Phase]:
    """Recursive-doubling allreduce (size constant per round)."""
    size = elements * elem_bytes
    rounds = max(1, int(math.ceil(math.log2(max(n_ranks, 2)))))
    phases = []
    for r in range(rounds):
        stride = 1 << r
        ranks = np.arange(n_ranks)
        peers = ranks ^ stride
        ok = peers < n_ranks
        phases.append(_phase(ranks[ok], peers[ok], size))
    return phases


def alltoall(n_ranks: int, size_per_pair: int) -> list[Phase]:
    """Single bulk phase with all n*(n-1) pairwise flows (packet-level
    alltoall; the NIC pipelines all destinations concurrently)."""
    ranks = np.arange(n_ranks)
    src = np.repeat(ranks, n_ranks - 1)
    dst = np.concatenate([np.delete(ranks, i) for i in range(n_ranks)])
    return [_phase(src, dst, size_per_pair)]


def barrier(n_ranks: int, _size: int = 8) -> list[Phase]:
    """Dissemination barrier: ceil(log2 n) rounds of 8-byte tokens."""
    rounds = max(1, int(math.ceil(math.log2(max(n_ranks, 2)))))
    phases = []
    ranks = np.arange(n_ranks)
    for r in range(rounds):
        peers = (ranks + (1 << r)) % n_ranks
        phases.append(_phase(ranks, peers, 8))
    return phases


def broadcast(n_ranks: int, size: int) -> list[Phase]:
    """Binomial-tree broadcast from rank 0."""
    phases = []
    have = 1
    while have < n_ranks:
        senders = np.arange(min(have, n_ranks - have))
        receivers = senders + have
        receivers = receivers[receivers < n_ranks]
        senders = senders[: len(receivers)]
        phases.append(_phase(senders, receivers, size))
        have *= 2
    return phases


def _grid_dims(n: int, dims: int) -> list[int]:
    """Near-cubic factorization of n into `dims` factors (MPI_Dims_create)."""
    out = [1] * dims
    f = n
    primes = []
    d = 2
    while d * d <= f:
        while f % d == 0:
            primes.append(d)
            f //= d
        d += 1
    if f > 1:
        primes.append(f)
    for prm in sorted(primes, reverse=True):
        out[out.index(min(out))] *= prm
    return sorted(out, reverse=True)


def halo3d(n_ranks: int, nx: int, var_bytes: int = 8,
           vars_: int = 1) -> list[Phase]:
    """Nearest-neighbor 3D stencil (ember halo3d): 6 face exchanges.

    nx is the global cubic domain edge; each rank owns (nx/px, nx/py, nx/pz)
    and exchanges faces with +-x, +-y, +-z neighbors."""
    px, py, pz = _grid_dims(n_ranks, 3)
    lx, ly, lz = nx // px, nx // py, nx // pz
    face = {0: ly * lz, 1: lx * lz, 2: lx * ly}
    ranks = np.arange(n_ranks)
    z, rem = np.divmod(ranks, px * py)
    y, x = np.divmod(rem, px)
    coords = [x, y, z]
    dims = [px, py, pz]
    phases = []
    for axis in range(3):
        for sign in (+1, -1):
            nb = [c.copy() for c in coords]
            nb[axis] = coords[axis] + sign
            ok = (nb[axis] >= 0) & (nb[axis] < dims[axis])
            dst = nb[0] + nb[1] * px + nb[2] * px * py
            size = face[axis] * var_bytes * vars_
            phases.append(_phase(ranks[ok], dst[ok], size))
    return phases


def sweep3d(n_ranks: int, nx: int, var_bytes: int = 8) -> list[Phase]:
    """Wavefront sweep (ember sweep3d): 2D process grid (px, py), the
    wavefront starts at a corner and pipelines +x then +y pencils."""
    px, py = _grid_dims(n_ranks, 2)
    lx, ly = nx // px, nx // py
    pencil = lx * var_bytes * max(nx // max(px, py), 1)
    phases = []
    for wave in range(px + py - 1):
        srcs, dsts = [], []
        for i in range(px):
            j = wave - i
            if 0 <= j < py:
                if i + 1 < px:
                    srcs.append(i + j * px)
                    dsts.append((i + 1) + j * px)
                if j + 1 < py:
                    srcs.append(i + j * px)
                    dsts.append(i + (j + 1) * px)
        if srcs:
            phases.append(_phase(srcs, dsts, pencil))
    del ly
    return phases


def moe_alltoall(n_ranks: int, tokens_per_rank: int = 4096,
                 token_bytes: int = 2048, zipf_alpha: float = 1.0,
                 seed: int = 0) -> list[Phase]:
    """Expert-parallel MoE dispatch/combine: a SKEWED all-to-all.

    The EP layer (repro.collectives.moe_ep) routes each token to its
    top-1 expert, one expert shard per rank; router logits are never
    uniform, so hot experts concentrate traffic — the rank-level
    byte matrix is an alltoall whose columns follow a Zipf popularity
    curve instead of a constant.  Two bulk phases per layer step:
    dispatch (token -> expert) and combine (the mirror transpose).
    `token_bytes` is one token's hidden activation (d_model * bf16).
    Seeded and deterministic: the popularity permutation is drawn once
    from `seed`, like the EP router's frozen gate."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(n_ranks)
    pop = 1.0 / np.power(ranks + 1.0, zipf_alpha)
    pop = rng.permutation(pop / pop.sum())       # expert popularity [n]
    src = np.repeat(ranks, n_ranks - 1)
    dst = np.concatenate([np.delete(ranks, i) for i in range(n_ranks)])
    # tokens_per_rank * P(expert at dst) bytes from every sender, floored
    # at one token so no pair degenerates to zero
    size = np.maximum(tokens_per_rank * pop[dst], 1.0) * token_bytes
    dispatch = _phase(src, dst, size)
    combine = _phase(dst, src, size)
    return [dispatch, combine]


PATTERNS: dict[str, Callable[..., list[Phase]]] = {
    "pingpong": pingpong,
    "allreduce": allreduce,
    "alltoall": alltoall,
    "barrier": barrier,
    "broadcast": broadcast,
    "halo3d": halo3d,
    "sweep3d": sweep3d,
    "moe_alltoall": moe_alltoall,
}


# ------------------------------------------------------------------ running
@dataclass
class IterationResult:
    time_us: float
    mean_latency_us: float
    mean_stalls: float
    nonmin_fraction: float
    mode_bytes: dict = field(default_factory=dict)


def run_iteration(sim: DragonflySimulator, alloc: Allocation,
                  phases: Sequence[Phase],
                  policy: RoutingPolicy, *,
                  use_plans: bool = False) -> IterationResult:
    """One benchmark iteration under a fixed routing mode.

    `use_plans=True` routes each phase through the simulator's
    content-addressed PhasePlan cache, so iteration loops stop redrawing
    candidate paths for identical traffic (see the reuse contract in
    docs/performance.md — seeded-deterministic, but a different RNG
    consumption than planless runs)."""
    total_us = 0.0
    lat, st, nmf, wts = [], [], [], []
    host_rng = sim.rng
    for (s, d, b) in phases:
        nodes = np.asarray(alloc.nodes)
        plan = sim.plan_for(nodes[s], nodes[d], b) if use_plans else None
        res = sim.run_phase(nodes[s], nodes[d], b, policy, alloc,
                            plan=plan)
        host = sim.params.host_overhead_us * host_rng.lognormal(
            0.0, sim.params.host_noise_sigma)
        total_us += res.phase_time_us + host
        if res.t_us.size:
            lat.append(res.latency_us.mean())
            st.append(res.stalls_per_flit.mean())
            nmf.append(res.nonmin_fraction)
            wts.append(b.sum())
    w = np.asarray(wts) if wts else np.ones(1)
    return IterationResult(
        time_us=total_us,
        mean_latency_us=float(np.average(lat, weights=w)) if lat else 0.0,
        mean_stalls=float(np.average(st, weights=w)) if st else 0.0,
        nonmin_fraction=float(np.average(nmf, weights=w)) if nmf else 0.0,
    )


#: pattern name -> DecisionBatch collective kind (Algorithm 1 only
#: special-cases alltoall; the rest is labeling for telemetry/policies).
PATTERN_KIND = {
    "pingpong": KIND_PT2PT,
    "allreduce": KIND_ALLREDUCE,
    "alltoall": KIND_ALLTOALL,
    "barrier": KIND_PT2PT,
    "broadcast": KIND_BROADCAST,
    "halo3d": KIND_PT2PT,
    "sweep3d": KIND_PT2PT,
    "moe_alltoall": KIND_ALLTOALL,
}


def run_iteration_engine(sim: DragonflySimulator, alloc: Allocation,
                         phases: Sequence[Phase], engine: PolicyEngine, *,
                         site: str = "default", kind: str = KIND_PT2PT,
                         base_policy: RoutingPolicy | None = None,
                         counter_read_overhead_us: float = 0.35,
                         use_plans: bool = False
                         ) -> IterationResult:
    """One iteration with a PolicyEngine choosing modes per phase.

    This is the vectorized successor of the per-message router protocol:
    ONE engine.decide() per phase (thousands of flows in a single
    NumPy-shaped batch), modes applied per flow inside the simulator, and
    one TelemetryBus publish of the phase's per-flow (L, s) — the
    counters are read after the send, so the policy stays one phase
    behind (paper §4.3), paying the same §5.1 counter-read overhead."""
    base_policy = base_policy or RoutingPolicy(RoutingMode.ADAPTIVE_0)
    total_us = 0.0
    lat, st, nmf, wts = [], [], [], []
    mode_bytes: dict = {}
    nodes = np.asarray(alloc.nodes)
    for (s, d, b) in phases:
        batch = DecisionBatch.of(b, site=site, kind=kind)
        modes = engine.decide(batch)          # ONE call for the whole phase
        plan = sim.plan_for(nodes[s], nodes[d], b) if use_plans else None
        res = sim.run_phase(nodes[s], nodes[d], b, base_policy, alloc,
                            modes=modes, plan=plan)
        # post-send counter read (never delays the message itself)
        if res.t_us.size == len(batch):
            engine.bus.publish_flow_arrays(res.latency_us,
                                           res.stalls_per_flit,
                                           notified=res.notified)
        elif res.t_us.size:
            # the simulator statistically subsampled the phase: publish
            # the phase-mean sample (engine broadcasts it over the batch)
            engine.bus.publish_flow_arrays(
                [float(res.latency_us.mean())],
                [float(res.stalls_per_flit.mean())],
                notified=None if res.notified is None
                else [float(res.notified.mean())])
        host = sim.params.host_overhead_us * sim.rng.lognormal(
            0.0, sim.params.host_noise_sigma) + counter_read_overhead_us
        total_us += res.phase_time_us + host
        for mode in {m for m in modes}:
            mode_bytes[mode] = mode_bytes.get(mode, 0.0) \
                + float(b[modes == mode].sum())
        if res.t_us.size:
            lat.append(res.latency_us.mean())
            st.append(res.stalls_per_flit.mean())
            nmf.append(res.nonmin_fraction)
            wts.append(b.sum())
    w = np.asarray(wts) if wts else np.ones(1)
    return IterationResult(
        time_us=total_us,
        mean_latency_us=float(np.average(lat, weights=w)) if lat else 0.0,
        mean_stalls=float(np.average(st, weights=w)) if st else 0.0,
        nonmin_fraction=float(np.average(nmf, weights=w)) if nmf else 0.0,
        mode_bytes=mode_bytes,
    )


def run_iteration_app_aware(sim: DragonflySimulator, alloc: Allocation,
                            phases: Sequence[Phase],
                            router, *,
                            alltoall_site: bool = False,
                            counter_read_overhead_us: float = 0.35
                            ) -> IterationResult:
    """DEPRECATED: one iteration with the legacy scalar router protocol.

    Kept for the seed API; new code should pass a PolicyEngine to
    run_iteration_engine.  The router selects before each phase using the
    *previous* phase's counters (the paper's one-message-behind protocol)
    and pays a small counter-read overhead (§5.1 observes this overhead
    on 1KiB alltoalls)."""
    total_us = 0.0
    lat, st, nmf, wts = [], [], [], []
    mode_bytes: dict = {}
    for (s, d, b) in phases:
        msg = float(b.max()) if b.size else 0.0
        mode = router.select(int(msg), alltoall=alltoall_site)
        policy = RoutingPolicy(mode)
        nodes = np.asarray(alloc.nodes)
        res = sim.run_phase(nodes[s], nodes[d], b, policy, alloc)
        # post-send counter read (never delays the message itself)
        if res.t_us.size:
            router.observe(res.latency_us.mean() * 1e3 *
                           sim.params.nic_clock_ghz,
                           res.stalls_per_flit.mean())
        host = sim.params.host_overhead_us * sim.rng.lognormal(
            0.0, sim.params.host_noise_sigma) + counter_read_overhead_us
        total_us += res.phase_time_us + host
        mode_bytes[mode] = mode_bytes.get(mode, 0.0) + float(b.sum())
        if res.t_us.size:
            lat.append(res.latency_us.mean())
            st.append(res.stalls_per_flit.mean())
            nmf.append(res.nonmin_fraction)
            wts.append(b.sum())
    w = np.asarray(wts) if wts else np.ones(1)
    return IterationResult(
        time_us=total_us,
        mean_latency_us=float(np.average(lat, weights=w)) if lat else 0.0,
        mean_stalls=float(np.average(st, weights=w)) if st else 0.0,
        nonmin_fraction=float(np.average(nmf, weights=w)) if nmf else 0.0,
        mode_bytes=mode_bytes,
    )


def engine_for_arm(arm: str, sim: DragonflySimulator,
                   router_config: AppAwareConfig | None = None,
                   seed: int = 0) -> PolicyEngine:
    """Build the PolicyEngine for one adaptive benchmark arm
    ("app_aware" | "eps_greedy" | "static"), clocked to the simulator."""
    bus = TelemetryBus(clock_ghz=sim.params.nic_clock_ghz)
    return make_engine(arm, config=router_config, granularity="phase",
                       seed=seed, bus=bus)


def run_benchmark(sim: DragonflySimulator, alloc: Allocation, pattern: str,
                  pattern_args: dict, iterations: int,
                  modes: Iterable = (RoutingMode.ADAPTIVE_0,
                                     RoutingMode.ADAPTIVE_3, "app_aware"),
                  router_config: AppAwareConfig | None = None,
                  use_plans: bool = False) -> dict:
    """Paper §5 protocol: alternate routing strategies on successive
    iterations inside ONE allocation, so transient noise hits all modes
    equally.  Returns {mode: [IterationResult, ...]}.

    `modes` entries are RoutingMode members (static arms) or policy
    names from repro.policy ("app_aware", "eps_greedy", "static") — each
    named arm gets its own PolicyEngine whose state persists across the
    alternating iterations, exactly like the paper's long-running
    application."""
    phases = PATTERNS[pattern](alloc.n_ranks, **pattern_args)
    kind = PATTERN_KIND.get(pattern, KIND_PT2PT)
    results: dict = {m: [] for m in modes}
    engines = {m: engine_for_arm(m, sim, router_config)
               for m in modes if isinstance(m, str)}
    for _ in range(iterations):
        for mode in modes:
            if isinstance(mode, str):
                results[mode].append(run_iteration_engine(
                    sim, alloc, phases, engines[mode],
                    site=pattern, kind=kind, use_plans=use_plans))
            else:
                results[mode].append(run_iteration(
                    sim, alloc, phases, RoutingPolicy(mode),
                    use_plans=use_plans))
    return results
