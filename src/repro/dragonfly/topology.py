"""Cray Aries Dragonfly topology — paper §2.1.

Connectivity tiers (Aries/Cascade):
  * group: 6 chassis x 16 blades; each blade has one Aries router + 4 nodes;
  * intra-chassis: every router connects to the other 15 in its chassis
    (15 tiles);
  * intra-group "row" links: every router connects to the 5 routers in the
    same blade slot of the other chassis (3 tiles per connection);
  * inter-group: up to 10 optical links per router; systems bundle several
    tiles per group pair.  We expose `global_links_per_pair` parallel links
    per group pair, attached to deterministic (chassis, blade) gateway slots.

Link ids are arithmetic so the simulator can vectorize over flows:
  [0, n_chassis_links)                 chassis links  (g, c, min(b), max(b))
  [+0, n_row_links)                    row links      (g, min(c), max(c), b)
  [+0, n_global_links)                 global links   (min(g), max(g), k)
  [+0, n_nodes)                        NIC injection links (one per node)

A *path* is a sequence of link ids (NIC link excluded; the simulator charges
injection separately).  Minimal inter-group paths have <= 5 router-router
hops, matching Figure 1's 5-hop example; non-minimal (Valiant) paths go
through an intermediate group and have <= 8 hops (10 on the largest systems
per §2.2 — we cap per topology size).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

PAD = -1  # path padding entry


def balanced_global_count(a: int, h: int) -> int:
    """The balanced-dragonfly rule ``g = a*h + 1`` (every pair of groups
    gets exactly one global link when each of the `a` routers per group
    owns `h` global ports)."""
    return a * h + 1


class Topology:
    """Abstract base of the topology family (docs/topology.md).

    A concrete topology is a router graph with arithmetic DIRECTED link
    ids plus one NIC injection link per node, exposing exactly the
    surface the simulator, allocations, and the invariant harness
    consume.  Subclasses set in ``__init__``:

      n_links, n_nodes, n_routers, n_groups   sizes
      nodes_per_group                         node ids are contiguous per
                                              group: node // nodes_per_group
                                              is its group
      nodes_per_router, n_node_routers        node ids are contiguous per
                                              node-hosting router
      capacity_gbs                            float64 [n_links], GB/s/dir
      hop_latency_ns, nic_latency_ns          fixed per-hop / NIC latency
      max_minimal_hops, max_nonmin_hops       hop bounds checked by
                                              repro.dragonfly.invariants
      valiant_transits_group                  True when inter-group Valiant
                                              paths visit exactly one
                                              intermediate group

    and implement ``link_ranges``, ``link_endpoints``,
    ``expected_router_degree``, ``router_of_node`` and
    ``candidate_paths``.  ``candidates()`` is the stable front door.
    """

    name: str = "abstract"
    MAX_HOPS = 8
    valiant_transits_group: bool = True

    # ------------------------------------------------------------- structure
    def link_ranges(self) -> dict:
        """{kind: (lo, hi)} — half-open link-id ranges, one per link
        class, partitioning [0, n_links)."""
        raise NotImplementedError

    def link_endpoints(self):
        """(src_router, dst_router) int64 [n_links] arrays.

        NIC links have ``src == -1`` (node side) and ``dst`` the host
        router; arithmetic slots that no physical link occupies (e.g.
        diagonal / non-canonical pair encodings) are (-1, -1)."""
        raise NotImplementedError

    def expected_router_degree(self) -> np.ndarray:
        """Spec-side outgoing router-router degree per router, checked
        against the measured ``link_endpoints`` degrees."""
        raise NotImplementedError

    def router_of_node(self, node):
        raise NotImplementedError

    def group_of_node(self, node):
        return np.asarray(node) // self.nodes_per_group

    def group_of_router(self, router):
        raise NotImplementedError

    def link_kind(self, link: int) -> str:
        for kind, (lo, hi) in self.link_ranges().items():
            if lo <= link < hi:
                return kind
        raise ValueError(f"link id {link} out of range")

    def nic_link(self, node):
        raise NotImplementedError

    # --------------------------------------------------------------- routing
    def candidate_paths(self, src, dst, rng, n_min: int = 2,
                        n_nonmin: int = 2):
        """(links [n, n_min+n_nonmin, MAX_HOPS] PAD-padded,
        is_nonmin [n_min+n_nonmin]) — minimal then Valiant candidates."""
        raise NotImplementedError

    def candidates(self, src, dst, rng=None, *, n_min: int = 2,
                   n_nonmin: int = 2):
        """The Topology front door: padded minimal + Valiant path arrays
        for each (src, dst) node pair.  ``rng`` seeds the per-flow
        candidate draw (global-link / intermediate-group choices); None
        means a fresh deterministic generator."""
        if rng is None:
            rng = np.random.default_rng(0)
        return self.candidate_paths(src, dst, rng, n_min=n_min,
                                    n_nonmin=n_nonmin)

    # ------------------------------------------------------------------ misc
    def spec_str(self) -> str:
        """Short human/JSON label, e.g. ``dragonfly(p=2,a=4,h=2,g=9)``."""
        return self.name

    def describe(self) -> dict:
        """JSON-able summary for benchmark records."""
        return {"spec": self.spec_str(), "n_links": int(self.n_links),
                "n_nodes": int(self.n_nodes),
                "n_routers": int(self.n_routers),
                "n_groups": int(self.n_groups)}


# ----------------------------------------------------------------- registry
@dataclass(frozen=True)
class TopologyEntry:
    """One registered topology: a factory plus small-scale kwargs the
    invariant harness (tests + ``ci_lint.py --topology``) instantiates."""

    name: str
    factory: Callable
    small: Mapping


TOPOLOGY_REGISTRY: dict = {}


def register_topology(name: str, factory: Callable, *, small: Mapping
                      ) -> None:
    TOPOLOGY_REGISTRY[name] = TopologyEntry(name, factory, dict(small))


def registered_topologies() -> list:
    _load_families()
    return sorted(TOPOLOGY_REGISTRY)


def small_topology(name: str) -> "Topology":
    """The registered small-scale instance (invariant harness scale)."""
    _load_families()
    e = TOPOLOGY_REGISTRY[name]
    return e.factory(**e.small)


def _load_families():
    # families.py registers itself on import; imported lazily to avoid a
    # topology <-> families cycle at module load.
    import repro.dragonfly.families  # noqa: F401


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    return v


def make_topology(spec, **overrides) -> "Topology":
    """Build a topology from a spec.

    spec: a Topology instance (returned as-is), a registered name
    ("aries", "dragonfly", ...), or "name:k=v,k2=v2" with int/float/str
    values (e.g. ``"dragonfly:p=2,a=4,h=2"``).  The ``name(k=v,...)``
    form emitted by ``Topology.spec_str()`` is accepted too, so recorded
    specs round-trip.  Keyword overrides win over the spec string's
    kwargs."""
    if isinstance(spec, Topology):
        return spec
    _load_families()
    spec = str(spec)
    if "(" in spec and spec.endswith(")"):
        name, _, argstr = spec[:-1].partition("(")
    else:
        name, _, argstr = spec.partition(":")
    if name not in TOPOLOGY_REGISTRY:
        raise ValueError(f"unknown topology {name!r}; registered: "
                         f"{registered_topologies()}")
    kwargs = {}
    if argstr:
        for item in argstr.split(","):
            k, _, v = item.partition("=")
            if not _ or not k:
                raise ValueError(f"bad topology spec item {item!r} "
                                 f"(want k=v)")
            kwargs[k.strip()] = _coerce(v.strip())
    kwargs.update(overrides)
    return TOPOLOGY_REGISTRY[name].factory(**kwargs)


@dataclass(frozen=True)
class TopologyParams:
    n_groups: int = 12
    chassis_per_group: int = 6
    blades_per_chassis: int = 16
    nodes_per_blade: int = 4
    global_links_per_pair: int = 4
    # Bandwidths, paper §2.1: 4.7 (optical) .. 5.25 (electrical) GB/s/dir.
    electrical_gbs: float = 5.25
    optical_gbs: float = 4.7
    nic_gbs: float = 10.0           # x16 PCIe Gen3 ~ 10+ GB/s effective
    hop_latency_ns: float = 100.0   # per router-router hop
    nic_latency_ns: float = 600.0   # NIC+PCIe fixed overhead per direction

    @property
    def routers_per_group(self) -> int:
        return self.chassis_per_group * self.blades_per_chassis

    @property
    def n_routers(self) -> int:
        return self.n_groups * self.routers_per_group

    @property
    def n_nodes(self) -> int:
        return self.n_routers * self.nodes_per_blade


class DragonflyTopology(Topology):
    """The canonical Cray Aries layout (the paper's machine) — the
    topology-family default; link ids, capacities and candidate paths
    are pinned bit-identical to the pre-family code by
    tests/test_topology_family.py."""

    name = "aries"
    max_minimal_hops = 5     # Fig. 1's 5-hop example
    max_nonmin_hops = 8

    def __init__(self, params: TopologyParams = TopologyParams()):
        p = self.params = params
        G, C, B = p.n_groups, p.chassis_per_group, p.blades_per_chassis
        # Links are DIRECTED (Aries links are full duplex: one channel per
        # direction) — each undirected pair gets 2 ids via a parity bit.
        self.n_chassis_links = G * C * B * B * 2      # (g,c,b1,b2,dir)
        self.n_row_links = G * C * C * B * 2          # (g,c1,c2,b,dir)
        self.n_global_links = G * G * p.global_links_per_pair * 2
        self._row_off = self.n_chassis_links
        self._glob_off = self._row_off + self.n_row_links
        self._nic_off = self._glob_off + self.n_global_links
        self.n_links = self._nic_off + p.n_nodes
        # per-link capacity (GB/s)
        cap = np.full(self.n_links, p.electrical_gbs, dtype=np.float64)
        cap[self._glob_off:self._nic_off] = p.optical_gbs
        cap[self._nic_off:] = p.nic_gbs
        self.capacity_gbs = cap

    # -------------------------------------------------- Topology protocol
    @property
    def n_nodes(self) -> int:
        return self.params.n_nodes

    @property
    def n_routers(self) -> int:
        return self.params.n_routers

    @property
    def n_groups(self) -> int:
        return self.params.n_groups

    @property
    def nodes_per_group(self) -> int:
        return self.params.routers_per_group * self.params.nodes_per_blade

    @property
    def nodes_per_router(self) -> int:
        return self.params.nodes_per_blade

    @property
    def n_node_routers(self) -> int:
        return self.params.n_routers    # every Aries router hosts nodes

    @property
    def hop_latency_ns(self) -> float:
        return self.params.hop_latency_ns

    @property
    def nic_latency_ns(self) -> float:
        return self.params.nic_latency_ns

    def router_of_node(self, node):
        return np.asarray(node) // self.params.nodes_per_blade

    def group_of_router(self, router):
        return np.asarray(router) // self.params.routers_per_group

    def spec_str(self) -> str:
        p = self.params
        return (f"aries(n_groups={p.n_groups},"
                f"chassis_per_group={p.chassis_per_group},"
                f"blades_per_chassis={p.blades_per_chassis},"
                f"nodes_per_blade={p.nodes_per_blade},"
                f"global_links_per_pair={p.global_links_per_pair})")

    def link_ranges(self) -> dict:
        return {"chassis": (0, self._row_off),
                "row": (self._row_off, self._glob_off),
                "global": (self._glob_off, self._nic_off),
                "nic": (self._nic_off, self.n_links)}

    def link_endpoints(self):
        p = self.params
        G, C, B = p.n_groups, p.chassis_per_group, p.blades_per_chassis
        K = p.global_links_per_pair
        src = np.full(self.n_links, -1, dtype=np.int64)
        dst = np.full(self.n_links, -1, dtype=np.int64)
        # chassis: base = ((g*C + c)*B + lo)*B + hi, id = base*2 + (b1>b2)
        ids = np.arange(self.n_chassis_links)
        base, dirb = np.divmod(ids, 2)
        hi = base % B
        lo = (base // B) % B
        c = (base // (B * B)) % C
        g = base // (B * B * C)
        ok = lo < hi
        r_lo = (g * C + c) * B + lo
        r_hi = (g * C + c) * B + hi
        src[ids[ok]] = np.where(dirb[ok] == 1, r_hi[ok], r_lo[ok])
        dst[ids[ok]] = np.where(dirb[ok] == 1, r_lo[ok], r_hi[ok])
        # row: base = ((g*C + lo)*C + hi)*B + b, id = off + base*2 + (c1>c2)
        ids = np.arange(self.n_row_links)
        base, dirb = np.divmod(ids, 2)
        b = base % B
        hi = (base // B) % C
        lo = (base // (B * C)) % C
        g = base // (B * C * C)
        ok = lo < hi
        r_lo = (g * C + lo) * B + b
        r_hi = (g * C + hi) * B + b
        src[self._row_off + ids[ok]] = np.where(dirb[ok] == 1,
                                                r_hi[ok], r_lo[ok])
        dst[self._row_off + ids[ok]] = np.where(dirb[ok] == 1,
                                                r_lo[ok], r_hi[ok])
        # global: base = (lo*G + hi)*K + k, id = off + base*2 + (g1>g2)
        ids = np.arange(self.n_global_links)
        base, dirb = np.divmod(ids, 2)
        k = base % K
        hi = (base // K) % G
        lo = base // (K * G)
        ok = lo < hi
        g_src = np.where(dirb == 1, hi, lo)
        g_dst = np.where(dirb == 1, lo, hi)
        sc, sb = self.gateway_router(g_src, g_dst, k)
        dc, db = self.gateway_router(g_dst, g_src, k)
        R, Bc = self.params.routers_per_group, B
        src[self._glob_off + ids[ok]] = (g_src * C + sc)[ok] * Bc + sb[ok]
        dst[self._glob_off + ids[ok]] = (g_dst * C + dc)[ok] * Bc + db[ok]
        del R
        # nic: node-side injection (src = -1 marks the node end)
        nodes = np.arange(p.n_nodes)
        dst[self._nic_off:] = self.router_of_node(nodes)
        return src, dst

    def expected_router_degree(self) -> np.ndarray:
        """(B-1) chassis + (C-1) row + owned gateway slots, per router."""
        p = self.params
        G, K = p.n_groups, p.global_links_per_pair
        deg = np.full(p.n_routers,
                      (p.blades_per_chassis - 1) + (p.chassis_per_group - 1),
                      dtype=np.int64)
        for g_here in range(G):
            for g_there in range(G):
                if g_here == g_there:
                    continue
                ks = np.arange(K)
                c, b = self.gateway_router(g_here, np.full(K, g_there), ks)
                r = (g_here * p.chassis_per_group + c) \
                    * p.blades_per_chassis + b
                np.add.at(deg, r, 1)
        return deg

    # ------------------------------------------------------------- addressing
    def node_coords(self, node: np.ndarray | int):
        """node id -> (group, chassis, blade, slot)."""
        p = self.params
        node = np.asarray(node)
        router, slot = divmod(node, p.nodes_per_blade)
        group, r_in_g = divmod(router, p.routers_per_group)
        chassis, blade = divmod(r_in_g, p.blades_per_chassis)
        return group, chassis, blade, slot

    def node_id(self, group: int, chassis: int, blade: int, slot: int) -> int:
        p = self.params
        return ((group * p.chassis_per_group + chassis)
                * p.blades_per_chassis + blade) * p.nodes_per_blade + slot

    def nic_link(self, node: np.ndarray | int):
        return self._nic_off + np.asarray(node)

    def chassis_link(self, g, c, b1, b2):
        """Directed b1 -> b2 channel of the (g, c, {b1,b2}) chassis link."""
        B = self.params.blades_per_chassis
        lo, hi = np.minimum(b1, b2), np.maximum(b1, b2)
        base = ((g * self.params.chassis_per_group + c) * B + lo) * B + hi
        return base * 2 + (b1 > b2)

    def row_link(self, g, c1, c2, b):
        """Directed c1 -> c2 channel of the (g, {c1,c2}, b) row link."""
        C = self.params.chassis_per_group
        B = self.params.blades_per_chassis
        lo, hi = np.minimum(c1, c2), np.maximum(c1, c2)
        base = ((g * C + lo) * C + hi) * B + b
        return self._row_off + base * 2 + (c1 > c2)

    def global_link(self, g1, g2, k):
        """Directed g1 -> g2 channel of global link k between the groups."""
        G = self.params.n_groups
        K = self.params.global_links_per_pair
        lo, hi = np.minimum(g1, g2), np.maximum(g1, g2)
        base = (lo * G + hi) * K + k
        return self._glob_off + base * 2 + (g1 > g2)

    def link_kind(self, link: int) -> str:
        if link < self._row_off:
            return "chassis"
        if link < self._glob_off:
            return "row"
        if link < self._nic_off:
            return "global"
        return "nic"

    # ---------------------------------------------------------- gateway slots
    def gateway_router(self, g_here, g_there, k):
        """(chassis, blade) of the router in g_here owning global link k
        toward g_there.  Deterministic spread over the group's routers."""
        R = self.params.routers_per_group
        h = (np.asarray(g_there) * self.params.global_links_per_pair
             + np.asarray(k)) * np.int64(2654435761) + np.asarray(g_here)
        r = np.abs(h) % R
        return divmod(r, self.params.blades_per_chassis)

    # ------------------------------------------------- scalar path enumeration
    def intra_group_hops(self, g, c1, b1, c2, b2, order_cb: bool = True):
        """<=2-hop minimal route within a group; `order_cb` picks
        chassis-then-row vs row-then-chassis for the 2-hop case."""
        if c1 == c2 and b1 == b2:
            return []
        if c1 == c2:
            return [self.chassis_link(g, c1, b1, b2)]
        if b1 == b2:
            return [self.row_link(g, c1, c2, b1)]
        if order_cb:
            return [self.chassis_link(g, c1, b1, b2),
                    self.row_link(g, c1, c2, b2)]
        return [self.row_link(g, c1, c2, b1),
                self.chassis_link(g, c2, b1, b2)]

    def minimal_path(self, src_node: int, dst_node: int, k: int = 0,
                     order_seed: int = 0) -> list[int]:
        """One minimal path (router-router links only) using global link k
        for the inter-group hop."""
        g1, c1, b1, _ = self.node_coords(src_node)
        g2, c2, b2, _ = self.node_coords(dst_node)
        if g1 == g2:
            return self.intra_group_hops(g1, c1, b1, c2, b2,
                                         order_cb=bool((order_seed + k) % 2))
        gc1, gb1 = self.gateway_router(g1, g2, k)
        gc2, gb2 = self.gateway_router(g2, g1, k)
        path = self.intra_group_hops(g1, c1, b1, int(gc1), int(gb1),
                                     order_cb=bool(order_seed % 2))
        path.append(int(self.global_link(g1, g2, k)))
        path += self.intra_group_hops(g2, int(gc2), int(gb2), c2, b2,
                                      order_cb=bool((order_seed // 2) % 2))
        return path

    def nonminimal_path(self, src_node: int, dst_node: int, gi: int,
                        k1: int = 0, k2: int = 0) -> list[int]:
        """Valiant path through intermediate group gi (for intra-group flows
        gi is interpreted as an intermediate *router* seed)."""
        g1, c1, b1, _ = self.node_coords(src_node)
        g2, c2, b2, _ = self.node_coords(dst_node)
        if g1 == g2:
            # Non-minimal within a group: detour via intermediate router.
            R = self.params.routers_per_group
            r = (gi * 40503 + 7) % R
            ci, bi = divmod(r, self.params.blades_per_chassis)
            return (self.intra_group_hops(g1, c1, b1, ci, bi) +
                    self.intra_group_hops(g1, ci, bi, c2, b2, order_cb=False))
        gi = gi % self.params.n_groups
        if gi in (g1, g2):
            gi = (gi + 1) % self.params.n_groups
            if gi in (g1, g2):
                gi = (gi + 1) % self.params.n_groups
        # src group -> gi
        gc1, gb1 = self.gateway_router(g1, gi, k1)
        path = self.intra_group_hops(g1, c1, b1, int(gc1), int(gb1))
        path.append(int(self.global_link(g1, gi, k1)))
        # across gi: entry router -> exit gateway
        ec, eb = self.gateway_router(gi, g1, k1)
        xc, xb = self.gateway_router(gi, g2, k2)
        path += self.intra_group_hops(gi, int(ec), int(eb), int(xc), int(xb))
        path.append(int(self.global_link(gi, g2, k2)))
        # entry in g2 -> dst
        gc2, gb2 = self.gateway_router(g2, gi, k2)
        path += self.intra_group_hops(g2, int(gc2), int(gb2), c2, b2,
                                      order_cb=False)
        return path

    # ------------------------------------------------ vectorized candidates
    MAX_HOPS = 8

    def _intra_vec(self, g, c1, b1, c2, b2, order_cb):
        """Vectorized <=2-hop intra-group route. All args int64 [n];
        order_cb bool [n]. Returns [n, 2] PAD-padded link ids."""
        n = g.shape[0]
        out = np.full((n, 2), PAD, dtype=np.int64)
        same = (c1 == c2) & (b1 == b2)
        samec = (c1 == c2) & ~same
        sameb = (b1 == b2) & ~same
        two = ~(same | samec | sameb)
        cl = self.chassis_link(g, c1, b1, b2)
        rl = self.row_link(g, c1, c2, b1)
        out[samec, 0] = cl[samec]
        out[sameb, 0] = rl[sameb]
        cb2 = self.row_link(g, c1, c2, b2)
        rc2 = self.chassis_link(g, c2, b1, b2)
        use_cb = two & order_cb
        use_rc = two & ~order_cb
        out[use_cb, 0] = cl[use_cb]
        out[use_cb, 1] = cb2[use_cb]
        out[use_rc, 0] = rl[use_rc]
        out[use_rc, 1] = rc2[use_rc]
        return out

    def _minimal_vec(self, g1, c1, b1, g2, c2, b2, k, order_seed):
        """Vectorized minimal path -> [n, MAX_HOPS] (slots 5.. are PAD)."""
        n = g1.shape[0]
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = g1 == g2
        gc1, gb1 = self.gateway_router(g1, g2, k)
        # src-side target: dst coords for intra flows, gateway otherwise
        tc = np.where(intra, c2, gc1)
        tb = np.where(intra, b2, gb1)
        out[:, 0:2] = self._intra_vec(g1, c1, b1, tc, tb,
                                      ((order_seed + k) % 2 == 1) & intra
                                      | (order_seed % 2 == 1) & ~intra)
        gl = self.global_link(g1, g2, k)
        inter = ~intra
        out[inter, 2] = gl[inter]
        gc2, gb2 = self.gateway_router(g2, g1, k)
        dst_side = self._intra_vec(g2, gc2, gb2, c2, b2,
                                   (order_seed // 2) % 2 == 1)
        out[inter, 3:5] = dst_side[inter]
        return out

    def _nonmin_vec(self, g1, c1, b1, g2, c2, b2, gi, k1, k2):
        """Vectorized Valiant path -> [n, MAX_HOPS]."""
        n = g1.shape[0]
        G = self.params.n_groups
        R = self.params.routers_per_group
        B = self.params.blades_per_chassis
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = g1 == g2
        # --- intra-group detour via intermediate router (seed = raw gi)
        r = (gi * 40503 + 7) % R
        ci, bi = divmod(r, B)
        seg_a = self._intra_vec(g1, c1, b1, ci, bi, np.ones(n, dtype=bool))
        seg_b = self._intra_vec(g1, ci, bi, c2, b2, np.zeros(n, dtype=bool))
        out[intra, 0:2] = seg_a[intra]
        out[intra, 2:4] = seg_b[intra]
        # --- inter-group Valiant through gi (collision-adjusted like scalar)
        gim = gi % G
        gim = np.where((gim == g1) | (gim == g2), (gim + 1) % G, gim)
        gim = np.where((gim == g1) | (gim == g2), (gim + 1) % G, gim)
        ones = np.ones(n, dtype=bool)
        gc1, gb1 = self.gateway_router(g1, gim, k1)
        seg1 = self._intra_vec(g1, c1, b1, gc1, gb1, ones)
        glob1 = self.global_link(g1, gim, k1)
        ec, eb = self.gateway_router(gim, g1, k1)
        xc, xb = self.gateway_router(gim, g2, k2)
        seg2 = self._intra_vec(gim, ec, eb, xc, xb, ones)
        glob2 = self.global_link(gim, g2, k2)
        gc2, gb2 = self.gateway_router(g2, gim, k2)
        seg3 = self._intra_vec(g2, gc2, gb2, c2, b2, ~ones)
        inter = ~intra
        out[inter, 0:2] = seg1[inter]
        out[inter, 2] = glob1[inter]
        out[inter, 3:5] = seg2[inter]
        out[inter, 5] = glob2[inter]
        out[inter, 6:8] = seg3[inter]
        return out

    def candidate_paths(self, src: np.ndarray, dst: np.ndarray,
                        rng: np.random.Generator, n_min: int = 2,
                        n_nonmin: int = 2):
        """Vectorized candidate generation (paper §2.2: two minimal and two
        non-minimal paths are randomly selected per packet).

        Returns (links, is_nonmin):
          links:     int64 [n_flows, n_min+n_nonmin, MAX_HOPS], PAD-filled
          is_nonmin: bool  [n_min+n_nonmin]
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n = src.shape[0]
        K = self.params.global_links_per_pair
        G = self.params.n_groups
        ncand = n_min + n_nonmin
        g1, c1, b1, _ = self.node_coords(src)
        g2, c2, b2, _ = self.node_coords(dst)
        # Aries draws 2 minimal + 2 non-minimal candidates PER PACKET; over a
        # whole message the union of draws covers all K global links.  The
        # fluid equivalent: the n_min minimal candidates use DISTINCT global
        # links ((k0+j) mod K), so spray weights can spread over all of them.
        k0 = rng.integers(0, K, size=n)
        gis = rng.integers(0, max(G, 1), size=(n_nonmin, n))
        knm = rng.integers(0, K, size=(2 * n_nonmin, n))
        seeds = rng.integers(0, 4, size=(n_min, n))
        cands = []
        for j in range(n_min):
            cands.append(self._minimal_vec(g1, c1, b1, g2, c2, b2,
                                           (k0 + j) % K, seeds[j]))
        for j in range(n_nonmin):
            cands.append(self._nonmin_vec(g1, c1, b1, g2, c2, b2, gis[j],
                                          knm[2 * j], knm[2 * j + 1]))
        links = np.stack(cands, axis=1)
        # same-node flows have no hops at all
        links[src == dst] = PAD
        is_nonmin = np.array([False] * n_min + [True] * n_nonmin)
        return links, is_nonmin

    def candidate_paths_scalar(self, src: int, dst: int, *, k: int = 0,
                               gi: int = 0, order_seed: int = 0):
        """Scalar oracle for property tests: (minimal, nonminimal) paths
        built with the pure-python enumerators."""
        mn = self.minimal_path(src, dst, k=k, order_seed=order_seed) \
            if src != dst else []
        nm = self.nonminimal_path(src, dst, gi=gi, k1=k, k2=k) \
            if src != dst else []
        return mn, nm


@dataclass(frozen=True)
class Allocation:
    """A fixed process->node mapping (paper §3.1: fix the allocation)."""

    allocation_id: str
    nodes: tuple  # node ids, rank r runs on nodes[r]

    @property
    def n_ranks(self) -> int:
        return len(self.nodes)

    def node_of(self, rank: int) -> int:
        return self.nodes[rank]


def make_allocation(topo: Topology, n_ranks: int, *, spread: str,
                    seed: int = 0, allocation_id: str | None = None
                    ) -> Allocation:
    """Build allocations matching the paper's placement tiers.

    spread: 'inter_nodes' (same blade/router), 'inter_blades' (same
            chassis; generic: same group, distinct routers),
            'inter_chassis' (same group, different chassis; generic:
            same group, strided routers),
            'inter_groups' (different groups),
            'scattered' (random over the machine — production-like),
            'contiguous' (fill blades in order).

    Works on every topology in the family: the chassis/blade tiers use
    the Aries coordinates when available and degrade to group/router
    equivalents elsewhere (RNG draws for the generic tiers are identical
    on Aries, so pre-family allocations replay bit-for-bit).
    """
    rng = np.random.default_rng(seed)
    aries = isinstance(topo, DragonflyTopology)
    npg, npr = topo.nodes_per_group, topo.nodes_per_router
    if spread == "inter_nodes":
        assert n_ranks <= npr
        base = int(rng.integers(0, topo.n_node_routers)) * npr
        nodes = [base + i for i in range(n_ranks)]
    elif spread == "inter_blades":
        p = topo.params if aries else None
        g = int(rng.integers(0, topo.n_groups))
        if aries:
            c = int(rng.integers(0, p.chassis_per_group))
            blades = rng.choice(p.blades_per_chassis,
                                size=min(n_ranks, p.blades_per_chassis),
                                replace=False)
            nodes = [topo.node_id(g, c, int(blades[i % len(blades)]),
                                  i // len(blades)) for i in range(n_ranks)]
        else:
            rpg = npg // npr            # node-routers per group
            rs = rng.choice(rpg, size=min(n_ranks, rpg), replace=False)
            nodes = [g * npg + int(rs[i % len(rs)]) * npr + i // len(rs)
                     for i in range(n_ranks)]
    elif spread == "inter_chassis":
        p = topo.params if aries else None
        g = int(rng.integers(0, topo.n_groups))
        if aries:
            cs = rng.permutation(p.chassis_per_group)
            nodes = [topo.node_id(g, int(cs[i % p.chassis_per_group]),
                                  (i // p.chassis_per_group)
                                  % p.blades_per_chassis,
                                  0) for i in range(n_ranks)]
        else:
            rpg = npg // npr
            rs = rng.permutation(rpg)
            nodes = [g * npg + int(rs[i % rpg]) * npr
                     + (i // rpg) % npr for i in range(n_ranks)]
    elif spread == "inter_groups":
        gs = rng.permutation(topo.n_groups)
        nodes = []
        for i in range(n_ranks):
            g = int(gs[i % topo.n_groups])
            j = i // topo.n_groups
            if aries:
                p = topo.params
                c, rem = divmod(j, p.blades_per_chassis)
                nodes.append(topo.node_id(g, c % p.chassis_per_group,
                                          rem, 0))
            else:
                nodes.append(g * npg + (j * npr) % npg)
    elif spread.startswith("groups:"):
        # production-style: ranks packed into a random subset of k groups
        # (paper Fig. 8: 1024 nodes on 257 routers spanning 6 groups)
        k = min(int(spread.split(":")[1]), topo.n_groups)
        # widen the subset when k groups cannot hold n_ranks (small
        # non-Aries machines): capacity first, requested locality second
        k = min(topo.n_groups, max(k, -(-n_ranks // npg)))
        gs = rng.choice(topo.n_groups, size=k, replace=False)
        pool = np.stack([
            g * npg + rng.permutation(npg)
            for g in gs])                       # [k, nodes_per_group]
        # interleave across the chosen groups (rank i -> group i mod k)
        nodes = list(pool.T.ravel()[:n_ranks])
    elif spread == "scattered":
        nodes = list(rng.choice(topo.n_nodes, size=n_ranks, replace=False))
    elif spread == "contiguous":
        start = int(rng.integers(0, max(1, topo.n_nodes - n_ranks)))
        nodes = list(range(start, start + n_ranks))
    else:
        raise ValueError(f"unknown spread {spread!r}")
    return Allocation(
        allocation_id=allocation_id or f"{spread}-{seed}",
        nodes=tuple(int(x) for x in nodes),
    )


register_topology(
    "aries",
    lambda **kw: DragonflyTopology(TopologyParams(**kw)),
    small=dict(n_groups=4, chassis_per_group=2, blades_per_chassis=4,
               nodes_per_blade=2, global_links_per_pair=2),
)
