"""Cray Aries Dragonfly topology — paper §2.1.

Connectivity tiers (Aries/Cascade):
  * group: 6 chassis x 16 blades; each blade has one Aries router + 4 nodes;
  * intra-chassis: every router connects to the other 15 in its chassis
    (15 tiles);
  * intra-group "row" links: every router connects to the 5 routers in the
    same blade slot of the other chassis (3 tiles per connection);
  * inter-group: up to 10 optical links per router; systems bundle several
    tiles per group pair.  We expose `global_links_per_pair` parallel links
    per group pair, attached to deterministic (chassis, blade) gateway slots.

Link ids are arithmetic so the simulator can vectorize over flows:
  [0, n_chassis_links)                 chassis links  (g, c, min(b), max(b))
  [+0, n_row_links)                    row links      (g, min(c), max(c), b)
  [+0, n_global_links)                 global links   (min(g), max(g), k)
  [+0, n_nodes)                        NIC injection links (one per node)

A *path* is a sequence of link ids (NIC link excluded; the simulator charges
injection separately).  Minimal inter-group paths have <= 5 router-router
hops, matching Figure 1's 5-hop example; non-minimal (Valiant) paths go
through an intermediate group and have <= 8 hops (10 on the largest systems
per §2.2 — we cap per topology size).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

PAD = -1  # path padding entry


@dataclass(frozen=True)
class TopologyParams:
    n_groups: int = 12
    chassis_per_group: int = 6
    blades_per_chassis: int = 16
    nodes_per_blade: int = 4
    global_links_per_pair: int = 4
    # Bandwidths, paper §2.1: 4.7 (optical) .. 5.25 (electrical) GB/s/dir.
    electrical_gbs: float = 5.25
    optical_gbs: float = 4.7
    nic_gbs: float = 10.0           # x16 PCIe Gen3 ~ 10+ GB/s effective
    hop_latency_ns: float = 100.0   # per router-router hop
    nic_latency_ns: float = 600.0   # NIC+PCIe fixed overhead per direction

    @property
    def routers_per_group(self) -> int:
        return self.chassis_per_group * self.blades_per_chassis

    @property
    def n_routers(self) -> int:
        return self.n_groups * self.routers_per_group

    @property
    def n_nodes(self) -> int:
        return self.n_routers * self.nodes_per_blade


class DragonflyTopology:
    def __init__(self, params: TopologyParams = TopologyParams()):
        p = self.params = params
        G, C, B = p.n_groups, p.chassis_per_group, p.blades_per_chassis
        # Links are DIRECTED (Aries links are full duplex: one channel per
        # direction) — each undirected pair gets 2 ids via a parity bit.
        self.n_chassis_links = G * C * B * B * 2      # (g,c,b1,b2,dir)
        self.n_row_links = G * C * C * B * 2          # (g,c1,c2,b,dir)
        self.n_global_links = G * G * p.global_links_per_pair * 2
        self._row_off = self.n_chassis_links
        self._glob_off = self._row_off + self.n_row_links
        self._nic_off = self._glob_off + self.n_global_links
        self.n_links = self._nic_off + p.n_nodes
        # per-link capacity (GB/s)
        cap = np.full(self.n_links, p.electrical_gbs, dtype=np.float64)
        cap[self._glob_off:self._nic_off] = p.optical_gbs
        cap[self._nic_off:] = p.nic_gbs
        self.capacity_gbs = cap

    # ------------------------------------------------------------- addressing
    def node_coords(self, node: np.ndarray | int):
        """node id -> (group, chassis, blade, slot)."""
        p = self.params
        node = np.asarray(node)
        router, slot = divmod(node, p.nodes_per_blade)
        group, r_in_g = divmod(router, p.routers_per_group)
        chassis, blade = divmod(r_in_g, p.blades_per_chassis)
        return group, chassis, blade, slot

    def node_id(self, group: int, chassis: int, blade: int, slot: int) -> int:
        p = self.params
        return ((group * p.chassis_per_group + chassis)
                * p.blades_per_chassis + blade) * p.nodes_per_blade + slot

    def nic_link(self, node: np.ndarray | int):
        return self._nic_off + np.asarray(node)

    def chassis_link(self, g, c, b1, b2):
        """Directed b1 -> b2 channel of the (g, c, {b1,b2}) chassis link."""
        B = self.params.blades_per_chassis
        lo, hi = np.minimum(b1, b2), np.maximum(b1, b2)
        base = ((g * self.params.chassis_per_group + c) * B + lo) * B + hi
        return base * 2 + (b1 > b2)

    def row_link(self, g, c1, c2, b):
        """Directed c1 -> c2 channel of the (g, {c1,c2}, b) row link."""
        C = self.params.chassis_per_group
        B = self.params.blades_per_chassis
        lo, hi = np.minimum(c1, c2), np.maximum(c1, c2)
        base = ((g * C + lo) * C + hi) * B + b
        return self._row_off + base * 2 + (c1 > c2)

    def global_link(self, g1, g2, k):
        """Directed g1 -> g2 channel of global link k between the groups."""
        G = self.params.n_groups
        K = self.params.global_links_per_pair
        lo, hi = np.minimum(g1, g2), np.maximum(g1, g2)
        base = (lo * G + hi) * K + k
        return self._glob_off + base * 2 + (g1 > g2)

    def link_kind(self, link: int) -> str:
        if link < self._row_off:
            return "chassis"
        if link < self._glob_off:
            return "row"
        if link < self._nic_off:
            return "global"
        return "nic"

    # ---------------------------------------------------------- gateway slots
    def gateway_router(self, g_here, g_there, k):
        """(chassis, blade) of the router in g_here owning global link k
        toward g_there.  Deterministic spread over the group's routers."""
        R = self.params.routers_per_group
        h = (np.asarray(g_there) * self.params.global_links_per_pair
             + np.asarray(k)) * np.int64(2654435761) + np.asarray(g_here)
        r = np.abs(h) % R
        return divmod(r, self.params.blades_per_chassis)

    # ------------------------------------------------- scalar path enumeration
    def intra_group_hops(self, g, c1, b1, c2, b2, order_cb: bool = True):
        """<=2-hop minimal route within a group; `order_cb` picks
        chassis-then-row vs row-then-chassis for the 2-hop case."""
        if c1 == c2 and b1 == b2:
            return []
        if c1 == c2:
            return [self.chassis_link(g, c1, b1, b2)]
        if b1 == b2:
            return [self.row_link(g, c1, c2, b1)]
        if order_cb:
            return [self.chassis_link(g, c1, b1, b2),
                    self.row_link(g, c1, c2, b2)]
        return [self.row_link(g, c1, c2, b1),
                self.chassis_link(g, c2, b1, b2)]

    def minimal_path(self, src_node: int, dst_node: int, k: int = 0,
                     order_seed: int = 0) -> list[int]:
        """One minimal path (router-router links only) using global link k
        for the inter-group hop."""
        g1, c1, b1, _ = self.node_coords(src_node)
        g2, c2, b2, _ = self.node_coords(dst_node)
        if g1 == g2:
            return self.intra_group_hops(g1, c1, b1, c2, b2,
                                         order_cb=bool((order_seed + k) % 2))
        gc1, gb1 = self.gateway_router(g1, g2, k)
        gc2, gb2 = self.gateway_router(g2, g1, k)
        path = self.intra_group_hops(g1, c1, b1, int(gc1), int(gb1),
                                     order_cb=bool(order_seed % 2))
        path.append(int(self.global_link(g1, g2, k)))
        path += self.intra_group_hops(g2, int(gc2), int(gb2), c2, b2,
                                      order_cb=bool((order_seed // 2) % 2))
        return path

    def nonminimal_path(self, src_node: int, dst_node: int, gi: int,
                        k1: int = 0, k2: int = 0) -> list[int]:
        """Valiant path through intermediate group gi (for intra-group flows
        gi is interpreted as an intermediate *router* seed)."""
        g1, c1, b1, _ = self.node_coords(src_node)
        g2, c2, b2, _ = self.node_coords(dst_node)
        if g1 == g2:
            # Non-minimal within a group: detour via intermediate router.
            R = self.params.routers_per_group
            r = (gi * 40503 + 7) % R
            ci, bi = divmod(r, self.params.blades_per_chassis)
            return (self.intra_group_hops(g1, c1, b1, ci, bi) +
                    self.intra_group_hops(g1, ci, bi, c2, b2, order_cb=False))
        gi = gi % self.params.n_groups
        if gi in (g1, g2):
            gi = (gi + 1) % self.params.n_groups
            if gi in (g1, g2):
                gi = (gi + 1) % self.params.n_groups
        # src group -> gi
        gc1, gb1 = self.gateway_router(g1, gi, k1)
        path = self.intra_group_hops(g1, c1, b1, int(gc1), int(gb1))
        path.append(int(self.global_link(g1, gi, k1)))
        # across gi: entry router -> exit gateway
        ec, eb = self.gateway_router(gi, g1, k1)
        xc, xb = self.gateway_router(gi, g2, k2)
        path += self.intra_group_hops(gi, int(ec), int(eb), int(xc), int(xb))
        path.append(int(self.global_link(gi, g2, k2)))
        # entry in g2 -> dst
        gc2, gb2 = self.gateway_router(g2, gi, k2)
        path += self.intra_group_hops(g2, int(gc2), int(gb2), c2, b2,
                                      order_cb=False)
        return path

    # ------------------------------------------------ vectorized candidates
    MAX_HOPS = 8

    def _intra_vec(self, g, c1, b1, c2, b2, order_cb):
        """Vectorized <=2-hop intra-group route. All args int64 [n];
        order_cb bool [n]. Returns [n, 2] PAD-padded link ids."""
        n = g.shape[0]
        out = np.full((n, 2), PAD, dtype=np.int64)
        same = (c1 == c2) & (b1 == b2)
        samec = (c1 == c2) & ~same
        sameb = (b1 == b2) & ~same
        two = ~(same | samec | sameb)
        cl = self.chassis_link(g, c1, b1, b2)
        rl = self.row_link(g, c1, c2, b1)
        out[samec, 0] = cl[samec]
        out[sameb, 0] = rl[sameb]
        cb2 = self.row_link(g, c1, c2, b2)
        rc2 = self.chassis_link(g, c2, b1, b2)
        use_cb = two & order_cb
        use_rc = two & ~order_cb
        out[use_cb, 0] = cl[use_cb]
        out[use_cb, 1] = cb2[use_cb]
        out[use_rc, 0] = rl[use_rc]
        out[use_rc, 1] = rc2[use_rc]
        return out

    def _minimal_vec(self, g1, c1, b1, g2, c2, b2, k, order_seed):
        """Vectorized minimal path -> [n, MAX_HOPS] (slots 5.. are PAD)."""
        n = g1.shape[0]
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = g1 == g2
        gc1, gb1 = self.gateway_router(g1, g2, k)
        # src-side target: dst coords for intra flows, gateway otherwise
        tc = np.where(intra, c2, gc1)
        tb = np.where(intra, b2, gb1)
        out[:, 0:2] = self._intra_vec(g1, c1, b1, tc, tb,
                                      ((order_seed + k) % 2 == 1) & intra
                                      | (order_seed % 2 == 1) & ~intra)
        gl = self.global_link(g1, g2, k)
        inter = ~intra
        out[inter, 2] = gl[inter]
        gc2, gb2 = self.gateway_router(g2, g1, k)
        dst_side = self._intra_vec(g2, gc2, gb2, c2, b2,
                                   (order_seed // 2) % 2 == 1)
        out[inter, 3:5] = dst_side[inter]
        return out

    def _nonmin_vec(self, g1, c1, b1, g2, c2, b2, gi, k1, k2):
        """Vectorized Valiant path -> [n, MAX_HOPS]."""
        n = g1.shape[0]
        G = self.params.n_groups
        R = self.params.routers_per_group
        B = self.params.blades_per_chassis
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = g1 == g2
        # --- intra-group detour via intermediate router (seed = raw gi)
        r = (gi * 40503 + 7) % R
        ci, bi = divmod(r, B)
        seg_a = self._intra_vec(g1, c1, b1, ci, bi, np.ones(n, dtype=bool))
        seg_b = self._intra_vec(g1, ci, bi, c2, b2, np.zeros(n, dtype=bool))
        out[intra, 0:2] = seg_a[intra]
        out[intra, 2:4] = seg_b[intra]
        # --- inter-group Valiant through gi (collision-adjusted like scalar)
        gim = gi % G
        gim = np.where((gim == g1) | (gim == g2), (gim + 1) % G, gim)
        gim = np.where((gim == g1) | (gim == g2), (gim + 1) % G, gim)
        ones = np.ones(n, dtype=bool)
        gc1, gb1 = self.gateway_router(g1, gim, k1)
        seg1 = self._intra_vec(g1, c1, b1, gc1, gb1, ones)
        glob1 = self.global_link(g1, gim, k1)
        ec, eb = self.gateway_router(gim, g1, k1)
        xc, xb = self.gateway_router(gim, g2, k2)
        seg2 = self._intra_vec(gim, ec, eb, xc, xb, ones)
        glob2 = self.global_link(gim, g2, k2)
        gc2, gb2 = self.gateway_router(g2, gim, k2)
        seg3 = self._intra_vec(g2, gc2, gb2, c2, b2, ~ones)
        inter = ~intra
        out[inter, 0:2] = seg1[inter]
        out[inter, 2] = glob1[inter]
        out[inter, 3:5] = seg2[inter]
        out[inter, 5] = glob2[inter]
        out[inter, 6:8] = seg3[inter]
        return out

    def candidate_paths(self, src: np.ndarray, dst: np.ndarray,
                        rng: np.random.Generator, n_min: int = 2,
                        n_nonmin: int = 2):
        """Vectorized candidate generation (paper §2.2: two minimal and two
        non-minimal paths are randomly selected per packet).

        Returns (links, is_nonmin):
          links:     int64 [n_flows, n_min+n_nonmin, MAX_HOPS], PAD-filled
          is_nonmin: bool  [n_min+n_nonmin]
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n = src.shape[0]
        K = self.params.global_links_per_pair
        G = self.params.n_groups
        ncand = n_min + n_nonmin
        g1, c1, b1, _ = self.node_coords(src)
        g2, c2, b2, _ = self.node_coords(dst)
        # Aries draws 2 minimal + 2 non-minimal candidates PER PACKET; over a
        # whole message the union of draws covers all K global links.  The
        # fluid equivalent: the n_min minimal candidates use DISTINCT global
        # links ((k0+j) mod K), so spray weights can spread over all of them.
        k0 = rng.integers(0, K, size=n)
        gis = rng.integers(0, max(G, 1), size=(n_nonmin, n))
        knm = rng.integers(0, K, size=(2 * n_nonmin, n))
        seeds = rng.integers(0, 4, size=(n_min, n))
        cands = []
        for j in range(n_min):
            cands.append(self._minimal_vec(g1, c1, b1, g2, c2, b2,
                                           (k0 + j) % K, seeds[j]))
        for j in range(n_nonmin):
            cands.append(self._nonmin_vec(g1, c1, b1, g2, c2, b2, gis[j],
                                          knm[2 * j], knm[2 * j + 1]))
        links = np.stack(cands, axis=1)
        # same-node flows have no hops at all
        links[src == dst] = PAD
        is_nonmin = np.array([False] * n_min + [True] * n_nonmin)
        return links, is_nonmin

    def candidate_paths_scalar(self, src: int, dst: int, *, k: int = 0,
                               gi: int = 0, order_seed: int = 0):
        """Scalar oracle for property tests: (minimal, nonminimal) paths
        built with the pure-python enumerators."""
        mn = self.minimal_path(src, dst, k=k, order_seed=order_seed) \
            if src != dst else []
        nm = self.nonminimal_path(src, dst, gi=gi, k1=k, k2=k) \
            if src != dst else []
        return mn, nm


@dataclass(frozen=True)
class Allocation:
    """A fixed process->node mapping (paper §3.1: fix the allocation)."""

    allocation_id: str
    nodes: tuple  # node ids, rank r runs on nodes[r]

    @property
    def n_ranks(self) -> int:
        return len(self.nodes)

    def node_of(self, rank: int) -> int:
        return self.nodes[rank]


def make_allocation(topo: DragonflyTopology, n_ranks: int, *, spread: str,
                    seed: int = 0, allocation_id: str | None = None
                    ) -> Allocation:
    """Build allocations matching the paper's placement tiers.

    spread: 'inter_nodes' (same blade), 'inter_blades' (same chassis),
            'inter_chassis' (same group, different chassis),
            'inter_groups' (different groups),
            'scattered' (random over the machine — production-like),
            'contiguous' (fill blades in order).
    """
    p = topo.params
    rng = np.random.default_rng(seed)
    if spread == "inter_nodes":
        assert n_ranks <= p.nodes_per_blade
        base = int(rng.integers(0, topo.params.n_routers)) * p.nodes_per_blade
        nodes = [base + i for i in range(n_ranks)]
    elif spread == "inter_blades":
        g = int(rng.integers(0, p.n_groups))
        c = int(rng.integers(0, p.chassis_per_group))
        blades = rng.choice(p.blades_per_chassis,
                            size=min(n_ranks, p.blades_per_chassis),
                            replace=False)
        nodes = [topo.node_id(g, c, int(blades[i % len(blades)]),
                              i // len(blades)) for i in range(n_ranks)]
    elif spread == "inter_chassis":
        g = int(rng.integers(0, p.n_groups))
        cs = rng.permutation(p.chassis_per_group)
        nodes = [topo.node_id(g, int(cs[i % p.chassis_per_group]),
                              (i // p.chassis_per_group) % p.blades_per_chassis,
                              0) for i in range(n_ranks)]
    elif spread == "inter_groups":
        gs = rng.permutation(p.n_groups)
        per_g = -(-n_ranks // p.n_groups)
        nodes = []
        for i in range(n_ranks):
            g = int(gs[i % p.n_groups])
            j = i // p.n_groups
            c, rem = divmod(j, p.blades_per_chassis)
            nodes.append(topo.node_id(g, c % p.chassis_per_group,
                                      rem, 0))
        del per_g
    elif spread.startswith("groups:"):
        # production-style: ranks packed into a random subset of k groups
        # (paper Fig. 8: 1024 nodes on 257 routers spanning 6 groups)
        k = min(int(spread.split(":")[1]), p.n_groups)
        gs = rng.choice(p.n_groups, size=k, replace=False)
        nodes_per_group = p.routers_per_group * p.nodes_per_blade
        pool = np.stack([
            g * nodes_per_group + rng.permutation(nodes_per_group)
            for g in gs])                       # [k, nodes_per_group]
        # interleave across the chosen groups (rank i -> group i mod k)
        nodes = list(pool.T.ravel()[:n_ranks])
    elif spread == "scattered":
        nodes = list(rng.choice(p.n_nodes, size=n_ranks, replace=False))
    elif spread == "contiguous":
        start = int(rng.integers(0, max(1, p.n_nodes - n_ranks)))
        nodes = list(range(start, start + n_ranks))
    else:
        raise ValueError(f"unknown spread {spread!r}")
    return Allocation(
        allocation_id=allocation_id or f"{spread}-{seed}",
        nodes=tuple(int(x) for x in nodes),
    )
