"""Pre-refactor `run_phase` preserved verbatim as an equivalence oracle.

This module is the PR-3 snapshot of `DragonflySimulator.run_phase` from
before the vectorized fast path (bincount segment-sums, hoisted score
base, mode-code bias tables) replaced its kernels.  It exists for two
consumers only:

  * the golden-trace tests (`tests/test_dragonfly_fastpath.py`), which
    assert the fast path is seed-for-seed equivalent to this oracle —
    bit-identical with `route_feedback_iters=1` and within ~1e-9
    relative otherwise (the hoisted `extra` term reassociates one
    float64 sum; see docs/performance.md);
  * `benchmarks/perf_sim.py`, which measures the fast-path speedup
    against it (the BENCH_sim.json "reference" stage).

It deliberately re-uses the simulator instance's state and RNG —
calling it advances `sim.rng`, `sim.link_queue_s`, `sim.est_memory_s`,
counters and the clock exactly like the pre-refactor method did, so a
fresh simulator driven through this function replays the pre-refactor
trajectory draw for draw.  The only intentional deviation: background
flows come from the *fixed* `sim._bg_flows` (the resample-to-
disjointness satellite fix), so oracle and fast path stay comparable on
every seed; the two differ from the seed-era code only in the rare
buggy case where an other-job flow used to survive on the allocation's
nodes.

Do not "improve" this file — its value is that it does not change.
"""

from __future__ import annotations

import numpy as np

from repro.core.counters import NICCounters
from repro.core.perf_model import MAX_OUTSTANDING_PACKETS
from repro.core.strategies import RoutingMode
from repro.dragonfly.routing import (RoutingPolicy, score_candidates,
                                     spray_weights)
from repro.dragonfly.topology import PAD, Allocation


def reference_run_phase(sim, src_nodes, dst_nodes, bytes_,
                        policy: RoutingPolicy,
                        allocation: Allocation | None = None,
                        modes: np.ndarray | None = None):
    """The pre-refactor `run_phase` body, operating on `sim`'s state."""
    from repro.dragonfly.simulator import FlowResult  # cycle-free import

    p = sim.params
    topo = sim.topo
    src = np.asarray(src_nodes, dtype=np.int64)
    dst = np.asarray(dst_nodes, dtype=np.int64)
    size = np.asarray(bytes_, dtype=np.float64)
    n_app = src.shape[0]
    if modes is not None and np.shape(modes)[0] != n_app:
        raise ValueError("modes must have one entry per app flow")
    if n_app == 0 and not (p.bg_enable and p.bg_flows_per_phase):
        return FlowResult(*(np.zeros(0),) * 5, 0.0)

    # statistical subsample of very large phases (load-preserving)
    if n_app > p.max_flows:
        idx = sim.rng.choice(n_app, size=p.max_flows, replace=False)
        scale = n_app / p.max_flows
        src, dst, size = src[idx], dst[idx], size[idx] * scale
        if modes is not None:
            modes = modes[idx]
        n_app = p.max_flows

    bg = sim._bg_flows(allocation)
    if bg is not None:
        src_all = np.concatenate([src, bg[0]])
        dst_all = np.concatenate([dst, bg[1]])
        size_all = np.concatenate([size, bg[2]])
    else:
        src_all, dst_all, size_all = src, dst, size
    n_all = src_all.shape[0]

    links, is_nonmin = topo.candidate_paths(
        src_all, dst_all, sim.rng,
        n_min=p.n_min_candidates, n_nonmin=p.n_nonmin_candidates)
    valid = links != PAD
    safe = np.where(valid, links, 0)

    # --- stale & noisy congestion estimate (phantom congestion) --------
    noise = sim.rng.lognormal(0.0, p.phantom_sigma, size=topo.n_links)
    ghosts = sim.rng.exponential(p.phantom_ghost_s, size=topo.n_links)
    a = p.est_staleness
    est_queue_s = ((1.0 - a) * sim.link_queue_s
                   + a * sim.est_memory_s) * noise + ghosts

    # --- contention window: the APP phase's clean serialization time ---
    ser_s_app = float(size[:n_app].max() * p.flit_ns_per_byte) * 1e-9 \
        if n_app else 0.0
    window_s = max(ser_s_app, p.min_phase_window_s)
    cap_bps = topo.capacity_gbs * 1e9
    nic_ids = topo.nic_link(src_all)
    inj_cap = topo.capacity_gbs[nic_ids] * 1e9 * window_s
    size_inst = np.minimum(size_all, inj_cap)
    packets_all = np.maximum(1, np.ceil(size_all / 64.0))
    bg_policy = RoutingPolicy(RoutingMode.ADAPTIVE_0)

    def weights_for(extra_queue_s):
        est = est_queue_s + extra_queue_s
        sc_app = score_candidates(links[:n_app], est, is_nonmin, policy,
                                  modes=modes)
        wa = spray_weights(sc_app, policy, sim.rng,
                           packets=packets_all[:n_app])
        if n_all > n_app:
            sc_bg = score_candidates(links[n_app:], est, is_nonmin,
                                     bg_policy)
            wb = spray_weights(sc_bg, bg_policy, sim.rng,
                               packets=packets_all[n_app:])
            return np.concatenate([wa, wb], axis=0)
        return wa

    def loads_for(w):
        fb = size_inst[:, None, None] * w[:, :, None] * valid
        li = np.zeros(topo.n_links)
        np.add.at(li, safe.ravel(), fb.ravel())
        np.add.at(li, nic_ids, size_inst)
        return li

    w = weights_for(np.zeros(topo.n_links))
    load_i = loads_for(w)
    for _ in range(max(0, p.route_feedback_iters - 1)):
        rho_fb = load_i / (cap_bps * window_s)
        extra = np.maximum(0.0, rho_fb - p.feedback_rho0) * window_s
        w = 0.5 * (w + weights_for(extra))
        load_i = loads_for(w)
    w_app = w[:n_app]

    # load_q: full backlog bytes (feeds persistent queues / Fig.3 tails)
    flow_bytes_q = size_all[:, None, None] * w[:, :, None] * valid
    load_q = np.zeros(topo.n_links)
    np.add.at(load_q, safe.ravel(), flow_bytes_q.ravel())

    rho = load_i / (cap_bps * window_s)
    lat_us, s_flit = _reference_observables(sim, valid, safe, rho, w,
                                            nic_ids)
    flits, packets = sim._flits_packets(size_all)
    win = (packets + MAX_OUTSTANDING_PACKETS // 2) / MAX_OUTSTANDING_PACKETS
    lat_cycles = lat_us * 1e3 * p.nic_clock_ghz
    t_cycles = win * lat_cycles + flits * (s_flit + 1.0)
    t_us = t_cycles / (1e3 * p.nic_clock_ghz)
    duration_s = max(float(t_us[:n_app].max()) * 1e-6, 1e-7) \
        if n_app else window_s
    sim.total_flits_all_jobs += float(flits.sum())

    # --- persistent queues (seconds-to-drain beyond this phase) --------
    excess_s = np.maximum(0.0, load_q / cap_bps
                          - max(duration_s, window_s))
    sim.est_memory_s = (sim.est_memory_s * p.est_memory_decay
                        + sim.link_queue_s * (1 - p.est_memory_decay))
    sim.link_queue_s = sim.link_queue_s * p.queue_carryover + excess_s
    sim.clock_s += duration_s

    # --- NIC counters for the allocation (§2.3) ------------------------
    app_flits, app_packets = flits[:n_app], packets[:n_app]
    app_lat, app_stalls = lat_us[:n_app], s_flit[:n_app]
    if allocation is not None:
        c = sim.counters.setdefault(allocation.allocation_id,
                                    NICCounters())
        c.observe(
            flits=int(app_flits.sum()),
            stalled_cycles=int((app_flits * app_stalls).sum()),
            packets=int(app_packets.sum()),
            latency_us_total=float((app_lat * app_packets).sum()),
        )

    nonmin_bytes = float(
        (size_all[:n_app, None] * w_app * is_nonmin[None, :]).sum())
    return FlowResult(
        t_us=t_us[:n_app],
        latency_us=app_lat,
        stalls_per_flit=app_stalls,
        flits=app_flits,
        packets=app_packets,
        nonmin_fraction=nonmin_bytes / max(float(size[:n_app].sum()), 1e-9),
    )


def _reference_observables(sim, valid, safe, rho, w, nic_ids):
    """Per-flow (L_us, s) from per-link utilization (pre-refactor)."""
    p = sim.params
    tp = sim.topo.params
    rho_path = rho[safe] * valid                    # [n, ncand, hops]
    hops = valid.sum(axis=-1)                       # [n, ncand]
    excess = np.maximum(0.0, rho_path - p.rho_threshold)
    qdelay_ns = p.queue_delay_ns * excess.sum(axis=-1)   # [n, ncand]
    qwait_ns = (sim.link_queue_s[safe] * valid).sum(axis=-1) \
        * p.qwait_fraction * 1e9
    lat_ns_cand = 2.0 * tp.nic_latency_ns + hops * tp.hop_latency_ns \
        + qdelay_ns + qwait_ns
    lat_us = (lat_ns_cand * w).sum(axis=-1) / 1e3   # weighted over cands
    rho_nic = rho[nic_ids]                          # [n]
    rho_bneck = np.maximum(rho_path.max(axis=-1),
                           rho_nic[:, None])        # [n, ncand]
    s_cand = p.stall_gain * np.maximum(0.0, rho_bneck - p.rho_threshold)
    s_flit = (s_cand * w).sum(axis=-1)
    return lat_us, s_flit
