"""UGAL-style adaptive routing with minimal bias — paper §2.2.

Per packet, Aries picks 2 minimal and 2 non-minimal candidate paths at
random and routes on the one whose *estimated* congestion is lowest, where
the estimate mixes local queue occupancy with far-end credit information
that arrives late (=> phantom congestion, Won et al. [46]).  The bias is
added to the non-minimal estimates; higher bias => more minimal routing.

All scores are in SECONDS of predicted delay:
    score(path) = sum(est_queue_s[link]) + hops * hop_latency + bias_s
where bias_s = mode.minimal_bias * bias_unit_s is charged to non-minimal
candidates only.

The simulator distributes each flow's bytes across candidates with a
softmin over scores (temperature = per-packet noise scale): this is the
fluid limit of per-packet argmin-with-noise selection — P(packet takes
candidate c) = softmax(-score/T)_c for Gumbel(T) packet noise.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.strategies import RoutingMode
from repro.dragonfly.topology import PAD


@dataclass(frozen=True)
class RoutingPolicy:
    mode: RoutingMode
    #: seconds of predicted delay per unit of minimal bias (paper: the exact
    #: Aries bias values are not public; this is the calibration constant).
    #: Sized so HIGH BIAS (8 units = 160us) overrides transient self-
    #: congestion and phantom ghosts, but yields to real ms-scale backlogs.
    bias_unit_s: float = 20e-6
    #: softmin temperature == per-packet congestion-estimate noise scale.
    spray_temperature_s: float = 10e-6
    #: per-hop latency charged in the score (router pipeline).
    hop_latency_s: float = 100e-9

    @property
    def bias_s(self) -> float:
        return mode_bias_s(self.mode, self.bias_unit_s)


def mode_bias_s(mode: RoutingMode, bias_unit_s: float) -> float:
    """Seconds of minimal bias for one mode.  Deterministic modes return
    raw ±inf (never scaled — inf * unit would be inf anyway, but the raw
    value is the sentinel score_candidates branches on)."""
    b = mode.minimal_bias
    if mode is RoutingMode.ADAPTIVE_1:
        # Increasingly-minimal: bias ramps 0 -> terminal along the path;
        # in the fluid model we charge the path-average (half terminal).
        return b * 0.5 * bias_unit_s
    if np.isinf(b):
        return b
    return b * bias_unit_s


# --- int mode codes + bias lookup (the fast path's per-flow bias) ---------
#: fixed enumeration order backing the int mode-code representation
MODE_ORDER: tuple = tuple(RoutingMode)
MODE_CODE: dict = {m: i for i, m in enumerate(MODE_ORDER)}


def mode_codes(modes: np.ndarray) -> np.ndarray:
    """Object array of RoutingModes -> int64 code array (one Python pass
    per *phase* instead of one set-membership pass per feedback
    iteration)."""
    n = len(modes)
    return np.fromiter((MODE_CODE[m] for m in modes), dtype=np.int64,
                       count=n)


def bias_table_s(bias_unit_s: float) -> np.ndarray:
    """[n_modes] seconds-of-bias lookup table aligned with MODE_ORDER
    (deterministic modes keep their raw ±inf sentinel)."""
    return np.array([mode_bias_s(m, bias_unit_s) for m in MODE_ORDER])


def row_bias_terms(n: int, policy: RoutingPolicy,
                   modes: np.ndarray | None = None):
    """Loop-invariant per-flow bias decomposition.

    Returns (bias_rows [n] float64, posinf [n] bool, neginf [n] bool):
    the finite seconds-of-bias charged to non-minimal candidates, and
    the deterministic-mode masks (±inf sentinels).  Computed once per
    phase and reused by every feedback iteration.
    """
    if modes is None:
        b = policy.bias_s
        bias_rows = np.full(n, 0.0 if np.isinf(b) else b)
        posinf = np.full(n, np.isposinf(b))
        neginf = np.full(n, np.isneginf(b))
        return bias_rows, posinf, neginf
    raw = bias_table_s(policy.bias_unit_s)[mode_codes(modes)]
    finite = np.isfinite(raw)
    return (np.where(finite, raw, 0.0), np.isposinf(raw),
            np.isneginf(raw))


def apply_bias(score: np.ndarray, is_nonmin: np.ndarray,
               bias_rows: np.ndarray, posinf: np.ndarray,
               neginf: np.ndarray) -> np.ndarray:
    """Charge the per-flow minimal bias to a [n, ncand] score array."""
    score = score + np.where(is_nonmin[None, :], bias_rows[:, None], 0.0)
    if posinf.any():                     # deterministic minimal rows
        score = np.where(posinf[:, None] & is_nonmin[None, :],
                         np.inf, score)
    if neginf.any():                     # deterministic non-minimal rows
        score = np.where(neginf[:, None] & ~is_nonmin[None, :],
                         np.inf, score)
    return score


def apply_notifications(est_queue_s: np.ndarray, notified: np.ndarray,
                        penalty_s: float) -> np.ndarray:
    """Demote links under a visible congestion notification.

    The notification channel (SimParams.notify_*, docs/policy_api.md;
    Rocher-Gonzalez et al. 2502.00616) marks links whose queue estimate
    crossed the notify threshold on a past phase.  Routing reacts by
    charging ``penalty_s`` seconds of predicted delay to every flagged
    link, which flows into the hoisted score base exactly like queue
    backlog — minimal candidates crossing a flagged link lose to clean
    non-minimal ones once the penalty exceeds the mode's bias.

    Returns a NEW array; the caller skips this call entirely when no
    flag is visible, so the disabled channel stays bit-identical to the
    notification-free scorer.
    """
    return est_queue_s + penalty_s * notified


def score_candidates(link_ids: np.ndarray, est_queue_s: np.ndarray,
                     is_nonmin: np.ndarray, policy: RoutingPolicy,
                     modes: np.ndarray | None = None) -> np.ndarray:
    """Predicted-delay score per candidate (seconds; lower is better).

    link_ids:    [n, ncand, max_hops] PAD-padded link ids
    est_queue_s: [n_links] estimated (stale/noisy) seconds-to-drain
    modes:       optional [n] object array of per-flow RoutingModes; when
                 given, each flow is biased by its own mode (the
                 PolicyEngine path: one batched call per phase, mixed
                 modes welcome).  Without it, policy.mode biases all rows.

    The simulator's fast path does not call this per feedback iteration
    any more — it hoists the (queue gather + hop latency + bias) base via
    row_bias_terms/apply_bias and only re-adds the iteration's `extra`
    term; this function remains the one-shot scoring entry point.
    """
    valid = link_ids != PAD
    safe = np.where(valid, link_ids, 0)
    q = est_queue_s[safe] * valid        # [n, ncand, hops]
    hops = valid.sum(axis=-1)            # [n, ncand]
    score = q.sum(axis=-1) + policy.hop_latency_s * hops
    if modes is None:
        bias = policy.bias_s
        if np.isposinf(bias):                # deterministic minimal
            score = np.where(is_nonmin[None, :], np.inf, score)
        elif np.isneginf(bias):              # deterministic non-minimal
            score = np.where(is_nonmin[None, :], score, np.inf)
        else:
            score = score + np.where(is_nonmin[None, :], bias, 0.0)
        return score
    return apply_bias(score, is_nonmin,
                      *row_bias_terms(score.shape[0], policy, modes))


def spray_weights(scores: np.ndarray, policy: RoutingPolicy,
                  rng: np.random.Generator | None = None,
                  packets: np.ndarray | None = None) -> np.ndarray:
    """Byte distribution over candidates: softmin(scores / T).

    When candidate scores are close (ADAPTIVE, bias 0) bytes spread across
    paths (packet spraying); when the bias separates them (HIGH BIAS) bytes
    concentrate on minimal paths.  Deterministic modes collapse to one
    class.

    The optional Gumbel jitter is the *sampling error* of per-packet
    selection: each packet draws its own noisy estimate, so a message of
    `packets` packets realizes the softmin distribution with ~1/sqrt(p)
    relative error — a single-packet message takes exactly one path, a
    64k-packet message matches the distribution almost exactly.

    When `rng is None` the scores go straight into the softmin — no
    copy, no noise machinery (this runs 4x per phase on the bg arm)."""
    t = max(policy.spray_temperature_s, 1e-12)
    noise = scale = None
    if rng is not None:
        noise = rng.gumbel(0.0, 1.0, size=scores.shape)
        scale = t * 0.9
        if packets is not None:
            scale = scale / np.sqrt(np.maximum(packets, 1.0))[:, None]
    return softmin_weights(scores, t, noise=noise, noise_scale=scale)


def softmin_weights(scores: np.ndarray, temperature,
                    noise: np.ndarray | None = None,
                    noise_scale=None) -> np.ndarray:
    """softmin(scores / T) with optional pre-drawn additive noise.

    `temperature` is a scalar or a per-row [n] / [n, 1] array (the fused
    fast path sprays app + background flows, whose policies may carry
    different temperatures, in ONE call).  Inf/NaN scrubbing is a single
    pass on the score side: a +inf score exponentiates to an exact 0.0
    weight, so the exp output needs no second scrub.
    """
    t = np.asarray(temperature)
    if t.ndim == 1:
        t = t[:, None]
    s = scores
    if noise is not None:
        s = s + noise * noise_scale
    s = np.where(np.isfinite(s), s, np.inf)
    smin = s.min(axis=1, keepdims=True)
    # rows with no usable candidate (all inf): shift by 0 instead of inf
    # so exp(-inf) cleanly zeroes them without inf-inf NaN warnings
    smin = np.where(np.isfinite(smin), smin, 0.0)
    z = np.exp(-(s - smin) / t)
    tot = z.sum(axis=1, keepdims=True)
    tot = np.where(tot <= 0, 1.0, tot)
    return z / tot
