"""UGAL-style adaptive routing with minimal bias — paper §2.2.

Per packet, Aries picks 2 minimal and 2 non-minimal candidate paths at
random and routes on the one whose *estimated* congestion is lowest, where
the estimate mixes local queue occupancy with far-end credit information
that arrives late (=> phantom congestion, Won et al. [46]).  The bias is
added to the non-minimal estimates; higher bias => more minimal routing.

All scores are in SECONDS of predicted delay:
    score(path) = sum(est_queue_s[link]) + hops * hop_latency + bias_s
where bias_s = mode.minimal_bias * bias_unit_s is charged to non-minimal
candidates only.

The simulator distributes each flow's bytes across candidates with a
softmin over scores (temperature = per-packet noise scale): this is the
fluid limit of per-packet argmin-with-noise selection — P(packet takes
candidate c) = softmax(-score/T)_c for Gumbel(T) packet noise.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.strategies import RoutingMode
from repro.dragonfly.topology import PAD


@dataclass(frozen=True)
class RoutingPolicy:
    mode: RoutingMode
    #: seconds of predicted delay per unit of minimal bias (paper: the exact
    #: Aries bias values are not public; this is the calibration constant).
    #: Sized so HIGH BIAS (8 units = 160us) overrides transient self-
    #: congestion and phantom ghosts, but yields to real ms-scale backlogs.
    bias_unit_s: float = 20e-6
    #: softmin temperature == per-packet congestion-estimate noise scale.
    spray_temperature_s: float = 10e-6
    #: per-hop latency charged in the score (router pipeline).
    hop_latency_s: float = 100e-9

    @property
    def bias_s(self) -> float:
        return mode_bias_s(self.mode, self.bias_unit_s)


def mode_bias_s(mode: RoutingMode, bias_unit_s: float) -> float:
    """Seconds of minimal bias for one mode.  Deterministic modes return
    raw ±inf (never scaled — inf * unit would be inf anyway, but the raw
    value is the sentinel score_candidates branches on)."""
    b = mode.minimal_bias
    if mode is RoutingMode.ADAPTIVE_1:
        # Increasingly-minimal: bias ramps 0 -> terminal along the path;
        # in the fluid model we charge the path-average (half terminal).
        return b * 0.5 * bias_unit_s
    if np.isinf(b):
        return b
    return b * bias_unit_s


def score_candidates(link_ids: np.ndarray, est_queue_s: np.ndarray,
                     is_nonmin: np.ndarray, policy: RoutingPolicy,
                     modes: np.ndarray | None = None) -> np.ndarray:
    """Predicted-delay score per candidate (seconds; lower is better).

    link_ids:    [n, ncand, max_hops] PAD-padded link ids
    est_queue_s: [n_links] estimated (stale/noisy) seconds-to-drain
    modes:       optional [n] object array of per-flow RoutingModes; when
                 given, each flow is biased by its own mode (the
                 PolicyEngine path: one batched call per phase, mixed
                 modes welcome).  Without it, policy.mode biases all rows.
    """
    valid = link_ids != PAD
    safe = np.where(valid, link_ids, 0)
    q = est_queue_s[safe] * valid        # [n, ncand, hops]
    hops = valid.sum(axis=-1)            # [n, ncand]
    score = q.sum(axis=-1) + policy.hop_latency_s * hops
    if modes is None:
        bias = policy.bias_s
        if np.isposinf(bias):                # deterministic minimal
            score = np.where(is_nonmin[None, :], np.inf, score)
        elif np.isneginf(bias):              # deterministic non-minimal
            score = np.where(is_nonmin[None, :], score, np.inf)
        else:
            score = score + np.where(is_nonmin[None, :], bias, 0.0)
        return score
    # --- per-flow modes: one masked pass per UNIQUE mode (<= 7) ----------
    n = score.shape[0]
    bias_rows = np.zeros(n)
    posinf = np.zeros(n, dtype=bool)
    neginf = np.zeros(n, dtype=bool)
    for mode in {m for m in modes}:
        rows = modes == mode
        b = mode_bias_s(mode, policy.bias_unit_s)
        if np.isposinf(b):
            posinf |= rows
        elif np.isneginf(b):
            neginf |= rows
        else:
            bias_rows[rows] = b
    score = score + np.where(is_nonmin[None, :], bias_rows[:, None], 0.0)
    score = np.where(posinf[:, None] & is_nonmin[None, :], np.inf, score)
    score = np.where(neginf[:, None] & ~is_nonmin[None, :], np.inf, score)
    return score


def spray_weights(scores: np.ndarray, policy: RoutingPolicy,
                  rng: np.random.Generator | None = None,
                  packets: np.ndarray | None = None) -> np.ndarray:
    """Byte distribution over candidates: softmin(scores / T).

    When candidate scores are close (ADAPTIVE, bias 0) bytes spread across
    paths (packet spraying); when the bias separates them (HIGH BIAS) bytes
    concentrate on minimal paths.  Deterministic modes collapse to one
    class.

    The optional Gumbel jitter is the *sampling error* of per-packet
    selection: each packet draws its own noisy estimate, so a message of
    `packets` packets realizes the softmin distribution with ~1/sqrt(p)
    relative error — a single-packet message takes exactly one path, a
    64k-packet message matches the distribution almost exactly."""
    t = max(policy.spray_temperature_s, 1e-12)
    s = scores.copy()
    if rng is not None:
        scale = t * 0.9
        if packets is not None:
            scale = scale / np.sqrt(np.maximum(packets, 1.0))[:, None]
        s = s + rng.gumbel(0.0, 1.0, size=s.shape) * scale
    s = np.where(np.isfinite(s), s, np.inf)
    smin = s.min(axis=1, keepdims=True)
    # rows with no usable candidate (all inf): shift by 0 instead of inf
    # so exp(-inf) cleanly zeroes them without inf-inf NaN warnings
    smin = np.where(np.isfinite(smin), smin, 0.0)
    z = np.exp(-(s - smin) / t)
    z = np.where(np.isfinite(z), z, 0.0)
    tot = z.sum(axis=1, keepdims=True)
    tot = np.where(tot <= 0, 1.0, tot)
    return z / tot
