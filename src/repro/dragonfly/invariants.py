"""Cross-topology structural invariants (docs/topology.md).

Every topology in the family must satisfy the same battery of checks,
whatever its internal link-id arithmetic.  The battery is shared by the
property-test harness (tests/test_topology_family.py) and the headless
CI gate (``scripts/ci_lint.py --topology``): each ``check_*`` function
raises ``InvariantViolation`` with a topology-labelled message, and
``check_all`` runs the full battery on sampled (src, dst) pairs.
"""

from __future__ import annotations

import numpy as np

from repro.dragonfly.topology import PAD, Topology

__all__ = [
    "InvariantViolation",
    "check_all",
    "check_candidates",
    "check_capacity_scale",
    "check_fault_mask",
    "check_link_ranges",
    "check_router_radix",
    "sample_pairs",
]


class InvariantViolation(AssertionError):
    """A topology broke one of the family-wide structural invariants."""


def _fail(topo: Topology, msg: str):
    raise InvariantViolation(f"[{topo.spec_str()}] {msg}")


def sample_pairs(topo: Topology, n: int = 256, seed: int = 1):
    """Deterministic (src, dst) sample with src != dst, covering intra-
    and inter-group pairs."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, topo.n_nodes, size=n)
    dst = (src + rng.integers(1, topo.n_nodes, size=n)) % topo.n_nodes
    return src, dst


def check_link_ranges(topo: Topology) -> None:
    """link_ranges() partitions [0, n_links) with no gaps or overlaps,
    and one 'nic' range of n_nodes injection links comes last."""
    ranges = topo.link_ranges()
    if "nic" not in ranges:
        _fail(topo, "link_ranges() has no 'nic' class")
    spans = sorted(ranges.values())
    if not spans or spans[0][0] != 0 or spans[-1][1] != topo.n_links:
        _fail(topo, f"link ranges {ranges} do not span [0, {topo.n_links})")
    for (_, b), (c, _) in zip(spans, spans[1:]):
        if b != c:
            _fail(topo, f"link ranges {ranges} gap/overlap at {b} vs {c}")
    lo, hi = ranges["nic"]
    if hi - lo != topo.n_nodes or hi != topo.n_links:
        _fail(topo, f"nic range {ranges['nic']} is not the trailing "
                    f"{topo.n_nodes} links")
    nic = topo.nic_link(np.arange(topo.n_nodes))
    if not (np.array_equal(nic, np.arange(lo, hi))):
        _fail(topo, "nic_link() disagrees with the 'nic' link range")
    for kind, (lo, hi) in ranges.items():
        if topo.link_kind(lo) != kind or topo.link_kind(hi - 1) != kind:
            _fail(topo, f"link_kind() disagrees with range for {kind!r}")


def check_router_radix(topo: Topology) -> None:
    """Measured outgoing router->router degree (from link_endpoints)
    matches the spec-side expected_router_degree."""
    sr, dr = topo.link_endpoints()
    if sr.shape != (topo.n_links,) or dr.shape != (topo.n_links,):
        _fail(topo, "link_endpoints() arrays are not [n_links]")
    lo, hi = topo.link_ranges()["nic"]
    if not (sr[lo:hi] == -1).all():
        _fail(topo, "nic links must have src_router == -1 (node side)")
    want_dr = topo.router_of_node(np.arange(topo.n_nodes))
    if not np.array_equal(dr[lo:hi], want_dr):
        _fail(topo, "nic links must land on router_of_node")
    deg = np.bincount(sr[sr >= 0], minlength=topo.n_routers)
    exp = np.asarray(topo.expected_router_degree())
    if exp.shape != (topo.n_routers,):
        _fail(topo, "expected_router_degree() is not [n_routers]")
    if not np.array_equal(deg, exp):
        bad = np.flatnonzero(deg != exp)[:5]
        _fail(topo, f"router radix mismatch at routers {bad.tolist()}: "
                    f"measured {deg[bad].tolist()} vs spec "
                    f"{exp[bad].tolist()}")


def check_candidates(topo: Topology, src, dst, *, rng=None,
                     n_min: int = 2, n_nonmin: int = 2) -> None:
    """candidates() paths are valid link-id sequences: in range, on
    physical router-router links, contiguous (consecutive links share a
    router), starting/ending at the src/dst routers, within the hop
    bounds, and (when the topology claims it) inter-group Valiant paths
    transit exactly one intermediate group."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    links, is_nonmin = topo.candidates(src, dst, rng, n_min=n_min,
                                       n_nonmin=n_nonmin)
    n = src.shape[0]
    if links.shape != (n, n_min + n_nonmin, topo.MAX_HOPS):
        _fail(topo, f"candidates() shape {links.shape} != "
                    f"{(n, n_min + n_nonmin, topo.MAX_HOPS)}")
    if list(is_nonmin) != [False] * n_min + [True] * n_nonmin:
        _fail(topo, f"is_nonmin {is_nonmin} is not minimal-then-Valiant")
    valid = links != PAD
    flat = links[valid]
    if flat.size and (flat.min() < 0 or flat.max() >= topo.n_links):
        _fail(topo, "candidate entries outside [0, n_links)")
    sr, dr = topo.link_endpoints()
    nic_lo, _ = topo.link_ranges()["nic"]
    if flat.size and (flat >= nic_lo).any():
        _fail(topo, "candidate paths must not contain NIC links")
    if flat.size and (sr[flat] < 0).any():
        _fail(topo, "candidate paths use non-physical link slots")
    hops = valid.sum(axis=2)
    if hops[:, ~is_nonmin].max(initial=0) > topo.max_minimal_hops:
        _fail(topo, f"minimal path exceeds max_minimal_hops="
                    f"{topo.max_minimal_hops}")
    if hops[:, is_nonmin].max(initial=0) > topo.max_nonmin_hops:
        _fail(topo, f"Valiant path exceeds max_nonmin_hops="
                    f"{topo.max_nonmin_hops}")
    r_src = np.asarray(topo.router_of_node(src))
    r_dst = np.asarray(topo.router_of_node(dst))
    g_src = np.asarray(topo.group_of_node(src))
    g_dst = np.asarray(topo.group_of_node(dst))
    for i in range(n):
        for c in range(links.shape[1]):
            path = links[i, c][valid[i, c]]
            if path.size == 0:
                if src[i] != dst[i] and r_src[i] != r_dst[i]:
                    _fail(topo, f"empty path for cross-router pair "
                                f"({src[i]}, {dst[i]})")
                continue
            if sr[path[0]] != r_src[i]:
                _fail(topo, f"path for ({src[i]},{dst[i]}) cand {c} does "
                            f"not start at the src router")
            if dr[path[-1]] != r_dst[i]:
                _fail(topo, f"path for ({src[i]},{dst[i]}) cand {c} does "
                            f"not end at the dst router")
            if (dr[path[:-1]] != sr[path[1:]]).any():
                _fail(topo, f"path for ({src[i]},{dst[i]}) cand {c} is "
                            f"not contiguous")
            if (topo.valiant_transits_group and is_nonmin[c]
                    and g_src[i] != g_dst[i]):
                routers = np.concatenate([sr[path], dr[path]])
                grp = np.unique(topo.group_of_router(routers))
                mid = set(grp.tolist()) - {int(g_src[i]), int(g_dst[i])}
                if int(g_src[i]) not in grp or int(g_dst[i]) not in grp \
                        or len(mid) != 1:
                    _fail(topo, f"Valiant path for ({src[i]},{dst[i]}) "
                                f"cand {c} transits groups {sorted(mid)} "
                                f"(want exactly one)")


def check_capacity_scale(topo: Topology, state) -> None:
    """A FaultState's capacity_scale is a well-formed per-link scale:
    float64 [n_links], finite, in [0, 1], with ``dead`` exactly the
    (near-)zero entries."""
    scale = np.asarray(state.capacity_scale)
    if scale.shape != (topo.n_links,):
        _fail(topo, f"capacity_scale shape {scale.shape} != "
                    f"({topo.n_links},)")
    if scale.dtype != np.float64:
        _fail(topo, f"capacity_scale dtype {scale.dtype} != float64")
    if not np.isfinite(scale).all():
        _fail(topo, "capacity_scale has non-finite entries")
    if scale.min(initial=1.0) < 0.0 or scale.max(initial=0.0) > 1.0:
        _fail(topo, "capacity_scale outside [0, 1]")
    dead = np.asarray(state.dead)
    if dead.shape != scale.shape or dead.dtype != bool:
        _fail(topo, "dead mask shape/dtype mismatch with capacity_scale")
    if not np.array_equal(dead, scale <= 1e-9):
        _fail(topo, "dead mask disagrees with capacity_scale zeros")


def check_fault_mask(topo: Topology, dead, src, dst, *, rng=None,
                     n_min: int = 2, n_nonmin: int = 2) -> None:
    """Fault-mask semantics over the PAD-padded candidate tensors
    (docs/faults.md): the vectorized mask the simulator derives from a
    dead-link flag array must agree with a per-path scalar recheck —

      * a candidate survives iff NO link on its path is dead (PAD
        entries never count: the mask gather must not be poisoned by
        the `safe` placeholder link 0, even when link 0 itself dies);
      * masking never rewrites the candidate tensor: the PAD layout is
        untouched (the mask lives beside the tensor, never inside it),
        so surviving candidates keep their exact PAD-masked paths;
      * reachability accounting: a flow is stranded iff every candidate
        crosses a dead link (endpoint-NIC deaths are checked by the
        simulator on top of this).
    """
    dead = np.asarray(dead, dtype=bool)
    if dead.shape != (topo.n_links,):
        _fail(topo, f"dead mask shape {dead.shape} != ({topo.n_links},)")
    src = np.asarray(src)
    dst = np.asarray(dst)
    links, is_nonmin = topo.candidates(src, dst, rng, n_min=n_min,
                                       n_nonmin=n_nonmin)
    frozen = links.copy()
    valid = links != PAD
    safe = np.where(valid, links, 0)
    cand_alive = ~((dead[safe] & valid).any(axis=-1))
    stranded = ~cand_alive.any(axis=-1)
    if not np.array_equal(links, frozen):
        _fail(topo, "mask computation mutated the candidate tensor")
    # PAD-placeholder immunity: PAD slots gather link 0 through `safe`;
    # killing link 0 must only ever change candidates whose PATH truly
    # contains link 0 — never a candidate that merely has PAD slots
    dead0 = dead.copy()
    dead0[0] = True
    alive0 = ~((dead0[safe] & valid).any(axis=-1))
    contains0 = ((links == 0) & valid).any(axis=-1)
    if ((alive0 != cand_alive) & ~contains0).any():
        _fail(topo, "PAD placeholder poisons the fault mask when link 0 "
                    "is dead")
    # scalar recheck, flow by flow
    for i in range(src.shape[0]):
        for c in range(links.shape[1]):
            path = links[i, c][valid[i, c]]
            want = not dead[path].any() if path.size else True
            if bool(cand_alive[i, c]) != want:
                _fail(topo, f"fault mask disagrees with scalar recheck "
                            f"for pair ({src[i]},{dst[i]}) cand {c}")
        if bool(stranded[i]) != (not any(
                not dead[links[i, c][valid[i, c]]].any()
                if valid[i, c].any() else True
                for c in range(links.shape[1]))):
            _fail(topo, f"stranded accounting wrong for pair "
                        f"({src[i]},{dst[i]})")
    # the mask must never kill a candidate on a healthy machine
    if not dead.any() and not cand_alive.all():
        _fail(topo, "mask kills candidates with no dead links")


def check_all(topo: Topology, *, n_pairs: int = 256, seed: int = 1) -> None:
    """The full battery on a deterministic pair sample."""
    check_link_ranges(topo)
    check_router_radix(topo)
    src, dst = sample_pairs(topo, n=n_pairs, seed=seed)
    check_candidates(topo, src, dst, rng=np.random.default_rng(seed + 6))
