# repro.dragonfly — Cray-Aries-like Dragonfly network substrate.
#
# This package is the experimental platform of the faithful reproduction:
# the paper measures on Piz Daint / Cori (Cray Aries); this container has no
# network, so we reproduce the paper's experiments against a flow-level
# ("fluid") congestion model of the Aries Dragonfly with UGAL-style adaptive
# routing, credit-stall accounting, and phantom congestion.  The paper's §6
# discusses simulation fidelity limits; ours is calibrated to reproduce the
# qualitative phenomena (allocation-tier latency ladder, adaptive-vs-bias
# crossover, alltoall spreading preference, heavy outlier tails), not
# cycle-accuracy.

from repro.dragonfly.topology import (Allocation, DragonflyTopology,
                                      Topology, TopologyParams,
                                      make_topology, registered_topologies,
                                      small_topology)
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.simulator import (DragonflySimulator, SimParams,
                                       FlowResult, PhasePlan,
                                       TenantSegments)
from repro.dragonfly.traffic import (
    pingpong, allreduce, alltoall, barrier, broadcast, halo3d, sweep3d,
    moe_alltoall, PATTERNS,
)

__all__ = [
    "DragonflyTopology", "Topology", "TopologyParams", "Allocation",
    "make_topology", "registered_topologies", "small_topology",
    "RoutingPolicy",
    "DragonflySimulator", "SimParams", "FlowResult", "PhasePlan",
    "TenantSegments",
    "pingpong", "allreduce", "alltoall", "barrier", "broadcast", "halo3d",
    "sweep3d", "moe_alltoall", "PATTERNS",
]
