"""Device-resident jitted phase engine for the Dragonfly simulator.

``SimParams.backend = "jax"`` routes the score -> spray -> feedback
fixed point -> observables pipeline of ``run_phase`` through ONE jitted
function whose feedback loop is a ``lax.fori_loop`` — iterations never
touch the host, and compile time no longer scales with
``route_feedback_iters``.  Three things make the path device-resident:

  * **In-graph scoring.** The host no longer materializes ``score0``
    for the jax path: the loop-invariant score base (queue-estimate
    gather + hop latency + bias/notification terms) is computed inside
    the graph from the per-link estimate vector, so the expensive
    [n, ncand, hops] gather runs fused in XLA instead of NumPy.

  * **Plan-pinned device buffers.** When a :class:`PhasePlan` is
    replayed, its phase-invariant tensors (``safe``/``valid``/``hops``/
    ``pair_links``/``pair_fc``/``nic_ids``) are transferred once and
    pinned on the plan (``plan.device_bundle``); the plan cache key
    already covers topology spec + fault epoch + notify epoch, so a
    stale bundle cannot outlive its plan.  Per phase only the small
    per-link state, the background-flow slivers, and the Gumbel noise
    block move host->device — the noise block is donated
    (``donate_argnums`` via ``repro.compat.jit_compiled``) so XLA can
    reuse its buffer for the outputs.

  * **Stable shapes.** Background flows redraw candidates per phase,
    which used to change the (link, flow-cand) pair-list length P every
    phase and force a full recompile EVERY phase (the 2.64s
    ``fixed_point`` stage of the v1 bench was almost entirely XLA
    retracing).  Pair lists are now padded to coarse buckets with
    zero-weight entries (mask 0.0, link 0 — exact no-ops under the
    segment sum), so steady-state phases reuse one compiled executable.

Fault candidate masks and congestion-notification penalties are both
consumed in-graph (the mask as a ``where(+inf)`` before every softmin,
the penalty folded into the per-link estimate by the caller), so
faulted / notification-active phases no longer fall back to numpy.

``fixed_point_jax_batch`` evaluates SEVERAL phases (one per simulator)
through a single ``jax.vmap``-ed dispatch when their shapes/statics
agree — the entry point ``run_phase_batch`` / the tenancy lockstep
driver use to batch whole sweep columns.

RNG parity: ALL randomness (background draws, candidate paths, phantom
noise, per-iteration Gumbel spray noise) is drawn on the host from the
simulator's NumPy generator — the jitted pipeline is deterministic in
its inputs, so the jax backend consumes the RNG stream draw-for-draw
like the NumPy backend and matches it within float32 tolerance
(pinned at rtol=2e-2 for the Eq.(2) times in the tests).
"""

from __future__ import annotations

import functools
import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat.compilation import jit_compiled
from repro.compat.runtime import on_tpu, resolve_pallas_kernel
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.segment_sum.segment_sum import segment_sum_pallas

# CPU/GPU backends cannot always alias the donated Gumbel block into an
# output buffer; the fallback (a silent copy) is exactly the pre-donation
# behavior, so the warning is noise here.
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")

#: diagnostics: executed-pipeline counters ("single"/"batched" jitted
#: dispatches).  Tests and perf_sim assert on deltas to prove the jax
#: path actually ran instead of silently falling back to numpy.
PIPELINE_CALLS = {"single": 0, "batched": 0}

#: pair-list padding buckets (docs/performance.md).  Plan-reused phases
#: only redraw the ~bg_flows_per_phase background rows, so their pair
#: tail is padded to a small bucket; planless phases redraw everything
#: and get a coarse bucket.  Bigger buckets = fewer distinct compiled
#: shapes at the cost of a few zero-weight pairs per segment sum.
_PAIR_BUCKET_PLAN = 256
_PAIR_BUCKET_FULL = 4096

#: block width of the sorted-head prefix sum.  The pinned sorted pair
#: list is padded to a multiple of this (zero-mask entries on the last
#: link), so the blocked cumsum needs no remainder handling.
_CUMSUM_BLOCK = 1024


def _padded_len(n: int, bucket: int) -> int:
    return -(-max(int(n), 1) // bucket) * bucket


# --------------------------------------------------------------- pipeline
def _phase_pipeline(safe, validf, hops, is_nonmin, cand_mask, est_queue_s,
                    link_queue_s, hl_rows, bias_rows, posinf, neginf,
                    t_rows, noise_scale, gnoise, size_all, cap_window,
                    nic_ids, pair_links, pair_fc, pair_mask, seg_off,
                    window_s, feedback_rho0, rho_threshold, queue_delay_ns,
                    qwait_fraction, stall_gain, nic_latency_ns,
                    hop_latency_ns, *, n_spray: int, n_links: int,
                    use_kernel: bool, interpret: bool, p_sorted: int):
    """One phase: score -> spray -> fori_loop feedback -> observables.

    Pure in its arguments; statics select the segment-sum implementation
    (Pallas vs jax.ops.segment_sum) and fix loop count / bin count.
    ``cand_mask`` may be None (healthy machine) — the mask branch then
    never enters the graph.  ``pair_mask`` zeroes the bucket-padding
    entries so they are exact no-ops in every accumulation.

    ``p_sorted``/``seg_off``: the first ``p_sorted`` pair entries are
    pre-sorted by link id on the host (the plan-pinned app pairs), with
    ``seg_off`` their [n_links+1] segment offsets.  That head reduces
    via cumsum-diff — XLA CPU runs it ~5x faster than the scatter-add
    lowering of `segment_sum` — while the unsorted tail (the per-phase
    background sliver) still scatter-adds.  The Pallas-kernel path keeps
    the scatter layout its kernel is written for.
    """
    def seg_sum(vals, ids):
        if use_kernel:
            return segment_sum_pallas(vals, ids, n_links,
                                      interpret=interpret)
        return segment_sum_ref(vals, ids, n_links)

    def pair_sum(vals):
        if use_kernel or not p_sorted:
            return seg_sum(vals, pair_links)
        # blocked prefix sum over the sorted head: per-block cumsums
        # vectorize across rows where XLA CPU's 1-D cumsum does not, and
        # only the [n_links+1] boundary prefixes ever materialize.
        nb = p_sorted // _CUMSUM_BLOCK
        within = jnp.cumsum(vals[:p_sorted].reshape(nb, _CUMSUM_BLOCK),
                            axis=1)
        base = jnp.concatenate([jnp.zeros(1, vals.dtype),
                                jnp.cumsum(within[:, -1])])
        i, j = seg_off // _CUMSUM_BLOCK, seg_off % _CUMSUM_BLOCK
        w_in = within[jnp.minimum(i, nb - 1), jnp.maximum(j - 1, 0)]
        pref = base[i] + jnp.where(j > 0, w_in, 0.0)
        out = pref[1:] - pref[:-1]
        if vals.shape[0] > p_sorted:
            out = out + seg_sum(vals[p_sorted:], pair_links[p_sorted:])
        return out

    # loop-invariant score base, in-graph (the hoisted scorer of the
    # numpy fast path: estimate gather + hop latency + bias terms)
    base = (est_queue_s[safe] * validf).sum(axis=-1) \
        + hl_rows[:, None] * hops
    score0 = base + jnp.where(is_nonmin[None, :], bias_rows[:, None], 0.0)
    score0 = jnp.where(posinf[:, None] & is_nonmin[None, :], jnp.inf,
                       score0)
    score0 = jnp.where(neginf[:, None] & ~is_nonmin[None, :], jnp.inf,
                       score0)
    if cand_mask is not None:
        # fault path: candidates crossing dead links spray exactly zero
        # (all-False rows — stranded flows — spray nowhere)
        score0 = jnp.where(cand_mask, score0, jnp.inf)

    # a flow cannot inject more than its NIC moves in the window
    size_inst = jnp.minimum(size_all, cap_window[nic_ids])
    nic_load = seg_sum(size_inst, nic_ids)

    def spray(score, g):
        s = score + g * noise_scale
        s = jnp.where(jnp.isfinite(s), s, jnp.inf)
        smin = s.min(axis=1, keepdims=True)
        smin = jnp.where(jnp.isfinite(smin), smin, 0.0)
        z = jnp.exp(-(s - smin) / t_rows[:, None])
        tot = z.sum(axis=1, keepdims=True)
        tot = jnp.where(tot <= 0, 1.0, tot)
        return z / tot

    def loads(w):
        vals = (size_inst[:, None] * w).reshape(-1)[pair_fc] * pair_mask
        return pair_sum(vals) + nic_load

    w0 = spray(score0, gnoise[0])

    def body(carry, g):
        w, load_i = carry
        rho_fb = load_i / cap_window
        extra = jnp.maximum(0.0, rho_fb - feedback_rho0) * window_s
        score = score0 + (extra[safe] * validf).sum(axis=-1)
        w = 0.5 * (w + spray(score, g))
        return (w, loads(w)), None

    # scan (not fori_loop + dynamic_index): the per-iteration noise block
    # arrives as a scanned input, so XLA skips the in-loop gather-copy of
    # gnoise[it]; compile time still does not scale with n_spray
    (w, load_i), _ = jax.lax.scan(body, (w0, loads(w0)), gnoise[1:])
    del n_spray                           # loop count lives in the shape

    load_q = pair_sum((size_all[:, None] * w).reshape(-1)[pair_fc]
                      * pair_mask)
    rho = load_i / cap_window

    # --- observables: per-flow (L_us, s) ------------------------------
    rho_path = rho[safe] * validf                   # [n, ncand, hops]
    excess = jnp.maximum(0.0, rho_path - rho_threshold)
    qdelay_ns = queue_delay_ns * excess.sum(axis=-1)
    qwait_ns = (link_queue_s[safe] * validf).sum(axis=-1) \
        * qwait_fraction * 1e9
    lat_ns_cand = 2.0 * nic_latency_ns + hops * hop_latency_ns \
        + qdelay_ns + qwait_ns
    lat_us = (lat_ns_cand * w).sum(axis=-1) / 1e3
    rho_nic = rho[nic_ids]
    rho_bneck = jnp.maximum(rho_path.max(axis=-1), rho_nic[:, None])
    s_cand = stall_gain * jnp.maximum(0.0, rho_bneck - rho_threshold)
    s_flit = (s_cand * w).sum(axis=-1)
    return w, rho, load_q, lat_us, s_flit


#: positional index of cand_mask / gnoise in _phase_pipeline's signature
_MASK_ARG = 4
_GNOISE_ARG = 13
_N_ARGS = 29


@functools.lru_cache(maxsize=None)
def _jitted_pipeline(n_spray: int, n_links: int, use_kernel: bool,
                     interpret: bool, p_sorted: int, batched: bool,
                     has_mask: bool):
    """Compiled pipeline per (statics, batched, mask-presence) combo.

    ``batched`` wraps the core in ``jax.vmap`` over a stacked leading
    phase axis — scalars ride along as [B] vectors.  The Gumbel noise
    block (the largest per-phase transfer) is donated.
    """
    core = functools.partial(_phase_pipeline, n_spray=n_spray,
                             n_links=n_links, use_kernel=use_kernel,
                             interpret=interpret, p_sorted=p_sorted)
    fn = core
    if batched:
        axes = [0] * _N_ARGS
        if not has_mask:
            axes[_MASK_ARG] = None      # cand_mask=None: empty pytree
        fn = jax.vmap(core, in_axes=tuple(axes))
    return jit_compiled(fn, donate_argnums=(_GNOISE_ARG,))


# ------------------------------------------------------- input preparation
def _f32(a):
    return jnp.asarray(a, dtype=jnp.float32)


def _i32(a):
    return jnp.asarray(a, dtype=jnp.int32)


def _device_plan(plan, n_links: int) -> dict:
    """Pin a PhasePlan's phase-invariant tensors on device (once).

    Stored ON the plan (``plan.device_bundle``) so the bundle's lifetime
    is exactly the plan's; `plan_for`'s cache key already covers the
    topology spec and the fault/notify epochs, which is what keys the
    device side of the cache too.

    The pair list is pinned SORTED BY LINK ID (a host-side argsort, paid
    once per plan), padded to a `_CUMSUM_BLOCK` multiple with zero-mask
    entries on the last link (sort order survives, padded values are
    exactly 0.0), with its segment offsets alongside — the pipeline's
    blocked cumsum-diff reduction needs sorted block-aligned segments,
    and scatter-based consumers are order-insensitive, so the reorder is
    transparent to the Pallas path.  The plan's own (host) arrays keep
    original order: numpy-backend parity is untouched."""
    dev = plan.device_bundle
    if dev is None:
        pl = np.asarray(plan.pair_links)
        order = np.argsort(pl, kind="stable")
        p_pad = _padded_len(pl.shape[0], _CUMSUM_BLOCK)
        links = np.full(p_pad, n_links - 1, dtype=np.int32)
        links[:pl.shape[0]] = pl[order]
        fc = np.zeros(p_pad, dtype=np.int32)
        fc[:pl.shape[0]] = np.asarray(plan.pair_fc)[order]
        mask = np.zeros(p_pad, dtype=np.float32)
        mask[:pl.shape[0]] = 1.0
        off = np.zeros(n_links + 1, dtype=np.int64)
        np.cumsum(np.bincount(links, minlength=n_links), out=off[1:])
        dev = {
            "safe": _i32(plan.safe),
            "validf": _f32(plan.valid),
            "hops": _f32(plan.hops),
            "nic_ids": _i32(plan.nic_ids),
            "pair_links": jnp.asarray(links),
            "pair_fc": jnp.asarray(fc),
            "pair_mask": jnp.asarray(mask),
            "seg_off": _i32(off),
            "p_sorted": p_pad,
        }
        plan.device_bundle = dev
    return dev


@functools.lru_cache(maxsize=None)
def _tail_writer(n_app: int, p_head: int):
    """Jitted donated-buffer tail update: writes the per-phase background
    rows/pairs into the pinned full-size buffers IN PLACE (the buffers
    are donated, so XLA aliases them instead of copying the plan-pinned
    head every phase)."""
    def write(bufs, tails):
        rows = tuple(b.at[n_app:].set(t)
                     for b, t in zip(bufs[:4], tails[:4]))
        pairs = tuple(b.at[p_head:].set(t)
                      for b, t in zip(bufs[4:], tails[4:]))
        return rows + pairs
    return jit_compiled(write, donate_argnums=(0,))


def _pad_pairs(links: np.ndarray, fc: np.ndarray, pad_to: int):
    """Host-side bucket padding of a pair-list tail.

    Padding entries carry mask 0.0 and link/fc 0: the masked value is
    exactly 0.0, so scatter-adding it into bin 0 is a bitwise no-op —
    shapes stabilize without perturbing any segment sum."""
    n = links.shape[0]
    pl = np.zeros(pad_to, dtype=np.int32)
    pl[:n] = links
    pf = np.zeros(pad_to, dtype=np.int32)
    pf[:n] = fc
    pm = np.zeros(pad_to, dtype=np.float32)
    pm[:n] = 1.0
    return jnp.asarray(pl), jnp.asarray(pf), jnp.asarray(pm)


def padded_pair_len(ctx: dict) -> int:
    """Total pair-list length AFTER bucket padding (shape-signature
    component: phases agreeing here share one compiled executable)."""
    P = int(ctx["pair_links"].shape[0])
    plan = ctx["plan"]
    if plan is not None:
        p_app = int(plan.pair_links.shape[0])
        head = _padded_len(p_app, _CUMSUM_BLOCK)
        n_bg = P - p_app
        if n_bg == 0:
            return head
        return head + _padded_len(n_bg, _PAIR_BUCKET_PLAN)
    return _padded_len(P, _PAIR_BUCKET_FULL)


def _prepare_inputs(sim, ctx: dict):
    """ctx (from `_phase_begin`) -> (pipeline inputs, statics)."""
    p = sim.params
    tp = sim.topo
    plan = ctx["plan"]
    n_app = ctx["n_app"]

    if plan is not None:
        dev = _device_plan(plan, int(tp.n_links))
        seg_off = dev["seg_off"]
        p_sorted = dev["p_sorted"]
        n_all = ctx["safe"].shape[0]
        if n_all > n_app:               # background rows ride along
            sl = slice(n_app, None)
            p_app = plan.pair_links.shape[0]
            n_bg = ctx["pair_links"].shape[0] - p_app
            bl, bf, bm = _pad_pairs(ctx["pair_links"][p_app:],
                                    ctx["pair_fc"][p_app:],
                                    _padded_len(n_bg, _PAIR_BUCKET_PLAN))
            tails = (_i32(ctx["safe"][sl]), _f32(ctx["valid"][sl]),
                     _f32(ctx["hops"][sl]), _i32(ctx["nic_ids"][sl]),
                     bl, bf, bm)
            bufs = dev.get("bufs")
            if (bufs is not None and bufs[0].shape[0] == n_all
                    and bufs[4].shape[0] == p_sorted + bl.shape[0]):
                # steady state: write ONLY the tails into the donated
                # full-size buffers — the pinned head is never re-copied
                dev["bufs"] = None       # donation consumes the olds
                bufs = _tail_writer(n_app, p_sorted)(bufs, tails)
            else:
                bufs = tuple(
                    jnp.concatenate([head, tail]) for head, tail in zip(
                        (dev["safe"], dev["validf"], dev["hops"],
                         dev["nic_ids"], dev["pair_links"],
                         dev["pair_fc"], dev["pair_mask"]), tails))
            dev["bufs"] = bufs
            (safe, validf, hops, nic_ids,
             pair_links, pair_fc, pair_mask) = bufs
        else:
            safe, validf = dev["safe"], dev["validf"]
            hops, nic_ids = dev["hops"], dev["nic_ids"]
            pair_links, pair_fc = dev["pair_links"], dev["pair_fc"]
            pair_mask = dev["pair_mask"]
    else:
        safe = _i32(ctx["safe"])
        validf = _f32(ctx["valid"])
        hops = _f32(ctx["hops"])
        nic_ids = _i32(ctx["nic_ids"])
        pair_links, pair_fc, pair_mask = _pad_pairs(
            ctx["pair_links"], ctx["pair_fc"],
            _padded_len(ctx["pair_links"].shape[0], _PAIR_BUCKET_FULL))
        seg_off = jnp.zeros(int(tp.n_links) + 1, dtype=jnp.int32)
        p_sorted = 0                     # planless: scatter everything

    cm = ctx["cand_mask"]
    inputs = (
        safe, validf, hops, jnp.asarray(ctx["is_nonmin"]),
        None if cm is None else jnp.asarray(cm),
        _f32(ctx["est_queue_s"]), _f32(sim.link_queue_s),
        _f32(ctx["hl_rows"]), _f32(ctx["bias_rows"]),
        jnp.asarray(ctx["posinf"]), jnp.asarray(ctx["neginf"]),
        _f32(ctx["t_rows"]), _f32(ctx["noise_scale"]),
        jnp.asarray(np.asarray(ctx["gnoise"], dtype=np.float32)),
        _f32(ctx["size_all"]), _f32(ctx["cap_window"]), nic_ids,
        pair_links, pair_fc, pair_mask, seg_off,
        jnp.float32(ctx["window_s"]), jnp.float32(p.feedback_rho0),
        jnp.float32(p.rho_threshold), jnp.float32(p.queue_delay_ns),
        jnp.float32(p.qwait_fraction), jnp.float32(p.stall_gain),
        jnp.float32(tp.nic_latency_ns), jnp.float32(tp.hop_latency_ns),
    )
    statics = (int(ctx["gnoise"].shape[0]), int(tp.n_links),
               resolve_pallas_kernel(p.pallas_kernel), not on_tpu(),
               p_sorted)
    return inputs, statics


def batch_signature(sim, ctx: dict) -> tuple:
    """Hashable key: phases with equal keys (shapes + statics + mask
    presence) can share one vmapped dispatch."""
    p = sim.params
    plan = ctx["plan"]
    return (int(sim.topo.n_links), int(ctx["gnoise"].shape[0]),
            resolve_pallas_kernel(p.pallas_kernel), not on_tpu(),
            tuple(ctx["safe"].shape), padded_pair_len(ctx),
            0 if plan is None else _padded_len(plan.pair_links.shape[0],
                                               _CUMSUM_BLOCK),
            ctx["cand_mask"] is not None)


# ------------------------------------------------------------ entry points
def fixed_point_jax(sim, ctx: dict):
    """One phase on device; float64 numpy outputs (kernel contract:
    (w, rho, load_q, lat_us, s_flit), same as `_fixed_point_numpy`)."""
    inputs, statics = _prepare_inputs(sim, ctx)
    fn = _jitted_pipeline(*statics, batched=False,
                          has_mask=ctx["cand_mask"] is not None)
    out = fn(*inputs)
    PIPELINE_CALLS["single"] += 1
    return tuple(np.asarray(o, dtype=np.float64) for o in out)


def fixed_point_jax_batch(batch):
    """Many phases, ONE vmapped dispatch.

    ``batch``: [(sim, ctx)] whose `batch_signature`s agree (the caller
    groups).  Returns one kernel-output tuple per entry, batch order.
    Cells keep their own simulators/RNG streams — batching changes the
    dispatch, not the draws, so results match per-cell dispatch within
    float32 reassociation noise."""
    prepped = [_prepare_inputs(sim, ctx) for sim, ctx in batch]
    statics = prepped[0][1]
    has_mask = batch[0][1]["cand_mask"] is not None
    stacked = []
    for j, col in enumerate(zip(*(inp for inp, _ in prepped))):
        if j == _MASK_ARG and not has_mask:
            stacked.append(None)
            continue
        stacked.append(jnp.stack(col))
    fn = _jitted_pipeline(*statics, batched=True, has_mask=has_mask)
    outs = fn(*stacked)
    PIPELINE_CALLS["batched"] += 1
    return [tuple(np.asarray(o[b], dtype=np.float64) for o in outs)
            for b in range(len(batch))]
