"""Jitted JAX backend for the Dragonfly phase kernel.

``SimParams.backend = "jax"`` routes the score -> spray -> feedback
fixed point -> observables pipeline of ``run_phase`` through ONE
``jax.jit``-ed function; link-load accumulation goes through the
Pallas segment-sum kernel (``repro.kernels.segment_sum``) on TPU and
``jax.ops.segment_sum`` elsewhere.

RNG parity: ALL randomness (background draws, candidate paths, phantom
noise, per-iteration Gumbel spray noise) is drawn on the host from the
simulator's NumPy generator — the jitted pipeline is deterministic in
its inputs, so the jax backend consumes the RNG stream draw-for-draw
like the NumPy backend and matches it within float32 tolerance
(documented in docs/performance.md; the tests pin it at rtol=2e-2 for
the Eq.(2) times with much tighter agreement on the softmin weights).

Shapes are static per jit cache entry: phases with a new (n_flows,
n_pairs, iters) signature recompile.  The backend therefore suits
fixed-shape repeated phases (plan-reused collective rounds, train/serve
step loops) — heterogeneous sweeps should stay on NumPy.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.segment_sum import segment_sum_op


@functools.partial(jax.jit, static_argnames=("n_spray", "n_links",
                                             "force_kernel"))
def _pipeline(score0, safe, valid, hops, t_rows, noise_scale, gnoise,
              size_inst, size_all, pair_links, pair_fc, nic_load, nic_ids,
              link_queue_s, cap_window, window_s, feedback_rho0,
              rho_threshold, queue_delay_ns, qwait_fraction, stall_gain,
              nic_latency_ns, hop_latency_ns, *, n_spray: int,
              n_links: int, force_kernel: bool):
    validf = valid.astype(jnp.float32)

    def spray(score, g):
        s = score + g * noise_scale
        s = jnp.where(jnp.isfinite(s), s, jnp.inf)
        smin = s.min(axis=1, keepdims=True)
        smin = jnp.where(jnp.isfinite(smin), smin, 0.0)
        z = jnp.exp(-(s - smin) / t_rows[:, None])
        tot = z.sum(axis=1, keepdims=True)
        tot = jnp.where(tot <= 0, 1.0, tot)
        return z / tot

    def loads(w):
        vals = (size_inst[:, None] * w).reshape(-1)[pair_fc]
        seg = segment_sum_op(vals, pair_links, n_links,
                             force_kernel=force_kernel)
        return seg + nic_load

    w = spray(score0, gnoise[0])
    load_i = loads(w)
    for it in range(1, n_spray):
        rho_fb = load_i / cap_window
        extra = jnp.maximum(0.0, rho_fb - feedback_rho0) * window_s
        score = score0 + (extra[safe] * validf).sum(axis=-1)
        w = 0.5 * (w + spray(score, gnoise[it]))
        load_i = loads(w)

    load_q = segment_sum_op(
        (size_all[:, None] * w).reshape(-1)[pair_fc], pair_links,
        n_links, force_kernel=force_kernel)
    rho = load_i / cap_window

    # --- observables: per-flow (L_us, s) ------------------------------
    rho_path = rho[safe] * validf                   # [n, ncand, hops]
    excess = jnp.maximum(0.0, rho_path - rho_threshold)
    qdelay_ns = queue_delay_ns * excess.sum(axis=-1)
    qwait_ns = (link_queue_s[safe] * validf).sum(axis=-1) \
        * qwait_fraction * 1e9
    lat_ns_cand = 2.0 * nic_latency_ns + hops * hop_latency_ns \
        + qdelay_ns + qwait_ns
    lat_us = (lat_ns_cand * w).sum(axis=-1) / 1e3
    rho_nic = rho[nic_ids]
    rho_bneck = jnp.maximum(rho_path.max(axis=-1), rho_nic[:, None])
    s_cand = stall_gain * jnp.maximum(0.0, rho_bneck - rho_threshold)
    s_flit = (s_cand * w).sum(axis=-1)
    return w, rho, load_q, lat_us, s_flit


def fixed_point_jax(sim, *, score0, safe, valid, hops, est_queue_s,
                    hl_rows, is_nonmin, bias_rows, posinf, neginf, t_rows,
                    noise_scale, gnoise, size_inst, size_all, pair_links,
                    pair_fc, nic_load, nic_ids, cap_window, window_s):
    """`DragonflySimulator._fixed_point_numpy` signature, jax execution.

    Host-side NumPy float64 inputs go in as float32 (or int32 indices);
    outputs come back as float64 NumPy arrays.  The score/bias
    decomposition (est_queue_s, hl_rows, bias terms) is already folded
    into `score0` by the caller, so only the feedback `extra` term is
    recomputed in-graph.
    """
    del est_queue_s, hl_rows, is_nonmin, bias_rows, posinf, neginf  # folded
    p = sim.params
    tp = sim.topo   # Topology protocol attrs (identical for every family)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    out = _pipeline(
        f32(score0), i32(safe), jnp.asarray(valid), f32(hops),
        f32(t_rows), f32(noise_scale), f32(gnoise), f32(size_inst),
        f32(size_all), i32(pair_links), i32(pair_fc), f32(nic_load),
        i32(nic_ids), f32(sim.link_queue_s),
        f32(cap_window), f32(window_s), f32(p.feedback_rho0),
        f32(p.rho_threshold), f32(p.queue_delay_ns), f32(p.qwait_fraction),
        f32(p.stall_gain), f32(tp.nic_latency_ns), f32(tp.hop_latency_ns),
        n_spray=int(gnoise.shape[0]), n_links=int(sim.topo.n_links),
        force_kernel=False)
    return tuple(np.asarray(o, dtype=np.float64) for o in out)
