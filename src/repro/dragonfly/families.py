"""The non-Aries members of the topology family (docs/topology.md).

Three implementations of the :class:`repro.dragonfly.topology.Topology`
protocol:

* :class:`DragonflyFamily` — the standard ``(p, a, h, g)`` dragonfly
  parameterization (RAPS / MPINET style) with one router tier per group
  and either *palmtree* or *consecutive* global-link arrangement.  The
  balanced rule ``g = a*h + 1`` is the default group count (``g=0``).
* :class:`DragonflyPlusFamily` — Dragonfly+ per 2406.15097: two-tier
  leaf/spine groups, nodes on leaves, global links on spines.
* :class:`FatTreeControl` — a degenerate 2-level fat-tree used as the
  experimental control (no group locality at all; every inter-router
  route is leaf-spine-leaf).

All three use arithmetic directed link ids like the Aries layout:
local links first, then global links, then one NIC injection link per
node.  Unused arithmetic slots (local diagonals, out-of-round global
channels) decode to (-1, -1) in ``link_endpoints`` and simply never
appear in candidate paths.

Global-link arrangements (channel ``c`` of a group, ``m = c % (g-1)``,
round ``j = c // (g-1)``):

* consecutive: channel ``m`` points at group ``(grp + m + 1) % g``
* palmtree:    channel ``m`` points at group ``(grp - m - 1) % g``

Either way the reverse direction of round ``j``'s link between two
groups is that round's channel ``m' = g - 2 - m`` on the peer — which
is what makes the directed global ids consistent between the two ends.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.dragonfly.topology import (PAD, Topology, balanced_global_count,
                                      register_topology)

__all__ = [
    "DragonflyFamily",
    "DragonflyParams",
    "DragonflyPlusFamily",
    "DragonflyPlusParams",
    "FatTreeControl",
    "FatTreeParams",
]

_ARRANGEMENTS = ("palmtree", "consecutive")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


# =========================================================== Dragonfly(p,a,h,g)
@dataclass(frozen=True)
class DragonflyParams:
    """p nodes/router, a routers/group, h global ports/router, g groups
    (0 means the balanced rule g = a*h + 1)."""

    p: int = 2
    a: int = 4
    h: int = 2
    g: int = 0
    arrangement: str = "palmtree"
    local_gbs: float = 5.25
    global_gbs: float = 4.7
    nic_gbs: float = 10.0
    hop_latency_ns: float = 100.0
    nic_latency_ns: float = 600.0


class DragonflyFamily(Topology):
    """Parameterized single-tier dragonfly.

    Link-id layout (directed):
      local   [0, g*a*a)        (grp*a + r1)*a + r2   (diagonal unused)
      global  [+, + g*a*h)      grp*(a*h) + c,  c < rounds*(g-1) used
      nic     [+, + n_nodes)    one injection link per node
    """

    name = "dragonfly"
    max_minimal_hops = 3     # local, global, local
    max_nonmin_hops = 5      # local, global, local, global, local

    def __init__(self, params: DragonflyParams):
        p, a, h = params.p, params.a, params.h
        g = params.g or balanced_global_count(a, h)
        _require(p >= 1 and a >= 1 and h >= 1,
                 f"dragonfly wants p,a,h >= 1, got {p},{a},{h}")
        _require(g >= 3, f"dragonfly wants g >= 3 groups, got {g}")
        _require(params.arrangement in _ARRANGEMENTS,
                 f"arrangement must be one of {_ARRANGEMENTS}, "
                 f"got {params.arrangement!r}")
        _require(a * h >= g - 1,
                 f"g={g} groups need a*h >= g-1 global ports/group, "
                 f"got a*h={a * h}")
        self.params = params
        self.p, self.a, self.h, self.g = p, a, h, g
        self.arrangement = params.arrangement
        # rounds = parallel global links between every ordered group pair
        self.rounds = (a * h) // (g - 1)
        self.n_groups = g
        self.n_routers = g * a
        self.n_nodes = g * a * p
        self.nodes_per_router = p
        self.nodes_per_group = a * p
        self.n_node_routers = self.n_routers
        self.hop_latency_ns = params.hop_latency_ns
        self.nic_latency_ns = params.nic_latency_ns
        self._glob_off = g * a * a
        self._nic_off = self._glob_off + g * a * h
        self.n_links = self._nic_off + self.n_nodes
        cap = np.empty(self.n_links, dtype=np.float64)
        cap[:self._glob_off] = params.local_gbs
        cap[self._glob_off:self._nic_off] = params.global_gbs
        cap[self._nic_off:] = params.nic_gbs
        self.capacity_gbs = cap

    # ------------------------------------------------------------- structure
    def spec_str(self) -> str:
        return (f"dragonfly(p={self.p},a={self.a},h={self.h},g={self.g},"
                f"arrangement={self.arrangement})")

    def link_ranges(self) -> dict:
        return {"local": (0, self._glob_off),
                "global": (self._glob_off, self._nic_off),
                "nic": (self._nic_off, self.n_links)}

    def router_of_node(self, node):
        return np.asarray(node) // self.p

    def group_of_router(self, router):
        return np.asarray(router) // self.a

    def nic_link(self, node):
        return self._nic_off + np.asarray(node)

    def _used_channels(self) -> int:
        return self.rounds * (self.g - 1)

    def _peer_group(self, grp, m):
        if self.arrangement == "consecutive":
            return (grp + m + 1) % self.g
        return (grp - m - 1) % self.g

    def _chan(self, g_from, g_to, j):
        """Channel index in g_from of round-j's global link toward g_to."""
        if self.arrangement == "consecutive":
            m = (g_to - g_from - 1) % self.g
        else:
            m = (g_from - g_to - 1) % self.g
        return j * (self.g - 1) + m

    def _local(self, grp, r1, r2):
        return (grp * self.a + r1) * self.a + r2

    def _global(self, grp, c):
        return self._glob_off + grp * (self.a * self.h) + c

    def link_endpoints(self):
        sr = np.full(self.n_links, -1, dtype=np.int64)
        dr = np.full(self.n_links, -1, dtype=np.int64)
        a, h, g = self.a, self.h, self.g
        # local
        ids = np.arange(self._glob_off)
        grp, rem = divmod(ids, a * a)
        r1, r2 = divmod(rem, a)
        ok = r1 != r2
        sr[:self._glob_off][ok] = (grp * a + r1)[ok]
        dr[:self._glob_off][ok] = (grp * a + r2)[ok]
        # global
        ids = np.arange(self._nic_off - self._glob_off)
        grp, c = divmod(ids, a * h)
        j, m = divmod(c, g - 1)
        used = c < self._used_channels()
        peer = self._peer_group(grp, m)
        rev = j * (g - 1) + (g - 2 - m)
        gsl = slice(self._glob_off, self._nic_off)
        sr[gsl][used] = (grp * a + c // h)[used]
        dr[gsl][used] = (peer * a + rev // h)[used]
        # nic: node side has no router
        dr[self._nic_off:] = self.router_of_node(np.arange(self.n_nodes))
        return sr, dr

    def expected_router_degree(self) -> np.ndarray:
        l = np.arange(self.a)
        used = np.clip(self._used_channels() - l * self.h, 0, self.h)
        return np.tile((self.a - 1) + used, self.g)

    # --------------------------------------------------------------- routing
    def _decode(self, node):
        r = np.asarray(node, dtype=np.int64) // self.p
        return r // self.a, r % self.a          # (group, router-in-group)

    def _minimal_vec(self, src, dst, j):
        n = src.shape[0]
        g1, l1 = self._decode(src)
        g2, l2 = self._decode(dst)
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = g1 == g2
        m = intra & (l1 != l2)
        out[m, 0] = self._local(g1, l1, l2)[m]
        inter = ~intra
        c1 = self._chan(g1, g2, j)
        c2 = self._chan(g2, g1, j)
        gw1, gw2 = c1 // self.h, c2 // self.h
        m = inter & (l1 != gw1)
        out[m, 0] = self._local(g1, l1, gw1)[m]
        out[inter, 1] = self._global(g1, c1)[inter]
        m = inter & (gw2 != l2)
        out[m, 2] = self._local(g2, gw2, l2)[m]
        return out

    def _pick_transit(self, gi, g1, g2):
        """Collision-adjusted intermediate group (Aries-style double bump)."""
        gim = gi % self.g
        for _ in range(2):
            gim = np.where((gim == g1) | (gim == g2), (gim + 1) % self.g, gim)
        return gim

    def _nonmin_vec(self, src, dst, gi, j1, j2):
        n = src.shape[0]
        g1, l1 = self._decode(src)
        g2, l2 = self._decode(dst)
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = g1 == g2
        # intra-group: detour via a hashed intermediate router
        ri = (gi * 40503 + 7) % self.a
        m = intra & (l1 != ri)
        out[m, 0] = self._local(g1, l1, ri)[m]
        m = intra & (ri != l2)
        out[m, 1] = self._local(g1, ri, l2)[m]
        # inter-group Valiant through gim
        inter = ~intra
        gim = self._pick_transit(gi, g1, g2)
        c_a = self._chan(g1, gim, j1)
        ea = self._chan(gim, g1, j1) // self.h     # entry router at gim
        c_b = self._chan(gim, g2, j2)
        eb = self._chan(g2, gim, j2) // self.h     # entry router at g2
        gwa, xb = c_a // self.h, c_b // self.h
        m = inter & (l1 != gwa)
        out[m, 0] = self._local(g1, l1, gwa)[m]
        out[inter, 1] = self._global(g1, c_a)[inter]
        m = inter & (ea != xb)
        out[m, 2] = self._local(gim, ea, xb)[m]
        out[inter, 3] = self._global(gim, c_b)[inter]
        m = inter & (eb != l2)
        out[m, 4] = self._local(g2, eb, l2)[m]
        return out

    def candidate_paths(self, src, dst, rng, n_min: int = 2,
                        n_nonmin: int = 2):
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n = src.shape[0]
        R = self.rounds
        k0 = rng.integers(0, R, size=n)
        gis = rng.integers(0, self.g, size=(n_nonmin, n))
        knm = rng.integers(0, R, size=(2 * n_nonmin, n))
        cands = [self._minimal_vec(src, dst, (k0 + j) % R)
                 for j in range(n_min)]
        cands += [self._nonmin_vec(src, dst, gis[j], knm[2 * j],
                                   knm[2 * j + 1])
                  for j in range(n_nonmin)]
        links = np.stack(cands, axis=1)
        links[src == dst] = PAD
        is_nonmin = np.array([False] * n_min + [True] * n_nonmin)
        return links, is_nonmin


# ============================================================== Dragonfly+
@dataclass(frozen=True)
class DragonflyPlusParams:
    """Two-tier groups: a_leaf leaf routers (p nodes each) bipartitely
    wired to a_spine spine routers; the spines own the h-per-router
    global ports.  g=0 means the balanced rule g = a_spine*h + 1."""

    p: int = 2
    a_leaf: int = 2
    a_spine: int = 2
    h: int = 2
    g: int = 0
    arrangement: str = "palmtree"
    local_gbs: float = 5.25
    global_gbs: float = 4.7
    nic_gbs: float = 10.0
    hop_latency_ns: float = 100.0
    nic_latency_ns: float = 600.0


class DragonflyPlusFamily(Topology):
    """Dragonfly+ (leaf/spine groups per 2406.15097).

    Link-id layout (directed):
      local   [0, g*a_leaf*a_spine*2)   ((grp*a_leaf + l)*a_spine + s)*2
                                        + dir  (0 = up leaf->spine)
      global  [+, + g*a_spine*h)        grp*(a_spine*h) + c
      nic     [+, + n_nodes)

    Router ids: group grp owns [grp*R, (grp+1)*R) with R = a_leaf +
    a_spine; leaves first, spines after.
    """

    name = "dragonfly_plus"
    max_minimal_hops = 3     # up, global, down
    max_nonmin_hops = 6      # up, global, down, up, global, down

    def __init__(self, params: DragonflyPlusParams):
        p, al, asp, h = params.p, params.a_leaf, params.a_spine, params.h
        g = params.g or balanced_global_count(asp, h)
        _require(p >= 1 and al >= 1 and asp >= 1 and h >= 1,
                 f"dragonfly+ wants p,a_leaf,a_spine,h >= 1, "
                 f"got {p},{al},{asp},{h}")
        _require(g >= 3, f"dragonfly+ wants g >= 3 groups, got {g}")
        _require(params.arrangement in _ARRANGEMENTS,
                 f"arrangement must be one of {_ARRANGEMENTS}, "
                 f"got {params.arrangement!r}")
        _require(asp * h >= g - 1,
                 f"g={g} groups need a_spine*h >= g-1, got {asp * h}")
        self.params = params
        self.p, self.a_leaf, self.a_spine, self.h = p, al, asp, h
        self.g = g
        self.arrangement = params.arrangement
        self.rounds = (asp * h) // (g - 1)
        self._R = al + asp                       # routers per group
        self.n_groups = g
        self.n_routers = g * self._R
        self.n_nodes = g * al * p
        self.nodes_per_router = p
        self.nodes_per_group = al * p
        self.n_node_routers = g * al
        self.hop_latency_ns = params.hop_latency_ns
        self.nic_latency_ns = params.nic_latency_ns
        self._glob_off = g * al * asp * 2
        self._nic_off = self._glob_off + g * asp * h
        self.n_links = self._nic_off + self.n_nodes
        cap = np.empty(self.n_links, dtype=np.float64)
        cap[:self._glob_off] = params.local_gbs
        cap[self._glob_off:self._nic_off] = params.global_gbs
        cap[self._nic_off:] = params.nic_gbs
        self.capacity_gbs = cap

    # ------------------------------------------------------------- structure
    def spec_str(self) -> str:
        return (f"dragonfly_plus(p={self.p},a_leaf={self.a_leaf},"
                f"a_spine={self.a_spine},h={self.h},g={self.g},"
                f"arrangement={self.arrangement})")

    def link_ranges(self) -> dict:
        return {"local": (0, self._glob_off),
                "global": (self._glob_off, self._nic_off),
                "nic": (self._nic_off, self.n_links)}

    def router_of_node(self, node):
        nrf = np.asarray(node) // self.p         # flat leaf index
        return (nrf // self.a_leaf) * self._R + nrf % self.a_leaf

    def group_of_router(self, router):
        return np.asarray(router) // self._R

    def nic_link(self, node):
        return self._nic_off + np.asarray(node)

    def _used_channels(self) -> int:
        return self.rounds * (self.g - 1)

    def _peer_group(self, grp, m):
        if self.arrangement == "consecutive":
            return (grp + m + 1) % self.g
        return (grp - m - 1) % self.g

    def _chan(self, g_from, g_to, j):
        if self.arrangement == "consecutive":
            m = (g_to - g_from - 1) % self.g
        else:
            m = (g_from - g_to - 1) % self.g
        return j * (self.g - 1) + m

    def _up(self, grp, l, s):
        return ((grp * self.a_leaf + l) * self.a_spine + s) * 2

    def _down(self, grp, s, l):
        return ((grp * self.a_leaf + l) * self.a_spine + s) * 2 + 1

    def _global(self, grp, c):
        return self._glob_off + grp * (self.a_spine * self.h) + c

    def link_endpoints(self):
        sr = np.full(self.n_links, -1, dtype=np.int64)
        dr = np.full(self.n_links, -1, dtype=np.int64)
        al, asp, h, g, R = (self.a_leaf, self.a_spine, self.h, self.g,
                           self._R)
        # local (every slot physical)
        ids = np.arange(self._glob_off)
        half, dirn = divmod(ids, 2)
        s = half % asp
        l = (half // asp) % al
        grp = half // (asp * al)
        leaf = grp * R + l
        spine = grp * R + al + s
        sr[:self._glob_off] = np.where(dirn == 0, leaf, spine)
        dr[:self._glob_off] = np.where(dirn == 0, spine, leaf)
        # global
        ids = np.arange(self._nic_off - self._glob_off)
        grp, c = divmod(ids, asp * h)
        j, m = divmod(c, g - 1)
        used = c < self._used_channels()
        peer = self._peer_group(grp, m)
        rev = j * (g - 1) + (g - 2 - m)
        gsl = slice(self._glob_off, self._nic_off)
        sr[gsl][used] = (grp * R + al + c // h)[used]
        dr[gsl][used] = (peer * R + al + rev // h)[used]
        # nic
        dr[self._nic_off:] = self.router_of_node(np.arange(self.n_nodes))
        return sr, dr

    def expected_router_degree(self) -> np.ndarray:
        si = np.arange(self.a_spine)
        used = np.clip(self._used_channels() - si * self.h, 0, self.h)
        per_group = np.concatenate([
            np.full(self.a_leaf, self.a_spine, dtype=np.int64),
            self.a_leaf + used])
        return np.tile(per_group, self.g)

    # --------------------------------------------------------------- routing
    def _decode(self, node):
        nrf = np.asarray(node, dtype=np.int64) // self.p
        return nrf // self.a_leaf, nrf % self.a_leaf   # (group, leaf idx)

    def _minimal_vec(self, src, dst, j, sk):
        n = src.shape[0]
        g1, l1 = self._decode(src)
        g2, l2 = self._decode(dst)
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        intra = (g1 == g2) & (l1 != l2)
        s = sk % self.a_spine
        out[intra, 0] = self._up(g1, l1, s)[intra]
        out[intra, 1] = self._down(g1, s, l2)[intra]
        inter = g1 != g2
        c1 = self._chan(g1, g2, j)
        c2 = self._chan(g2, g1, j)
        out[inter, 0] = self._up(g1, l1, c1 // self.h)[inter]
        out[inter, 1] = self._global(g1, c1)[inter]
        out[inter, 2] = self._down(g2, c2 // self.h, l2)[inter]
        return out

    def _pick_transit(self, gi, g1, g2):
        gim = gi % self.g
        for _ in range(2):
            gim = np.where((gim == g1) | (gim == g2), (gim + 1) % self.g, gim)
        return gim

    def _nonmin_vec(self, src, dst, gi, j1, j2):
        n = src.shape[0]
        g1, l1 = self._decode(src)
        g2, l2 = self._decode(dst)
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        lt = (gi * 40503 + 7) % self.a_leaf       # transit leaf
        # intra-group: down to a transit leaf, back up, down to dst
        intra = (g1 == g2) & (src != dst)
        sa = j1 % self.a_spine
        sb = j2 % self.a_spine
        out[intra, 0] = self._up(g1, l1, sa)[intra]
        out[intra, 1] = self._down(g1, sa, lt)[intra]
        out[intra, 2] = self._up(g1, lt, sb)[intra]
        out[intra, 3] = self._down(g1, sb, l2)[intra]
        # inter-group Valiant through gim's transit leaf
        inter = g1 != g2
        gim = self._pick_transit(gi, g1, g2)
        jr1, jr2 = j1 % self.rounds, j2 % self.rounds
        c_a = self._chan(g1, gim, jr1)
        s_in = self._chan(gim, g1, jr1) // self.h
        c_b = self._chan(gim, g2, jr2)
        s2 = self._chan(g2, gim, jr2) // self.h
        out[inter, 0] = self._up(g1, l1, c_a // self.h)[inter]
        out[inter, 1] = self._global(g1, c_a)[inter]
        out[inter, 2] = self._down(gim, s_in, lt)[inter]
        out[inter, 3] = self._up(gim, lt, c_b // self.h)[inter]
        out[inter, 4] = self._global(gim, c_b)[inter]
        out[inter, 5] = self._down(g2, s2, l2)[inter]
        return out

    def candidate_paths(self, src, dst, rng, n_min: int = 2,
                        n_nonmin: int = 2):
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n = src.shape[0]
        R = self.rounds
        k0 = rng.integers(0, R, size=n)
        sks = rng.integers(0, self.a_spine, size=(n_min, n))
        gis = rng.integers(0, self.g, size=(n_nonmin, n))
        knm = rng.integers(0, max(R, self.a_spine), size=(2 * n_nonmin, n))
        cands = [self._minimal_vec(src, dst, (k0 + j) % R, sks[j])
                 for j in range(n_min)]
        cands += [self._nonmin_vec(src, dst, gis[j], knm[2 * j],
                                   knm[2 * j + 1])
                  for j in range(n_nonmin)]
        links = np.stack(cands, axis=1)
        links[src == dst] = PAD
        is_nonmin = np.array([False] * n_min + [True] * n_nonmin)
        return links, is_nonmin


# ============================================================ fat-tree control
@dataclass(frozen=True)
class FatTreeParams:
    """Degenerate 2-level fat tree: n_leaf leaf routers (p nodes each)
    fully wired to n_spine spines.  No groups, no global tier — the
    control arm for 'does group locality matter at all'."""

    p: int = 2
    n_leaf: int = 4
    n_spine: int = 2
    local_gbs: float = 5.25
    nic_gbs: float = 10.0
    hop_latency_ns: float = 100.0
    nic_latency_ns: float = 600.0


class FatTreeControl(Topology):
    """2-level fat tree; every leaf is its own 'group' of p nodes.

    Link-id layout (directed):
      up    [0, n_leaf*n_spine)     l*n_spine + s
      down  [+, + n_spine*n_leaf)   s*n_leaf + l
      nic   [+, + n_nodes)
    """

    name = "fattree"
    max_minimal_hops = 2
    max_nonmin_hops = 2
    valiant_transits_group = False   # no intermediate groups exist

    def __init__(self, params: FatTreeParams):
        p, nl, ns = params.p, params.n_leaf, params.n_spine
        _require(p >= 1 and nl >= 2 and ns >= 1,
                 f"fattree wants p>=1, n_leaf>=2, n_spine>=1, "
                 f"got {p},{nl},{ns}")
        self.params = params
        self.p, self.n_leaf, self.n_spine = p, nl, ns
        self.n_groups = nl
        self.n_routers = nl + ns
        self.n_nodes = nl * p
        self.nodes_per_router = p
        self.nodes_per_group = p
        self.n_node_routers = nl
        self.hop_latency_ns = params.hop_latency_ns
        self.nic_latency_ns = params.nic_latency_ns
        self._down_off = nl * ns
        self._nic_off = 2 * nl * ns
        self.n_links = self._nic_off + self.n_nodes
        cap = np.empty(self.n_links, dtype=np.float64)
        cap[:self._nic_off] = params.local_gbs
        cap[self._nic_off:] = params.nic_gbs
        self.capacity_gbs = cap

    # ------------------------------------------------------------- structure
    def spec_str(self) -> str:
        return (f"fattree(p={self.p},n_leaf={self.n_leaf},"
                f"n_spine={self.n_spine})")

    def link_ranges(self) -> dict:
        return {"up": (0, self._down_off),
                "down": (self._down_off, self._nic_off),
                "nic": (self._nic_off, self.n_links)}

    def router_of_node(self, node):
        return np.asarray(node) // self.p

    def group_of_router(self, router):
        # leaves are their own group; spines belong to none
        r = np.asarray(router)
        return np.where(r < self.n_leaf, r, -1)

    def nic_link(self, node):
        return self._nic_off + np.asarray(node)

    def _up(self, l, s):
        return l * self.n_spine + s

    def _down(self, s, l):
        return self._down_off + s * self.n_leaf + l

    def link_endpoints(self):
        sr = np.full(self.n_links, -1, dtype=np.int64)
        dr = np.full(self.n_links, -1, dtype=np.int64)
        nl, ns = self.n_leaf, self.n_spine
        ids = np.arange(nl * ns)
        l, s = divmod(ids, ns)
        sr[:self._down_off] = l
        dr[:self._down_off] = nl + s
        s, l = divmod(ids, nl)
        sr[self._down_off:self._nic_off] = nl + s
        dr[self._down_off:self._nic_off] = l
        dr[self._nic_off:] = self.router_of_node(np.arange(self.n_nodes))
        return sr, dr

    def expected_router_degree(self) -> np.ndarray:
        return np.concatenate([
            np.full(self.n_leaf, self.n_spine, dtype=np.int64),
            np.full(self.n_spine, self.n_leaf, dtype=np.int64)])

    # --------------------------------------------------------------- routing
    def _via_spine(self, src, dst, s):
        n = src.shape[0]
        l1 = src // self.p
        l2 = dst // self.p
        out = np.full((n, self.MAX_HOPS), PAD, dtype=np.int64)
        inter = l1 != l2
        out[inter, 0] = self._up(l1, s)[inter]
        out[inter, 1] = self._down(s, l2)[inter]
        return out

    def candidate_paths(self, src, dst, rng, n_min: int = 2,
                        n_nonmin: int = 2):
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n = src.shape[0]
        ns = self.n_spine
        s0 = rng.integers(0, ns, size=n)
        snm = rng.integers(0, ns, size=(n_nonmin, n))
        cands = [self._via_spine(src, dst, (s0 + j) % ns)
                 for j in range(n_min)]
        # the 'Valiant' arm is just an independent spine draw
        cands += [self._via_spine(src, dst, snm[j]) for j in range(n_nonmin)]
        links = np.stack(cands, axis=1)
        links[src == dst] = PAD
        is_nonmin = np.array([False] * n_min + [True] * n_nonmin)
        return links, is_nonmin


# --------------------------------------------------------------- registration
register_topology(
    "dragonfly",
    lambda **kw: DragonflyFamily(DragonflyParams(**kw)),
    small=dict(p=2, a=4, h=2, g=9, arrangement="palmtree"),
)
register_topology(
    "dragonfly_consecutive",
    lambda **kw: DragonflyFamily(
        DragonflyParams(**{"arrangement": "consecutive", **kw})),
    small=dict(p=2, a=4, h=2, g=9),
)
register_topology(
    "dragonfly_plus",
    lambda **kw: DragonflyPlusFamily(DragonflyPlusParams(**kw)),
    small=dict(p=2, a_leaf=2, a_spine=2, h=2, g=5),
)
register_topology(
    "fattree",
    lambda **kw: FatTreeControl(FatTreeParams(**kw)),
    small=dict(p=2, n_leaf=4, n_spine=2),
)
