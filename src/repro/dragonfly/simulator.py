"""Flow-level ("fluid") congestion simulator for the Aries Dragonfly.

Design (DESIGN.md §8): a per-phase fixed-point congestion model rather than
a cycle-accurate flit simulator (which the paper itself avoids, §7: "
simulating the exact tiled structure of Dragonfly would be too costly").

One *phase* = a set of concurrent flows (e.g. one alltoall round, one
ping-pong direction).  For each phase the simulator:
  1. draws 2 minimal + 2 non-minimal candidate paths per flow (§2.2),
  2. scores candidates with *stale, noisy* queue estimates (phantom
     congestion, Won et al. [46]) plus the routing mode's minimal bias,
  3. spreads each flow's bytes over candidates via softmin (fluid packet
     spraying),
  4. solves a small fixed point: byte loads -> link utilization -> phase
     duration -> utilization,
  5. derives per-flow NIC observables — latency L (hop + queuing delays)
     and stall ratio s (bottleneck-utilization excess) — and plugs them
     into the paper's Eq. (2) for the message time,
  6. updates persistent link queues and the allocation's NIC counters.

Background ("other job") traffic with Pareto-sized flows shares the links,
producing the heavy outlier tails of Fig. 3.  All randomness is seeded.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.counters import NICCounters
from repro.core.perf_model import MAX_OUTSTANDING_PACKETS
from repro.core.strategies import RoutingMode
from repro.dragonfly.routing import RoutingPolicy, score_candidates, spray_weights
from repro.dragonfly.topology import PAD, Allocation, DragonflyTopology


@dataclass(frozen=True)
class SimParams:
    seed: int = 0
    #: minimal candidates = 4 (fluid union of Aries' per-packet 2-min draws
    #: over the K global links); non-minimal = 2 as per §2.2
    n_min_candidates: int = 4
    n_nonmin_candidates: int = 2
    #: statistical cap: phases with more flows are subsampled (bytes scaled)
    max_flows: int = 120_000
    #: fraction of a round's residual queue that persists to the next phase
    queue_carryover: float = 0.35
    #: phantom congestion (Won et al. [46]): credit-based estimates are
    #: STALE — the router sees a mix of the current queue and an EMA memory
    #: of past queues (drained hotspots look congested, fresh ones are
    #: missed), times a lognormal factor, plus exponential "ghosts".
    phantom_sigma: float = 0.45
    phantom_ghost_s: float = 25e-6
    est_staleness: float = 0.6         # weight of the stale memory
    est_memory_decay: float = 0.5       # EMA decay of the stale memory
    #: a packet waits behind only part of a queue (spraying interleaves it):
    qwait_fraction: float = 0.6
    #: stalls: a flow whose bottleneck link is offered `o` times its capacity
    #: during the serialization window stalls s = stall_gain*max(0, o - thr)
    #: cycles per flit (o>1 == credit backpressure; thr<1 == near-saturation
    #: queueing effects).
    stall_gain: float = 1.2
    rho_threshold: float = 0.85
    #: queuing delay added per hop per unit utilization excess (ns)
    queue_delay_ns: float = 900.0
    #: utilization is measured over at least this window: short messages do
    #: not self-congest (credit buffers absorb them), sustained flows do.
    min_phase_window_s: float = 50e-6
    #: NIC flit serialization: one 64B-packet = 5 flits = 5 cycles @1GHz
    flit_ns_per_byte: float = 5.0 / 64.0
    #: within-phase adaptive feedback: packets later in the message react to
    #: queues built by earlier packets (real-time local queue sensing on
    #: Aries).  Scores get + max(0, rho - feedback_rho0)*window per link and
    #: spray weights re-equilibrate this many times.
    route_feedback_iters: int = 4
    feedback_rho0: float = 0.9
    #: background traffic (other jobs): Pareto-sized flows concentrated on
    #: a slowly-rotating set of "hot" groups -> heavy outlier tails (Fig. 3)
    bg_flows_per_phase: int = 16
    bg_pareto_alpha: float = 1.1
    bg_bytes_scale: float = 2.5e6
    bg_hot_groups: int = 3
    bg_hot_prob: float = 0.65
    bg_rotate_phases: int = 50
    bg_enable: bool = True
    #: host-side constant per phase (not network noise! §3.3) — us
    host_overhead_us: float = 1.5
    host_noise_sigma: float = 0.25     # lognormal sigma of host-side jitter
    nic_clock_ghz: float = 1.0


@dataclass
class FlowResult:
    """Per-flow observables for one phase."""

    t_us: np.ndarray            # Eq.(2) message time
    latency_us: np.ndarray      # L
    stalls_per_flit: np.ndarray  # s
    flits: np.ndarray
    packets: np.ndarray
    nonmin_fraction: float      # byte fraction routed non-minimally

    @property
    def phase_time_us(self) -> float:
        return float(self.t_us.max()) if self.t_us.size else 0.0


class DragonflySimulator:
    def __init__(self, topo: DragonflyTopology,
                 params: SimParams = SimParams()):
        self.topo = topo
        self.params = params
        self.rng = np.random.default_rng(params.seed)
        self.link_queue_s = np.zeros(topo.n_links)  # seconds-to-drain units
        self.est_memory_s = np.zeros(topo.n_links)  # stale estimate memory
        self.counters: dict[str, NICCounters] = {}
        self.clock_s: float = 0.0
        self.total_flits_all_jobs: float = 0.0
        self._phase_count = 0
        self._hot_groups = self.rng.choice(
            topo.params.n_groups,
            size=min(params.bg_hot_groups, topo.params.n_groups),
            replace=False)

    # --------------------------------------------------------- counter API
    def backend_for(self, allocation_id: str):
        """CounterBackend view for one allocation's NICs."""
        sim = self

        class _Backend:
            def read_counters(_s) -> NICCounters:
                return sim.counters.setdefault(allocation_id, NICCounters())

            def now_s(_s) -> float:
                return sim.clock_s

        return _Backend()

    # ------------------------------------------------------------- internals
    def _bg_flows(self, allocation: Allocation | None = None):
        p = self.params
        n = p.bg_flows_per_phase
        if not p.bg_enable or n == 0:
            return None
        tp = self.topo.params
        self._phase_count += 1
        if self._phase_count % max(1, p.bg_rotate_phases) == 0:
            self._hot_groups = self.rng.choice(
                tp.n_groups, size=min(p.bg_hot_groups, tp.n_groups),
                replace=False)
        nodes_per_group = tp.routers_per_group * tp.nodes_per_blade
        ours = np.asarray(allocation.nodes) if allocation is not None \
            else np.empty(0, dtype=np.int64)

        def draw(size):
            hot = self.rng.random(size) < p.bg_hot_prob
            grp = np.where(
                hot,
                self.rng.choice(self._hot_groups, size=size),
                self.rng.integers(0, tp.n_groups, size=size))
            off = self.rng.integers(0, nodes_per_group, size=size)
            out = grp * nodes_per_group + off
            # batch systems do not share nodes between jobs: other-job flows
            # never originate/terminate on the allocation's nodes
            for _ in range(3):
                bad = np.isin(out, ours)
                if not bad.any():
                    break
                out[bad] = self.rng.integers(0, tp.n_nodes, size=bad.sum())
            return out

        src = draw(n)
        dst = draw(n)
        dst = np.where(dst == src, (dst + 1) % tp.n_nodes, dst)
        size = (self.rng.pareto(p.bg_pareto_alpha, size=n) + 1.0) \
            * p.bg_bytes_scale
        return src, dst, size

    @staticmethod
    def _flits_packets(bytes_: np.ndarray):
        packets = np.maximum(1, np.ceil(bytes_ / 64.0))
        flits = packets * 5.0  # PUT: 1 header + 4 payload flits
        return flits, packets

    # ------------------------------------------------------------- run_phase
    def run_phase(self, src_nodes, dst_nodes, bytes_, policy: RoutingPolicy,
                  allocation: Allocation | None = None,
                  modes: np.ndarray | None = None) -> FlowResult:
        """Simulate one phase of concurrent flows routed with `policy`.

        `modes` (optional, [n_app] object array of RoutingModes) is the
        PolicyEngine path: per-flow modes from one vectorized
        engine.decide() call bias each flow individually; `policy` then
        only supplies the calibration constants (bias_unit_s etc.)."""
        p = self.params
        topo = self.topo
        src = np.asarray(src_nodes, dtype=np.int64)
        dst = np.asarray(dst_nodes, dtype=np.int64)
        size = np.asarray(bytes_, dtype=np.float64)
        n_app = src.shape[0]
        if modes is not None and np.shape(modes)[0] != n_app:
            raise ValueError("modes must have one entry per app flow")
        if n_app == 0 and not (p.bg_enable and p.bg_flows_per_phase):
            return FlowResult(*(np.zeros(0),) * 5, 0.0)

        # statistical subsample of very large phases (load-preserving)
        if n_app > p.max_flows:
            idx = self.rng.choice(n_app, size=p.max_flows, replace=False)
            scale = n_app / p.max_flows
            src, dst, size = src[idx], dst[idx], size[idx] * scale
            if modes is not None:
                modes = modes[idx]
            n_app = p.max_flows

        bg = self._bg_flows(allocation)
        if bg is not None:
            src_all = np.concatenate([src, bg[0]])
            dst_all = np.concatenate([dst, bg[1]])
            size_all = np.concatenate([size, bg[2]])
        else:
            src_all, dst_all, size_all = src, dst, size
        n_all = src_all.shape[0]

        links, is_nonmin = topo.candidate_paths(
            src_all, dst_all, self.rng,
            n_min=p.n_min_candidates, n_nonmin=p.n_nonmin_candidates)
        valid = links != PAD
        safe = np.where(valid, links, 0)

        # --- stale & noisy congestion estimate (phantom congestion) --------
        noise = self.rng.lognormal(0.0, p.phantom_sigma, size=topo.n_links)
        ghosts = self.rng.exponential(p.phantom_ghost_s, size=topo.n_links)
        a = p.est_staleness
        est_queue_s = ((1.0 - a) * self.link_queue_s
                       + a * self.est_memory_s) * noise + ghosts

        # --- contention window: the APP phase's clean serialization time ---
        # (stall-free flit serialization of the largest app message; floored
        # so transient small messages do not self-congest)
        ser_s_app = float(size[:n_app].max() * p.flit_ns_per_byte) * 1e-9 \
            if n_app else 0.0
        window_s = max(ser_s_app, p.min_phase_window_s)
        cap_bps = topo.capacity_gbs * 1e9
        nic_ids = topo.nic_link(src_all)
        inj_cap = topo.capacity_gbs[nic_ids] * 1e9 * window_s
        size_inst = np.minimum(size_all, inj_cap)
        packets_all = np.maximum(1, np.ceil(size_all / 64.0))
        bg_policy = RoutingPolicy(RoutingMode.ADAPTIVE_0)

        def weights_for(extra_queue_s):
            est = est_queue_s + extra_queue_s
            sc_app = score_candidates(links[:n_app], est, is_nonmin, policy,
                                      modes=modes)
            wa = spray_weights(sc_app, policy, self.rng,
                               packets=packets_all[:n_app])
            if n_all > n_app:
                sc_bg = score_candidates(links[n_app:], est, is_nonmin,
                                         bg_policy)
                wb = spray_weights(sc_bg, bg_policy, self.rng,
                                   packets=packets_all[n_app:])
                return np.concatenate([wa, wb], axis=0)
            return wa

        def loads_for(w):
            # load_i: bytes offered DURING the window (a flow cannot inject
            # more than its NIC moves in the window) -> instant contention
            fb = size_inst[:, None, None] * w[:, :, None] * valid
            li = np.zeros(topo.n_links)
            np.add.at(li, safe.ravel(), fb.ravel())
            np.add.at(li, nic_ids, size_inst)
            return li

        # within-phase adaptive feedback: later packets see queues built by
        # earlier ones and re-equilibrate (per-packet real-time sensing).
        # Damped (w <- (w + w_target)/2) to avoid synchronous flip-flopping.
        w = weights_for(np.zeros(topo.n_links))
        load_i = loads_for(w)
        for _ in range(max(0, p.route_feedback_iters - 1)):
            rho_fb = load_i / (cap_bps * window_s)
            extra = np.maximum(0.0, rho_fb - p.feedback_rho0) * window_s
            w = 0.5 * (w + weights_for(extra))
            load_i = loads_for(w)
        w_app = w[:n_app]

        # load_q: full backlog bytes (feeds persistent queues / Fig.3 tails)
        flow_bytes_q = size_all[:, None, None] * w[:, :, None] * valid
        load_q = np.zeros(topo.n_links)
        np.add.at(load_q, safe.ravel(), flow_bytes_q.ravel())

        rho = load_i / (cap_bps * window_s)
        lat_us, s_flit = self._observables(valid, safe, rho, w, nic_ids)
        flits, packets = self._flits_packets(size_all)
        win = (packets + MAX_OUTSTANDING_PACKETS // 2) / MAX_OUTSTANDING_PACKETS
        lat_cycles = lat_us * 1e3 * p.nic_clock_ghz
        t_cycles = win * lat_cycles + flits * (s_flit + 1.0)
        t_us = t_cycles / (1e3 * p.nic_clock_ghz)
        duration_s = max(float(t_us[:n_app].max()) * 1e-6, 1e-7) \
            if n_app else window_s
        # "network tile" aggregate: every job's flits on the wire (what a
        # tile counter would see; §3.2's correlation trap)
        self.total_flits_all_jobs += float(flits.sum())

        # --- persistent queues (seconds-to-drain beyond this phase) --------
        excess_s = np.maximum(0.0, load_q / cap_bps
                              - max(duration_s, window_s))
        self.est_memory_s = (self.est_memory_s * p.est_memory_decay
                             + self.link_queue_s * (1 - p.est_memory_decay))
        self.link_queue_s = self.link_queue_s * p.queue_carryover + excess_s
        self.clock_s += duration_s

        # --- NIC counters for the allocation (§2.3) ------------------------
        app_flits, app_packets = flits[:n_app], packets[:n_app]
        app_lat, app_stalls = lat_us[:n_app], s_flit[:n_app]
        if allocation is not None:
            c = self.counters.setdefault(allocation.allocation_id,
                                         NICCounters())
            c.observe(
                flits=int(app_flits.sum()),
                stalled_cycles=int((app_flits * app_stalls).sum()),
                packets=int(app_packets.sum()),
                latency_us_total=float((app_lat * app_packets).sum()),
            )

        nonmin_bytes = float(
            (size_all[:n_app, None] * w_app * is_nonmin[None, :]).sum())
        return FlowResult(
            t_us=t_us[:n_app],
            latency_us=app_lat,
            stalls_per_flit=app_stalls,
            flits=app_flits,
            packets=app_packets,
            nonmin_fraction=nonmin_bytes / max(float(size[:n_app].sum()), 1e-9),
        )

    def _observables(self, valid, safe, rho, w, nic_ids):
        """Per-flow (L_us, s) from per-link utilization."""
        p = self.params
        tp = self.topo.params
        rho_path = rho[safe] * valid                    # [n, ncand, hops]
        hops = valid.sum(axis=-1)                       # [n, ncand]
        excess = np.maximum(0.0, rho_path - p.rho_threshold)
        qdelay_ns = p.queue_delay_ns * excess.sum(axis=-1)   # [n, ncand]
        # waiting behind queues persisting from earlier traffic: a packet
        # entering a link with q seconds-to-drain of backlog waits ~q
        # (discounted: spraying interleaves it into the backlog).  This is
        # THE outlier mechanism of Fig. 3 — and what adaptive routing dodges
        # when its congestion estimate is fresh.
        qwait_ns = (self.link_queue_s[safe] * valid).sum(axis=-1) \
            * p.qwait_fraction * 1e9
        lat_ns_cand = 2.0 * tp.nic_latency_ns + hops * tp.hop_latency_ns \
            + qdelay_ns + qwait_ns
        lat_us = (lat_ns_cand * w).sum(axis=-1) / 1e3   # weighted over cands
        # stall ratio from the bottleneck link of each candidate path,
        # including the NIC injection link
        rho_nic = rho[nic_ids]                          # [n]
        rho_bneck = np.maximum(rho_path.max(axis=-1),
                               rho_nic[:, None])        # [n, ncand]
        s_cand = p.stall_gain * np.maximum(0.0, rho_bneck - p.rho_threshold)
        s_flit = (s_cand * w).sum(axis=-1)
        return lat_us, s_flit

    # ----------------------------------------------------------------- misc
    def reset_queues(self) -> None:
        self.link_queue_s[:] = 0.0
