"""Flow-level ("fluid") congestion simulator for the Aries Dragonfly.

Design (DESIGN.md §8): a per-phase fixed-point congestion model rather than
a cycle-accurate flit simulator (which the paper itself avoids, §7: "
simulating the exact tiled structure of Dragonfly would be too costly").

One *phase* = a set of concurrent flows (e.g. one alltoall round, one
ping-pong direction).  For each phase the simulator:
  1. draws 2 minimal + 2 non-minimal candidate paths per flow (§2.2),
  2. scores candidates with *stale, noisy* queue estimates (phantom
     congestion, Won et al. [46]) plus the routing mode's minimal bias,
  3. spreads each flow's bytes over candidates via softmin (fluid packet
     spraying),
  4. solves a small fixed point: byte loads -> link utilization -> phase
     duration -> utilization,
  5. derives per-flow NIC observables — latency L (hop + queuing delays)
     and stall ratio s (bottleneck-utilization excess) — and plugs them
     into the paper's Eq. (2) for the message time,
  6. updates persistent link queues and the allocation's NIC counters.

Background ("other job") traffic with Pareto-sized flows shares the links,
producing the heavy outlier tails of Fig. 3.  All randomness is seeded.

Fast path (PR 3, docs/performance.md): the hot loop is vectorized —

  * link loads are np.bincount segment-sums over pre-flattened valid
    (link, byte) pairs instead of buffered ``np.add.at`` scatter-adds;
  * the loop-invariant score base (queue gather + hop latency + per-flow
    bias via an int mode-code table) is hoisted out of the
    ``route_feedback_iters`` fixed point — each iteration only adds the
    feedback ``extra`` term and re-sprays;
  * app + background flows spray in ONE fused softmin call per iteration
    (per-row temperatures), with the whole phase's Gumbel noise drawn
    up-front from the same RNG stream;
  * repeated traffic patterns can reuse a :class:`PhasePlan` (candidate
    tensor, validity masks, NIC ids, packet counts) via
    ``sim.plan_for(...)`` / ``run_phase(..., plan=...)``;
  * ``SimParams.backend = "jax"`` routes the score->spray->fixed-point->
    observables pipeline through one jitted JAX function (with a Pallas
    segment-sum kernel on TPU), falling back to NumPy when unavailable.

Seed-for-seed the NumPy fast path replays the pre-refactor simulator
(`repro.dragonfly.reference`): bit-identical with
``route_feedback_iters=1`` and within ~1e-9 relative otherwise (the
hoisted ``extra`` term reassociates one float64 sum per iteration).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

from repro.core.counters import NICCounters
from repro.core.perf_model import MAX_OUTSTANDING_PACKETS
from repro.core.strategies import RoutingMode
from repro.dragonfly.routing import (RoutingPolicy, apply_bias,
                                     apply_notifications, row_bias_terms,
                                     softmin_weights)
from repro.dragonfly.topology import (PAD, Allocation, DragonflyTopology,
                                      Topology, make_topology)

#: simulator compute backends (SimParams.backend)
BACKENDS = ("numpy", "jax")


@dataclass(frozen=True)
class SimParams:
    seed: int = 0
    #: minimal candidates = 4 (fluid union of Aries' per-packet 2-min draws
    #: over the K global links); non-minimal = 2 as per §2.2
    n_min_candidates: int = 4
    n_nonmin_candidates: int = 2
    #: statistical cap: phases with more flows are subsampled (bytes scaled)
    max_flows: int = 120_000
    #: fraction of a round's residual queue that persists to the next phase
    queue_carryover: float = 0.35
    #: phantom congestion (Won et al. [46]): credit-based estimates are
    #: STALE — the router sees a mix of the current queue and an EMA memory
    #: of past queues (drained hotspots look congested, fresh ones are
    #: missed), times a lognormal factor, plus exponential "ghosts".
    phantom_sigma: float = 0.45
    phantom_ghost_s: float = 25e-6
    est_staleness: float = 0.6         # weight of the stale memory
    est_memory_decay: float = 0.5       # EMA decay of the stale memory
    #: a packet waits behind only part of a queue (spraying interleaves it):
    qwait_fraction: float = 0.6
    #: stalls: a flow whose bottleneck link is offered `o` times its capacity
    #: during the serialization window stalls s = stall_gain*max(0, o - thr)
    #: cycles per flit (o>1 == credit backpressure; thr<1 == near-saturation
    #: queueing effects).
    stall_gain: float = 1.2
    rho_threshold: float = 0.85
    #: queuing delay added per hop per unit utilization excess (ns)
    queue_delay_ns: float = 900.0
    #: utilization is measured over at least this window: short messages do
    #: not self-congest (credit buffers absorb them), sustained flows do.
    min_phase_window_s: float = 50e-6
    #: NIC flit serialization: one 64B-packet = 5 flits = 5 cycles @1GHz
    flit_ns_per_byte: float = 5.0 / 64.0
    #: within-phase adaptive feedback: packets later in the message react to
    #: queues built by earlier packets (real-time local queue sensing on
    #: Aries).  Scores get + max(0, rho - feedback_rho0)*window per link and
    #: spray weights re-equilibrate this many times.
    route_feedback_iters: int = 4
    feedback_rho0: float = 0.9
    #: background traffic (other jobs): Pareto-sized flows concentrated on
    #: a slowly-rotating set of "hot" groups -> heavy outlier tails (Fig. 3)
    bg_flows_per_phase: int = 16
    bg_pareto_alpha: float = 1.1
    bg_bytes_scale: float = 2.5e6
    bg_hot_groups: int = 3
    bg_hot_prob: float = 0.65
    bg_rotate_phases: int = 50
    bg_enable: bool = True
    #: host-side constant per phase (not network noise! §3.3) — us
    host_overhead_us: float = 1.5
    host_noise_sigma: float = 0.25     # lognormal sigma of host-side jitter
    nic_clock_ghz: float = 1.0
    #: compute backend for the phase kernel: "numpy" (default, seed-exact)
    #: or "jax" (device-resident jitted pipeline; falls back to numpy
    #: with a warning when jax is unusable).  docs/performance.md.
    backend: str = "numpy"
    #: Pallas segment-sum inside the jax pipeline: "auto" uses it on TPU
    #: only (interpret-mode Pallas loses badly to jax.ops.segment_sum on
    #: CPU), "on" forces it everywhere (interpret off-TPU — the parity-
    #: testing path), "off" never uses it.  repro.compat.runtime resolves
    #: the knob; ignored by the numpy backend.
    pallas_kernel: str = "auto"
    #: topology spec resolved by make_topology when the simulator is built
    #: without an explicit Topology instance: a registered name ("aries",
    #: "dragonfly", "dragonfly_plus", "fattree") optionally with kwargs,
    #: e.g. "dragonfly:p=2,a=4,h=2".  docs/topology.md.
    topology: str = "aries"
    #: reroute-or-drop penalty (us) charged to a flow whose every
    #: candidate path crosses a dead link (or whose NIC link is dead)
    #: under an active fault schedule — models the retransmit/timeout
    #: cost of losing all routes.  docs/faults.md.
    fault_penalty_us: float = 500.0
    #: congestion-notification channel (docs/policy_api.md; Rocher-
    #: Gonzalez et al. 2502.00616).  A link whose noisy queue estimate
    #: `est_queue_s` crosses notify_threshold_s raises a flag that
    #: becomes visible to source routers notify_delay_phases later
    #: (propagation delay) and clears — hysteresis — only once the
    #: estimate drops below notify_clear_frac * notify_threshold_s.
    #: Visible flags charge notify_penalty_s of predicted delay to
    #: every candidate crossing the link (routing.apply_notifications)
    #: and surface per flow in FlowResult.notified / per allocation in
    #: the NIC notification counter.  The default threshold (inf)
    #: disables the channel: no flag ever raises, no extra RNG draws or
    #: float ops happen, and the simulator is BIT-identical to the
    #: notification-free fast path (tests/test_dragonfly_fastpath.py).
    notify_threshold_s: float = float("inf")
    notify_clear_frac: float = 0.5
    notify_delay_phases: int = 1
    notify_penalty_s: float = 300e-6
    #: accumulate per-stage wall times into sim.stage_time_s (perf_sim.py)
    profile_stages: bool = False

    @property
    def notify_enabled(self) -> bool:
        """True when the notification channel can ever raise a flag."""
        return bool(np.isfinite(self.notify_threshold_s))


@dataclass
class FlowResult:
    """Per-flow observables for one phase."""

    t_us: np.ndarray            # Eq.(2) message time
    latency_us: np.ndarray      # L
    stalls_per_flit: np.ndarray  # s
    flits: np.ndarray
    packets: np.ndarray
    nonmin_fraction: float      # byte fraction routed non-minimally
    #: multi-tenant breakdown (run_phase(tenants=...) only; see
    #: repro.tenancy / docs/interference.md), else None:
    #:   tenant_of            [n_app]  tenant index of each app flow row
    #:   tenant_link_loads    [K+1, n_links] backlog bytes per tenant
    #:                        (row K = background traffic)
    #:   link_load_q          [n_links] global backlog bytes (the sum)
    #:   tenant_nonmin_fraction [K] per-tenant non-minimal byte fraction
    tenant_of: np.ndarray | None = None
    tenant_link_loads: np.ndarray | None = None
    link_load_q: np.ndarray | None = None
    tenant_nonmin_fraction: np.ndarray | None = None
    #: fault path (docs/faults.md): bool [n_app], True for app flows with
    #: zero surviving candidate paths this phase (charged the
    #: reroute-or-drop penalty); None when no fault was active
    stranded: np.ndarray | None = None
    #: notification channel (SimParams.notify_*): float [n_app] in
    #: [0, 1], the fraction of each app flow's sprayed bytes that
    #: crossed a link under a VISIBLE congestion flag this phase; None
    #: when the channel is disabled (threshold=inf, the default)
    notified: np.ndarray | None = None

    @property
    def phase_time_us(self) -> float:
        return float(self.t_us.max()) if self.t_us.size else 0.0

    @property
    def n_stranded(self) -> int:
        return int(self.stranded.sum()) if self.stranded is not None else 0

    def tenant_slice(self, k: int) -> np.ndarray:
        """Row indices of tenant `k`'s app flows (post-subsample order)."""
        if self.tenant_of is None:
            raise ValueError("not a multi-tenant result (tenants= not set)")
        return np.flatnonzero(self.tenant_of == k)


@dataclass(frozen=True)
class TenantSegments:
    """Flow-segment map of one flattened multi-tenant phase.

    The tenancy engine (repro.tenancy) concatenates K tenants' flows into
    ONE app batch; this object tells run_phase where each tenant's
    segment lives so per-allocation NIC counters and the per-tenant
    link-load breakdown can be split back out with the same bincount
    segment-sum machinery the fast path uses for links (tenant-id
    segment offsets instead of link ids).

    allocations: K Allocations, tenant order == segment order.
    offsets:     int64 [K+1]; tenant k owns app-flow rows
                 [offsets[k], offsets[k+1]) of the PRE-subsample batch.
    """

    allocations: tuple
    offsets: np.ndarray

    @staticmethod
    def of(allocations, counts) -> "TenantSegments":
        """Build from per-tenant flow counts (tenant order)."""
        off = np.concatenate([[0], np.cumsum(np.asarray(counts,
                                                        dtype=np.int64))])
        return TenantSegments(tuple(allocations), off)

    def __len__(self) -> int:
        return len(self.allocations)

    @property
    def n_flows(self) -> int:
        return int(self.offsets[-1])

    def tenant_of_flows(self) -> np.ndarray:
        """[n_flows] tenant index per pre-subsample app-flow row."""
        return np.searchsorted(self.offsets, np.arange(self.n_flows),
                               side="right").astype(np.int64) - 1

    @cached_property
    def union_allocation(self) -> Allocation:
        """Union of every tenant's nodes — the background-traffic
        disjointness pool (other jobs share nodes with NO tenant)."""
        nodes = np.unique(np.concatenate(
            [np.asarray(a.nodes, dtype=np.int64)
             for a in self.allocations])) if self.allocations \
            else np.empty(0, dtype=np.int64)
        ids = ",".join(a.allocation_id for a in self.allocations)
        return Allocation(allocation_id=f"mix({ids})",
                          nodes=tuple(int(x) for x in nodes))


def _pair_compress(links: np.ndarray, valid: np.ndarray):
    """Flatten the PAD-padded [n, ncand, hops] candidate-link tensor into
    the fast path's (link, flow-candidate) pair lists.

    Returns (pair_links [P], pair_fc [P]): for every *valid* hop entry,
    the link id and the flat ``flow * ncand + cand`` index whose spray
    weight scales the bytes offered to that link.  ``np.bincount`` over
    these pairs is the segment-sum replacing ``np.add.at`` — skipping
    the PAD zero-contributions keeps the per-bin accumulation order (and
    therefore the float64 sums) bit-identical.
    """
    idx = np.flatnonzero(valid.ravel())
    return links.ravel()[idx], idx // links.shape[2]


@dataclass
class PhasePlan:
    """Precomputed, reusable tensors for one app traffic pattern.

    Repeated collective rounds (fig7/fig8/fig10 ping-pong & alltoall,
    train/serve step loops) re-send the same (src, dst, bytes) pattern
    every iteration; a plan freezes everything ``run_phase`` would
    otherwise rebuild per call: the candidate-path draw, validity masks,
    the bincount pair lists, NIC ids and packet counts.

    Reuse contract (docs/performance.md): a plan's candidate paths (and,
    for oversized phases, the statistical subsample) are drawn ONCE from
    the simulator RNG at plan creation and then FROZEN — replaying a
    plan consumes fewer RNG draws than planless calls, so plan-reused
    runs are seeded-deterministic but not draw-for-draw identical to
    planless ones.  Background traffic, phantom noise and spray noise
    stay fresh per phase.  Plans are immutable and topology-bound; they
    may be shared across policies/modes but not across simulators with
    different topologies.
    """

    src: np.ndarray             # [n] app flow sources (post-subsample)
    dst: np.ndarray
    size: np.ndarray            # [n] bytes (subsample-scaled)
    n_flows_in: int             # flow count the plan was built from
    subsample_idx: np.ndarray | None   # rows kept when n_flows_in > cap
    links: np.ndarray           # [n, ncand, hops] PAD-padded link ids
    valid: np.ndarray
    safe: np.ndarray
    hops: np.ndarray            # [n, ncand]
    is_nonmin: np.ndarray       # [ncand]
    pair_links: np.ndarray
    pair_fc: np.ndarray
    nic_ids: np.ndarray         # [n] injection link per flow
    packets: np.ndarray         # [n] request packets per flow
    ser_s_app: float            # clean serialization time of largest msg
    #: jax backend: the plan's phase-invariant tensors pinned on device
    #: (filled lazily by repro.dragonfly.jax_backend._device_plan; the
    #: bundle's lifetime is the plan's, and `plan_for`'s cache key —
    #: topology spec + fault epoch + notify epoch + pattern — is what
    #: keys the device side of the cache too)
    device_bundle: object = field(default=None, repr=False, compare=False)

    @property
    def n_flows(self) -> int:
        return int(self.src.shape[0])


class DragonflySimulator:
    def __init__(self, topo: Topology | None = None,
                 params: SimParams = SimParams(), faults=None):
        if params.backend not in BACKENDS:
            raise ValueError(f"unknown backend {params.backend!r}; "
                             f"expected one of {BACKENDS}")
        if params.pallas_kernel not in ("auto", "on", "off"):
            raise ValueError(
                f"unknown pallas_kernel {params.pallas_kernel!r}; "
                f"expected 'auto', 'on' or 'off'")
        # topo=None resolves params.topology ("aries", "dragonfly:p=2,...",
        # any registered family spec) through make_topology
        self.topo = topo = make_topology(topo if topo is not None
                                         else params.topology)
        self.params = params
        self.rng = np.random.default_rng(params.seed)
        self.link_queue_s = np.zeros(topo.n_links)  # seconds-to-drain units
        self.est_memory_s = np.zeros(topo.n_links)  # stale estimate memory
        #: congestion-notification state (SimParams.notify_*): per-link
        #: phase age of the active flag — -1 means no flag, and a flag
        #: becomes visible to source routers once its age reaches
        #: notify_delay_phases.  Lives alongside link_queue_s /
        #: est_memory_s and follows the same lifecycle: cleared by
        #: reset_queues() and by fault-epoch resets (dead links never
        #: notify, docs/faults.md).
        self.link_notify_age = np.full(topo.n_links, -1, dtype=np.int64)
        self._notify_epoch = 0              # bumps when the visible set changes
        self._notify_fault_epoch = 0        # last fault epoch seen by the channel
        self.counters: dict[str, NICCounters] = {}
        self.clock_s: float = 0.0
        self.total_flits_all_jobs: float = 0.0
        self._phase_count = 0
        self._hot_groups = self.rng.choice(
            topo.n_groups,
            size=min(params.bg_hot_groups, topo.n_groups),
            replace=False)
        self._plan_cache: dict = {}
        #: accumulated per-stage wall time (params.profile_stages)
        self.stage_time_s: dict[str, float] = {}
        #: fault injection (docs/faults.md): phase index of the NEXT
        #: run_phase call, and the bound schedule (None = healthy machine)
        self.phase_index = 0
        self.faults = None
        if faults is not None:
            self.set_faults(faults)

    def set_faults(self, schedule) -> None:
        """Install a :class:`repro.faults.FaultSchedule` (binding it to
        this simulator's topology).  An empty/None schedule restores the
        healthy machine — output is then bit-identical to a fault-free
        simulator, seed-for-seed (tests/test_faults.py)."""
        if schedule and not hasattr(schedule, "state_at"):
            schedule = schedule.bind(self.topo)   # FaultSchedule -> bound
        self.faults = schedule or None

    def fault_epoch(self) -> int:
        """Fault epoch of the NEXT phase (keys the plan cache)."""
        return self.faults.epoch_at(self.phase_index) \
            if self.faults is not None else 0

    def notify_epoch(self) -> int:
        """Notification epoch: increments whenever the set of VISIBLE
        congestion flags changes between phases (keys the plan cache —
        a mirror of fault_epoch()).  Always 0 while the channel is
        disabled."""
        return self._notify_epoch

    @property
    def notified_links(self) -> np.ndarray:
        """Bool [n_links]: flags visible to source routers on the NEXT
        phase (raised at least notify_delay_phases ago, not yet
        cleared by the hysteresis low-water mark)."""
        return self.link_notify_age >= self.params.notify_delay_phases

    # --------------------------------------------------------- counter API
    def backend_for(self, allocation_id: str):
        """CounterBackend view for one allocation's NICs."""
        sim = self

        class _Backend:
            def read_counters(_s) -> NICCounters:
                return sim.counters.setdefault(allocation_id, NICCounters())

            def now_s(_s) -> float:
                return sim.clock_s

        return _Backend()

    # ------------------------------------------------------------- internals
    def _stage(self, name: str, t0: float) -> float:
        t1 = time.perf_counter()
        self.stage_time_s[name] = self.stage_time_s.get(name, 0.0) + t1 - t0
        return t1

    def _bg_flows(self, allocation: Allocation | None = None):
        p = self.params
        n = p.bg_flows_per_phase
        if not p.bg_enable or n == 0:
            return None
        tp = self.topo
        self._phase_count += 1
        if self._phase_count % max(1, p.bg_rotate_phases) == 0:
            self._hot_groups = self.rng.choice(
                tp.n_groups, size=min(p.bg_hot_groups, tp.n_groups),
                replace=False)
        nodes_per_group = tp.nodes_per_group
        ours = np.asarray(allocation.nodes) if allocation is not None \
            else np.empty(0, dtype=np.int64)
        # nodes outside the allocation (the disjointness fallback pool);
        # empty only in the degenerate whole-machine-allocation case
        free = None

        def draw(size):
            nonlocal free
            hot = self.rng.random(size) < p.bg_hot_prob
            grp = np.where(
                hot,
                self.rng.choice(self._hot_groups, size=size),
                self.rng.integers(0, tp.n_groups, size=size))
            off = self.rng.integers(0, nodes_per_group, size=size)
            out = grp * nodes_per_group + off
            # batch systems do not share nodes between jobs: other-job flows
            # never originate/terminate on the allocation's nodes.  Resample
            # to DISJOINTNESS (bounded, seeded): a few general redraws, then
            # any survivor is drawn from the complement directly, so overlap
            # cannot silently persist (pre-PR-3 bug: 3 retries then give up)
            for _ in range(3):
                bad = np.isin(out, ours)
                if not bad.any():
                    return out
                out[bad] = self.rng.integers(0, tp.n_nodes, size=bad.sum())
            bad = np.isin(out, ours)
            if bad.any():
                if free is None:
                    free = np.setdiff1d(np.arange(tp.n_nodes), ours)
                if free.size:
                    out[bad] = self.rng.choice(free, size=bad.sum())
            return out

        src = draw(n)
        dst = draw(n)
        dst = np.where(dst == src, (dst + 1) % tp.n_nodes, dst)
        # the +1 shift above can re-land on the allocation (or on src):
        # walk forward deterministically until outside both (no RNG draws,
        # so the stream matches the pre-fix code whenever it was correct)
        bad = np.isin(dst, ours) | (dst == src)
        for _ in range(int(tp.n_nodes)):
            if not bad.any():
                break
            dst = np.where(bad, (dst + 1) % tp.n_nodes, dst)
            bad = np.isin(dst, ours) | (dst == src)
        size = (self.rng.pareto(p.bg_pareto_alpha, size=n) + 1.0) \
            * p.bg_bytes_scale
        return src, dst, size

    @staticmethod
    def _flits_packets(bytes_: np.ndarray):
        packets = np.maximum(1, np.ceil(bytes_ / 64.0))
        flits = packets * 5.0  # PUT: 1 header + 4 payload flits
        return flits, packets

    # --------------------------------------------------------------- plans
    def make_plan(self, src_nodes, dst_nodes, bytes_) -> PhasePlan:
        """Build a reusable PhasePlan for one app traffic pattern.

        Consumes RNG draws for the candidate paths (and the statistical
        subsample if the phase exceeds ``max_flows``) exactly once; see
        the PhasePlan reuse contract."""
        p = self.params
        src = np.asarray(src_nodes, dtype=np.int64)
        dst = np.asarray(dst_nodes, dtype=np.int64)
        size = np.asarray(bytes_, dtype=np.float64)
        n_in = int(src.shape[0])
        sub_idx = None
        if n_in > p.max_flows:
            sub_idx = self.rng.choice(n_in, size=p.max_flows, replace=False)
            scale = n_in / p.max_flows
            src, dst, size = src[sub_idx], dst[sub_idx], size[sub_idx] * scale
        links, is_nonmin = self.topo.candidate_paths(
            src, dst, self.rng,
            n_min=p.n_min_candidates, n_nonmin=p.n_nonmin_candidates)
        valid = links != PAD
        pair_links, pair_fc = _pair_compress(links, valid)
        return PhasePlan(
            src=src, dst=dst, size=size, n_flows_in=n_in,
            subsample_idx=sub_idx,
            links=links, valid=valid, safe=np.where(valid, links, 0),
            hops=valid.sum(axis=-1), is_nonmin=is_nonmin,
            pair_links=pair_links, pair_fc=pair_fc,
            nic_ids=np.asarray(self.topo.nic_link(src)),
            packets=np.maximum(1, np.ceil(size / 64.0)),
            ser_s_app=(float(size.max() * p.flit_ns_per_byte) * 1e-9
                       if size.size else 0.0),
        )

    def plan_for(self, src_nodes, dst_nodes, bytes_) -> PhasePlan:
        """Content-addressed plan cache: repeated (src, dst, bytes)
        patterns get one shared PhasePlan per simulator.

        The key also covers the topology spec and the CURRENT fault
        epoch: a plan drawn on the healthy machine must not be replayed
        once a fault changes the link set (its frozen candidate paths
        would silently keep routing into dead links), so every fault
        epoch recomputes — the plan-level half of rerouting."""
        import hashlib

        src = np.asarray(src_nodes, dtype=np.int64)
        dst = np.asarray(dst_nodes, dtype=np.int64)
        size = np.asarray(bytes_, dtype=np.float64)
        h = hashlib.sha1()
        h.update(self.topo.spec_str().encode())
        h.update(str(self.fault_epoch()).encode())
        # notification epoch: the key is a superset of everything
        # run_phase reads, so a reactive arm never replays a plan keyed
        # to a different visible-flag set (cheap insurance mirroring the
        # fault epoch — always 0, hence free, while the channel is off)
        h.update(str(self._notify_epoch).encode())
        for a in (src, dst, size):
            h.update(a.tobytes())
        key = h.digest()
        plan = self._plan_cache.get(key)
        if plan is None:
            if len(self._plan_cache) >= 64:     # bounded: drop the oldest
                self._plan_cache.pop(next(iter(self._plan_cache)))
            plan = self._plan_cache[key] = self.make_plan(src, dst, size)
        return plan

    # ------------------------------------------------------------- run_phase
    def run_phase(self, src_nodes, dst_nodes, bytes_, policy: RoutingPolicy,
                  allocation: Allocation | None = None,
                  modes: np.ndarray | None = None,
                  plan: PhasePlan | None = None,
                  tenants: TenantSegments | None = None) -> FlowResult:
        """Simulate one phase of concurrent flows routed with `policy`.

        `modes` (optional, [n_app] object array of RoutingModes) is the
        PolicyEngine path: per-flow modes from one vectorized
        engine.decide() call bias each flow individually; `policy` then
        only supplies the calibration constants (bias_unit_s etc.).

        `plan` (optional) replays a precomputed PhasePlan for the app
        flows (src/dst/bytes args are then ignored); candidate paths are
        not redrawn — see the PhasePlan reuse contract.

        `tenants` (optional, repro.tenancy path) declares the app batch
        as K concatenated tenant segments: NIC counters are credited per
        tenant allocation, background flows avoid the UNION of tenant
        nodes, and the result carries the per-tenant link-load breakdown
        (FlowResult.tenant_*).  Mutually exclusive with `allocation` —
        a K=1 TenantSegments is bit-identical to passing that tenant's
        Allocation directly (tests/test_tenancy.py)."""
        ctx = self._phase_begin(src_nodes, dst_nodes, bytes_, policy,
                                allocation=allocation, modes=modes,
                                plan=plan, tenants=tenants)
        if ctx["result"] is not None:
            return ctx["result"]
        return self._phase_finish(ctx, self._run_kernel(ctx))

    def _phase_begin(self, src_nodes, dst_nodes, bytes_,
                     policy: RoutingPolicy,
                     allocation: Allocation | None = None,
                     modes: np.ndarray | None = None,
                     plan: PhasePlan | None = None,
                     tenants: TenantSegments | None = None) -> dict:
        """Host half #1 of run_phase, up to the kernel boundary.

        Draws ALL of the phase's randomness (bg flows, candidate paths,
        phantom noise, Gumbel spray noise) from the simulator RNG and
        assembles the kernel inputs into a context dict; `_run_kernel`
        and `_phase_finish` complete the phase.  This split is what lets
        ``run_phase_batch`` fuse several simulators' kernels into one
        vmapped jax dispatch: begin/finish stay per-simulator (so
        batching never changes any RNG draw), only the pure kernel is
        batched.  For the numpy backend the host score base is computed
        here; the jax backend computes it in-graph and the host copy is
        skipped (``ctx["score0"]`` stays None)."""
        p = self.params
        topo = self.topo
        prof = p.profile_stages
        t0 = time.perf_counter() if prof else 0.0
        if tenants is not None and allocation is not None:
            raise ValueError("pass either allocation= or tenants=, not both")
        tenant_of = None

        # --- fault state for this phase (docs/faults.md) -------------------
        # None = healthy machine: every fault-path branch below is skipped
        # and the phase is bit-identical to a fault-free simulator.
        fstate = self.faults.state_at(self.phase_index) \
            if self.faults is not None else None
        if self.faults is not None:
            ep = self.faults.epoch_at(self.phase_index)
            if ep != self._notify_fault_epoch:
                # fault-epoch reset: the link set just changed, so flags
                # raised on the OLD machine describe paths that no
                # longer exist — the whole channel restarts (mirror of
                # the PR-4 est_memory_s reset contract)
                self._notify_fault_epoch = ep
                if (self.link_notify_age >= 0).any():
                    if self.notified_links.any():
                        self._notify_epoch += 1
                    self.link_notify_age[:] = -1
        self.phase_index += 1
        if fstate is not None and fstate.any_dead:
            # a downed link holds no backlog and leaves no stale estimate
            self.link_queue_s[fstate.dead] = 0.0
            self.est_memory_s[fstate.dead] = 0.0
            # ... and never notifies: an active flag dies with its link
            # instead of demoting paths the mask already removed
            self.link_notify_age[fstate.dead] = -1

        # --- app flows: from the plan, or validated + subsampled fresh ----
        if plan is not None:
            if modes is not None and np.shape(modes)[0] != plan.n_flows_in:
                raise ValueError("modes must have one entry per app flow")
            if modes is not None and plan.subsample_idx is not None:
                modes = modes[plan.subsample_idx]
            if tenants is not None:
                if tenants.n_flows != plan.n_flows_in:
                    raise ValueError("tenant segments must cover the plan's "
                                     "app flows")
                tenant_of = tenants.tenant_of_flows()
                if plan.subsample_idx is not None:
                    tenant_of = tenant_of[plan.subsample_idx]
            src, dst, size = plan.src, plan.dst, plan.size
            n_app = plan.n_flows
        else:
            src = np.asarray(src_nodes, dtype=np.int64)
            dst = np.asarray(dst_nodes, dtype=np.int64)
            size = np.asarray(bytes_, dtype=np.float64)
            n_app = src.shape[0]
            if modes is not None and np.shape(modes)[0] != n_app:
                raise ValueError("modes must have one entry per app flow")
            if tenants is not None:
                if tenants.n_flows != n_app:
                    raise ValueError("tenant segments must cover the app "
                                     "flows")
                tenant_of = tenants.tenant_of_flows()
            if n_app > p.max_flows:
                idx = self.rng.choice(n_app, size=p.max_flows, replace=False)
                scale = n_app / p.max_flows
                src, dst, size = src[idx], dst[idx], size[idx] * scale
                if modes is not None:
                    modes = modes[idx]
                if tenant_of is not None:
                    tenant_of = tenant_of[idx]
                n_app = p.max_flows
        if n_app == 0 and not (p.bg_enable and p.bg_flows_per_phase):
            return {"result": FlowResult(*(np.zeros(0),) * 5, 0.0)}

        bg = self._bg_flows(tenants.union_allocation if tenants is not None
                            else allocation)

        # --- candidate tensors (planless: one joint draw, as pre-refactor;
        #     plan: frozen app tensors + a fresh draw for the bg flows) ----
        if plan is None:
            if bg is not None:
                src_all = np.concatenate([src, bg[0]])
                size_all = np.concatenate([size, bg[2]])
                dst_all = np.concatenate([dst, bg[1]])
            else:
                src_all, dst_all, size_all = src, dst, size
            links, is_nonmin = topo.candidate_paths(
                src_all, dst_all, self.rng,
                n_min=p.n_min_candidates, n_nonmin=p.n_nonmin_candidates)
            valid = links != PAD
            safe = np.where(valid, links, 0)
            hops = valid.sum(axis=-1)
            pair_links, pair_fc = _pair_compress(links, valid)
            nic_ids = np.asarray(topo.nic_link(src_all))
            packets_all = np.maximum(1, np.ceil(size_all / 64.0))
            ser_s_app = float(size[:n_app].max() * p.flit_ns_per_byte) \
                * 1e-9 if n_app else 0.0
        else:
            is_nonmin = plan.is_nonmin
            ser_s_app = plan.ser_s_app
            if bg is not None:
                bg_links, _ = topo.candidate_paths(
                    bg[0], bg[1], self.rng,
                    n_min=p.n_min_candidates, n_nonmin=p.n_nonmin_candidates)
                bg_valid = bg_links != PAD
                bg_pl, bg_fc = _pair_compress(bg_links, bg_valid)
                ncand = bg_links.shape[1]
                valid = np.concatenate([plan.valid, bg_valid])
                safe = np.concatenate(
                    [plan.safe, np.where(bg_valid, bg_links, 0)])
                hops = np.concatenate([plan.hops, bg_valid.sum(axis=-1)])
                pair_links = np.concatenate([plan.pair_links, bg_pl])
                pair_fc = np.concatenate(
                    [plan.pair_fc, bg_fc + n_app * ncand])
                size_all = np.concatenate([size, bg[2]])
                nic_ids = np.concatenate(
                    [plan.nic_ids, np.asarray(topo.nic_link(bg[0]))])
                packets_all = np.concatenate(
                    [plan.packets, np.maximum(1, np.ceil(bg[2] / 64.0))])
            else:
                valid, safe, hops = plan.valid, plan.safe, plan.hops
                pair_links, pair_fc = plan.pair_links, plan.pair_fc
                size_all, nic_ids = size, plan.nic_ids
                packets_all = plan.packets
        n_all = safe.shape[0]
        ncand = safe.shape[1]

        # --- fault masking: kill candidates that cross dead links ----------
        # Vectorized through the same PAD-masked tensors as the fast path:
        # one gather of the dead-link flags over `safe` (PAD entries gather
        # link 0 but are ANDed away by `valid`).  A row whose injection or
        # ejection NIC link is down (router_down takes its hosted nodes
        # along) loses every candidate; rows with no survivor are
        # `stranded` — they spray nowhere and pay fault_penalty_us.
        cand_mask = stranded = None
        if fstate is not None and fstate.any_dead:
            fdead = fstate.dead
            if plan is None:
                dst_all_nodes = dst_all
            elif bg is not None:
                dst_all_nodes = np.concatenate([plan.dst, bg[1]])
            else:
                dst_all_nodes = plan.dst
            row_dead = fdead[nic_ids] \
                | fdead[np.asarray(topo.nic_link(dst_all_nodes))]
            cand_mask = ~((fdead[safe] & valid).any(axis=-1)) \
                & ~row_dead[:, None]
            stranded = ~cand_mask.any(axis=-1)
        if prof:
            t0 = self._stage("candidates", t0)

        # --- stale & noisy congestion estimate (phantom congestion) --------
        noise = self.rng.lognormal(0.0, p.phantom_sigma, size=topo.n_links)
        ghosts = self.rng.exponential(p.phantom_ghost_s, size=topo.n_links)
        a = p.est_staleness
        est_queue_s = ((1.0 - a) * self.link_queue_s
                       + a * self.est_memory_s) * noise + ghosts

        # --- congestion notifications (SimParams.notify_*) -----------------
        # Flags raised on a past phase become visible after the propagation
        # delay and demote every candidate crossing them via the
        # routing-layer penalty (folded into the estimate BEFORE the
        # hoisted score base, so the base gather, the feedback re-gathers
        # and both backends see one consistent per-link cost).  The raw
        # estimate is kept for the end-of-phase raise/clear update: the
        # penalty must not feed back into the hysteresis comparison or a
        # flagged link could never clear.  Disabled (threshold=inf) this
        # block is skipped entirely — no RNG draws, no float ops — keeping
        # the phase bit-identical to the notification-free simulator.
        notify_vis = est_notify = None
        if p.notify_enabled:
            est_notify = est_queue_s
            notify_vis = self.link_notify_age >= p.notify_delay_phases
            if fstate is not None and fstate.any_dead:
                notify_vis &= ~fstate.dead      # dead links never notify
            if notify_vis.any():
                est_queue_s = apply_notifications(
                    est_queue_s, notify_vis, p.notify_penalty_s)

        # --- contention window: the APP phase's clean serialization time ---
        # (stall-free flit serialization of the largest app message; floored
        # so transient small messages do not self-congest)
        window_s = max(ser_s_app, p.min_phase_window_s)
        cap_gbs = topo.capacity_gbs
        if fstate is not None:
            # degraded links keep a fraction of their capacity; DEAD links
            # keep the nominal value (they carry zero load thanks to the
            # candidate mask, and 0-capacity would poison rho with inf)
            cap_gbs = cap_gbs * np.where(fstate.dead, 1.0,
                                         fstate.capacity_scale)
        cap_bps = cap_gbs * 1e9
        bg_policy = RoutingPolicy(RoutingMode.ADAPTIVE_0)

        # --- loop-invariant score base + fused per-row spray constants -----
        # (queue gather + hop latency + bias hoisted OUT of the feedback
        # loop; per-flow modes become one int-code bias lookup per phase)
        bias_rows, posinf, neginf = row_bias_terms(n_app, policy, modes)
        hl_rows = np.full(n_app, policy.hop_latency_s)
        t_rows = np.full(n_app, max(policy.spray_temperature_s, 1e-12))
        if n_all > n_app:
            n_bg = n_all - n_app
            bb, bp_, bn = row_bias_terms(n_bg, bg_policy)
            bias_rows = np.concatenate([bias_rows, bb])
            posinf = np.concatenate([posinf, bp_])
            neginf = np.concatenate([neginf, bn])
            hl_rows = np.concatenate(
                [hl_rows, np.full(n_bg, bg_policy.hop_latency_s)])
            t_rows = np.concatenate(
                [t_rows,
                 np.full(n_bg, max(bg_policy.spray_temperature_s, 1e-12))])
        # backend for THIS phase's kernel: the jax path consumes the
        # fault cand_mask and notification penalties in-graph, so it no
        # longer falls back to numpy on faulted/notified phases
        backend = "numpy"
        if p.backend == "jax":
            from repro.compat.runtime import resolve_backend
            if resolve_backend(p.backend) == "jax":
                backend = "jax"
        score0 = size_inst = nic_load = None
        if backend == "numpy":
            # host score base — skipped on the jax path, where the same
            # gather/bias math runs fused in-graph from est_queue_s
            size_inst = np.minimum(size_all,
                                   cap_gbs[nic_ids] * 1e9 * window_s)
            base = (est_queue_s[safe] * valid).sum(axis=-1) \
                + hl_rows[:, None] * hops
            score0 = apply_bias(base, is_nonmin, bias_rows, posinf, neginf)
        noise_scale = (t_rows * 0.9)[:, None] \
            / np.sqrt(np.maximum(packets_all, 1.0))[:, None]
        # whole-phase spray noise, drawn up-front: one (iters, n, ncand)
        # block consumes the stream exactly like the per-iteration
        # app-then-bg draws did (Gumbel is one double per variate)
        n_spray = max(1, p.route_feedback_iters)
        gnoise = self.rng.gumbel(0.0, 1.0, size=(n_spray, n_all, ncand))
        if backend == "numpy":
            nic_load = np.bincount(nic_ids, weights=size_inst,
                                   minlength=topo.n_links)
        if prof:
            t0 = self._stage("estimate", t0)
        return {
            "result": None, "backend": backend,
            "n_app": n_app, "n_all": n_all, "ncand": ncand,
            "plan": plan, "safe": safe, "valid": valid, "hops": hops,
            "is_nonmin": is_nonmin, "pair_links": pair_links,
            "pair_fc": pair_fc, "nic_ids": nic_ids,
            "size": size, "size_all": size_all,
            "est_queue_s": est_queue_s, "hl_rows": hl_rows,
            "bias_rows": bias_rows, "posinf": posinf, "neginf": neginf,
            "t_rows": t_rows, "noise_scale": noise_scale,
            "gnoise": gnoise, "window_s": window_s, "cap_bps": cap_bps,
            "cap_window": cap_bps * window_s,
            "score0": score0, "size_inst": size_inst,
            "nic_load": nic_load,
            "cand_mask": cand_mask, "stranded": stranded,
            "fstate": fstate, "notify_vis": notify_vis,
            "est_notify": est_notify,
            "tenants": tenants, "tenant_of": tenant_of,
            "allocation": allocation, "t0": t0,
        }

    def _run_kernel(self, ctx: dict):
        """Fixed point + observables for one prepared phase context."""
        if ctx["backend"] == "jax":
            from repro.dragonfly.jax_backend import fixed_point_jax
            return fixed_point_jax(self, ctx)
        return self._fixed_point_numpy(self,
                                       **self._numpy_kernel_kwargs(ctx))

    def _numpy_kernel_kwargs(self, ctx: dict) -> dict:
        """Kwargs for `_fixed_point_numpy` from a phase context.

        A ctx prepared for the jax kernel skips the host score base;
        compute it on demand here (values identical to the eager numpy
        path) so such a phase can still be demoted to numpy."""
        if ctx["score0"] is None:
            ctx["size_inst"] = np.minimum(
                ctx["size_all"], ctx["cap_window"][ctx["nic_ids"]])
            base = (ctx["est_queue_s"][ctx["safe"]]
                    * ctx["valid"]).sum(axis=-1) \
                + ctx["hl_rows"][:, None] * ctx["hops"]
            ctx["score0"] = apply_bias(base, ctx["is_nonmin"],
                                       ctx["bias_rows"], ctx["posinf"],
                                       ctx["neginf"])
            ctx["nic_load"] = np.bincount(
                ctx["nic_ids"], weights=ctx["size_inst"],
                minlength=self.topo.n_links)
        return dict(
            score0=ctx["score0"], safe=ctx["safe"], valid=ctx["valid"],
            hops=ctx["hops"], est_queue_s=ctx["est_queue_s"],
            hl_rows=ctx["hl_rows"], is_nonmin=ctx["is_nonmin"],
            bias_rows=ctx["bias_rows"], posinf=ctx["posinf"],
            neginf=ctx["neginf"], t_rows=ctx["t_rows"],
            noise_scale=ctx["noise_scale"], gnoise=ctx["gnoise"],
            size_inst=ctx["size_inst"], size_all=ctx["size_all"],
            pair_links=ctx["pair_links"], pair_fc=ctx["pair_fc"],
            nic_load=ctx["nic_load"], nic_ids=ctx["nic_ids"],
            cap_window=ctx["cap_window"], window_s=ctx["window_s"],
            cand_mask=ctx["cand_mask"])

    def _phase_finish(self, ctx: dict, out) -> FlowResult:
        """Host half #2: notified exposure, Eq.(2) times, queue and
        notification-state updates, NIC counters, tenant breakdown."""
        p = self.params
        topo = self.topo
        prof = p.profile_stages
        t0 = ctx["t0"]
        n_app, ncand = ctx["n_app"], ctx["ncand"]
        safe, valid, is_nonmin = ctx["safe"], ctx["valid"], ctx["is_nonmin"]
        pair_links, pair_fc = ctx["pair_links"], ctx["pair_fc"]
        size, size_all = ctx["size"], ctx["size_all"]
        window_s, cap_bps = ctx["window_s"], ctx["cap_bps"]
        fstate, stranded = ctx["fstate"], ctx["stranded"]
        notify_vis, est_notify = ctx["notify_vis"], ctx["est_notify"]
        tenants, tenant_of = ctx["tenants"], ctx["tenant_of"]
        allocation = ctx["allocation"]
        w, rho, load_q, lat_us, s_flit = out
        w_app = w[:n_app]
        # per-flow notified exposure: the fraction of each app flow's
        # sprayed bytes that crossed a visibly-flagged link (all zero on
        # quiet phases so reactive policies can tell "enabled, calm"
        # from "disabled"=None)
        flow_notified = None
        if notify_vis is not None:
            flow_notified = np.zeros(n_app)
            if n_app and notify_vis.any():
                cand_flag = (notify_vis[safe[:n_app]]
                             & valid[:n_app]).any(axis=-1)
                flow_notified = (cand_flag * np.asarray(w_app)).sum(axis=-1)
        if prof:
            t0 = self._stage("fixed_point", t0)

        flits, packets = self._flits_packets(size_all)
        win = (packets + MAX_OUTSTANDING_PACKETS // 2) \
            / MAX_OUTSTANDING_PACKETS
        lat_cycles = lat_us * 1e3 * p.nic_clock_ghz
        t_cycles = win * lat_cycles + flits * (s_flit + 1.0)
        t_us = t_cycles / (1e3 * p.nic_clock_ghz)
        if stranded is not None and stranded.any():
            # reroute-or-drop: a flow with zero surviving paths sprays
            # nowhere (all-inf softmin row -> zero weights) and its message
            # time is the retransmit/timeout penalty on top of the local
            # serialization cost — surfaced in t_us so phase durations,
            # victim slowdown, and recovery metrics all see the fault
            t_us = t_us + stranded * p.fault_penalty_us
        duration_s = max(float(t_us[:n_app].max()) * 1e-6, 1e-7) \
            if n_app else window_s
        # "network tile" aggregate: every job's flits on the wire (what a
        # tile counter would see; §3.2's correlation trap)
        self.total_flits_all_jobs += float(flits.sum())

        # --- persistent queues (seconds-to-drain beyond this phase) --------
        excess_s = np.maximum(0.0, load_q / cap_bps
                              - max(duration_s, window_s))
        self.est_memory_s = (self.est_memory_s * p.est_memory_decay
                             + self.link_queue_s * (1 - p.est_memory_decay))
        self.link_queue_s = self.link_queue_s * p.queue_carryover + excess_s
        self.clock_s += duration_s

        # --- notification raise / age / clear (threshold + hysteresis) -----
        # Driven by the RAW estimate (est_notify, penalty-free): a link
        # raises at the threshold high-water mark, an active flag ages one
        # phase at a time toward visibility, and it clears only once the
        # estimate drops below the notify_clear_frac low-water mark — the
        # two-level hysteresis of 2502.00616 that keeps flags from
        # chattering around a single threshold.
        if notify_vis is not None:
            age = self.link_notify_age
            raised = est_notify >= p.notify_threshold_s
            if fstate is not None and fstate.any_dead:
                raised &= ~fstate.dead          # dead links never notify
            low = est_notify < p.notify_clear_frac * p.notify_threshold_s
            active = age >= 0
            age[active & low & ~raised] = -1    # hysteresis clear
            age[active & (raised | ~low)] += 1  # surviving flags age
            age[~active & raised] = 0           # fresh flags start hidden
            if not np.array_equal(self.notified_links, notify_vis):
                self._notify_epoch += 1         # visible set changed

        # --- NIC counters (§2.3): one allocation, or per tenant segment ----
        app_flits, app_packets = flits[:n_app], packets[:n_app]
        app_lat, app_stalls = lat_us[:n_app], s_flit[:n_app]
        # NIC-visible notification events: app flows whose sprayed bytes
        # touched a flagged link (allocation-scoped like every other
        # counter — §3.2: users cannot see other jobs' notifications)
        notif_flows = (flow_notified > 0.0) if flow_notified is not None \
            else None
        # counter_dropout fault: the allocation's NIC telemetry goes dark —
        # no observe(), so readers see a frozen snapshot and the
        # PolicyEngine staleness guard (docs/faults.md) eventually trips
        def _dark(aid):
            return fstate is not None and fstate.counters_blocked(aid)

        if tenants is not None:
            # each tenant sees ONLY its own NICs (§3.2: users cannot see
            # other jobs' counters) — K masked observes, one per segment
            for k, alloc_k in enumerate(tenants.allocations):
                if _dark(alloc_k.allocation_id):
                    continue
                mk = tenant_of == k
                c = self.counters.setdefault(alloc_k.allocation_id,
                                             NICCounters())
                c.observe(
                    flits=int(app_flits[mk].sum()),
                    stalled_cycles=int((app_flits[mk]
                                        * app_stalls[mk]).sum()),
                    packets=int(app_packets[mk].sum()),
                    latency_us_total=float((app_lat[mk]
                                            * app_packets[mk]).sum()),
                    notifications=int(notif_flows[mk].sum())
                    if notif_flows is not None else 0,
                )
        elif allocation is not None and not _dark(allocation.allocation_id):
            c = self.counters.setdefault(allocation.allocation_id,
                                         NICCounters())
            c.observe(
                flits=int(app_flits.sum()),
                stalled_cycles=int((app_flits * app_stalls).sum()),
                packets=int(app_packets.sum()),
                latency_us_total=float((app_lat * app_packets).sum()),
                notifications=int(notif_flows.sum())
                if notif_flows is not None else 0,
            )

        nonmin_bytes = float(
            (size_all[:n_app, None] * w_app * is_nonmin[None, :]).sum())

        # --- per-tenant link-load breakdown (tenancy path only) ------------
        # One flattened bincount over (tenant-id * n_links + link) segment
        # offsets — the PR-3 pair-list machinery with the tenant id as an
        # extra segment axis; row K is the background job's share, and the
        # rows sum to the global backlog load_q (tests/test_tenancy.py).
        t_loads = t_nonmin = None
        if tenants is not None:
            K = len(tenants)
            w_np = np.asarray(w)
            fc_rows = pair_fc // ncand
            seg = np.full(pair_fc.shape[0], K, dtype=np.int64)
            app_pair = fc_rows < n_app
            seg[app_pair] = tenant_of[fc_rows[app_pair]]
            vals_q = (size_all[:, None] * w_np).ravel()[pair_fc]
            t_loads = np.bincount(
                seg * topo.n_links + pair_links, weights=vals_q,
                minlength=(K + 1) * topo.n_links,
            ).reshape(K + 1, topo.n_links)
            nm_flow = (size[:n_app, None] * w_app
                       * is_nonmin[None, :]).sum(axis=1)
            nm_t = np.bincount(tenant_of, weights=nm_flow, minlength=K)
            bytes_t = np.bincount(tenant_of, weights=size[:n_app],
                                  minlength=K)
            t_nonmin = nm_t / np.maximum(bytes_t, 1e-9)
        if prof:
            self._stage("finalize", t0)
        return FlowResult(
            t_us=t_us[:n_app],
            latency_us=app_lat,
            stalls_per_flit=app_stalls,
            flits=app_flits,
            packets=app_packets,
            nonmin_fraction=nonmin_bytes / max(float(size[:n_app].sum()), 1e-9),
            tenant_of=tenant_of,
            tenant_link_loads=t_loads,
            link_load_q=np.asarray(load_q) if tenants is not None else None,
            tenant_nonmin_fraction=t_nonmin,
            stranded=stranded[:n_app] if stranded is not None else None,
            notified=flow_notified,
        )

    # ----------------------------------------------------- numpy fixed point
    @staticmethod
    def _fixed_point_numpy(sim, *, score0, safe, valid, hops, est_queue_s,
                           hl_rows, is_nonmin, bias_rows, posinf, neginf,
                           t_rows, noise_scale, gnoise, size_inst,
                           size_all, pair_links, pair_fc, nic_load,
                           nic_ids, cap_window, window_s, cand_mask=None):
        """Spray/feedback fixed point + observables, NumPy backend.

        Within-phase adaptive feedback: later packets see queues built by
        earlier ones and re-equilibrate (per-packet real-time sensing).
        Damped (w <- (w + w_target)/2) to avoid synchronous flip-flopping.

        ``cand_mask`` (fault path, docs/faults.md): bool [n, ncand];
        False candidates cross a dead link and are forced to +inf right
        before every softmin, so they get exactly zero spray weight —
        all-False rows (stranded flows) spray nowhere.  None (the
        default, healthy machine) leaves the kernel byte-for-byte on
        the bit-identical fast path.
        """
        p = sim.params
        n_links = sim.topo.n_links
        if cand_mask is None:
            def fmask(s):
                return s
        else:
            def fmask(s):
                return np.where(cand_mask, s, np.inf)

        def loads(w):
            # bytes offered DURING the window (a flow cannot inject more
            # than its NIC moves in the window) -> instant contention
            vals = (size_inst[:, None] * w).ravel()[pair_fc]
            return np.bincount(pair_links, weights=vals,
                               minlength=n_links) + nic_load

        w = softmin_weights(fmask(score0), t_rows, gnoise[0], noise_scale)
        load_i = loads(w)
        for it in range(1, gnoise.shape[0]):
            rho_fb = load_i / cap_window
            extra = np.maximum(0.0, rho_fb - p.feedback_rho0) * window_s
            # `extra` is nonzero only on links past feedback_rho0: every
            # row not touching one keeps its hoisted base score (est + 0.0
            # is bitwise est), and only the rows that DO are re-gathered
            # with the combined (est + extra) estimate — the same float64
            # accumulation the unhoisted scorer performs, so the fast
            # path stays bit-identical even in congested phases
            sel = (extra != 0.0)[pair_links]
            if sel.any():
                ncand = score0.shape[1]
                rows = np.unique(pair_fc[sel] // ncand)
                est_it = est_queue_s + extra
                hot = (est_it[safe[rows]] * valid[rows]).sum(axis=-1) \
                    + hl_rows[rows][:, None] * hops[rows]
                score = score0.copy()
                score[rows] = apply_bias(hot, is_nonmin, bias_rows[rows],
                                         posinf[rows], neginf[rows])
            else:
                score = score0
            w = 0.5 * (w + softmin_weights(fmask(score), t_rows, gnoise[it],
                                           noise_scale))
            load_i = loads(w)

        # load_q: full backlog bytes (feeds persistent queues/Fig.3 tails)
        vals_q = (size_all[:, None] * w).ravel()[pair_fc]
        load_q = np.bincount(pair_links, weights=vals_q, minlength=n_links)
        rho = load_i / cap_window
        lat_us, s_flit = sim._observables(valid, safe, rho, w, nic_ids,
                                          hops=hops, pair_links=pair_links,
                                          pair_fc=pair_fc)
        return w, rho, load_q, lat_us, s_flit

    def _observables(self, valid, safe, rho, w, nic_ids, *,
                     hops=None, pair_links=None, pair_fc=None):
        """Per-flow (L_us, s) from per-link utilization.

        With the fast path's pair lists, the congested-link terms
        (queuing-delay excess, persistent-queue waits, bottleneck
        stalls) are evaluated sparsely: only links past the thresholds
        contribute, and skipping their exact-0.0 terms leaves every
        float64 accumulation bit-identical to the dense gathers."""
        p = self.params
        tp = self.topo
        n, ncand = w.shape
        if hops is None:
            hops = valid.sum(axis=-1)                   # [n, ncand]
        if pair_links is None:
            # safe == links on the valid entries _pair_compress keeps
            pair_links, pair_fc = _pair_compress(safe, valid)
        hot_pairs = (rho > p.rho_threshold)[pair_links]
        any_hot = bool(hot_pairs.any())
        rho_nic = rho[nic_ids]                          # [n]
        nic_hot = rho_nic > p.rho_threshold
        qdelay_sum = np.zeros((n, ncand))
        s_flit = np.zeros(n)
        if any_hot or nic_hot.any():
            # union of rows whose path or NIC crosses rho_threshold: the
            # only rows with nonzero queuing-delay excess or stalls —
            # everyone else's terms are exact 0.0s, so the dense hop
            # gather/max runs on this (usually small) subset only
            rows = np.unique(np.concatenate(
                [pair_fc[hot_pairs] // ncand, np.flatnonzero(nic_hot)])) \
                if any_hot else np.flatnonzero(nic_hot)
            rho_path = rho[safe[rows]] * valid[rows]    # [k, ncand, hops]
            excess = np.maximum(0.0, rho_path - p.rho_threshold)
            qdelay_sum[rows] = excess.sum(axis=-1)
            rho_bneck = np.maximum(rho_path.max(axis=-1),
                                   rho_nic[rows][:, None])   # [k, ncand]
            s_cand = p.stall_gain * np.maximum(
                0.0, rho_bneck - p.rho_threshold)
            s_flit[rows] = (s_cand * w[rows]).sum(axis=-1)
        qdelay_ns = p.queue_delay_ns * qdelay_sum       # [n, ncand]
        # waiting behind queues persisting from earlier traffic: a packet
        # entering a link with q seconds-to-drain of backlog waits ~q
        # (discounted: spraying interleaves it into the backlog).  This is
        # THE outlier mechanism of Fig. 3 — and what adaptive routing dodges
        # when its congestion estimate is fresh.
        lq = self.link_queue_s
        lq_pairs = (lq != 0.0)[pair_links]
        qwait_sum = np.zeros((n, ncand))
        if lq_pairs.any():
            rows_q = np.unique(pair_fc[lq_pairs] // ncand)
            qwait_sum[rows_q] = (lq[safe[rows_q]]
                                 * valid[rows_q]).sum(axis=-1)
        qwait_ns = qwait_sum * p.qwait_fraction * 1e9
        lat_ns_cand = 2.0 * tp.nic_latency_ns + hops * tp.hop_latency_ns \
            + qdelay_ns + qwait_ns
        lat_us = (lat_ns_cand * w).sum(axis=-1) / 1e3   # weighted over cands
        return lat_us, s_flit

    # ----------------------------------------------------------------- misc
    def reset_queues(self, *, include_estimates: bool = True) -> None:
        """Clear the network's residual congestion state.

        Shared-vs-isolated contract (docs/interference.md): ONE simulator
        models ONE physical network, so back-to-back ``run_phase`` calls
        SHARE link queues and the stale-estimate memory BY DESIGN — that
        sharing is exactly how co-running allocations become each other's
        noise in the tenancy engine.  For ISOLATED experiments (run-alone
        baselines, reusing a simulator across independent scenarios) call
        ``reset_queues()`` between them: it clears BOTH the persistent
        link queues and the stale congestion-estimate memory.  Before the
        tenancy PR it leaked ``est_memory_s``, so a previous allocation's
        drained hotspots still phantom-congested the next allocation's
        estimates across a "reset".  Pass ``include_estimates=False`` to
        reproduce that legacy partial reset.  Per-allocation NIC counters
        are already isolated per allocation_id and never leak."""
        self.link_queue_s[:] = 0.0
        # notification flags are congestion state like the queues that
        # raised them: an isolated experiment must not inherit a previous
        # scenario's visible flags (the same leak class as the PR-4
        # est_memory_s bug — regression-pinned in tests/test_notifications)
        if (self.link_notify_age >= 0).any():
            if self.notified_links.any():
                self._notify_epoch += 1
            self.link_notify_age[:] = -1
        if include_estimates:
            self.est_memory_s[:] = 0.0


def run_phase_batch(calls) -> list:
    """Run several simulators' phases, fusing compatible jax kernels.

    ``calls``: sequence of ``(sim, kwargs)`` pairs — each ``kwargs`` is
    one `DragonflySimulator.run_phase` argument dict (the sims should be
    distinct; one sim may not appear twice in a batch).  Per-sim host
    halves (`_phase_begin` / `_phase_finish`) run exactly as in
    sequential ``run_phase`` calls — same RNG draws, same state updates
    — while jax-backed kernels whose `batch_signature`s agree are
    evaluated through ONE vmapped device dispatch
    (`jax_backend.fixed_point_jax_batch`).  Everything else (numpy
    backends, singleton shapes) runs its kernel per-sim.  Returns the
    [FlowResult] list in call order.

    This is the tenancy lockstep driver's primitive: whole sweep
    columns (same mix, different victim arms) advance round-for-round
    with every cell's phase kernel batched into one dispatch
    (docs/interference.md)."""
    ctxs = [sim._phase_begin(**kw) for sim, kw in calls]
    outs: dict = {}
    groups: dict = {}
    for i, ((sim, _), ctx) in enumerate(zip(calls, ctxs)):
        if ctx["result"] is None and ctx["backend"] == "jax":
            from repro.dragonfly.jax_backend import batch_signature
            groups.setdefault(batch_signature(sim, ctx), []).append(i)
    for idxs in groups.values():
        if len(idxs) < 2:
            continue
        from repro.dragonfly.jax_backend import fixed_point_jax_batch
        batch = [(calls[i][0], ctxs[i]) for i in idxs]
        for i, o in zip(idxs, fixed_point_jax_batch(batch)):
            outs[i] = o
    results = []
    for i, ((sim, _), ctx) in enumerate(zip(calls, ctxs)):
        if ctx["result"] is not None:
            results.append(ctx["result"])
            continue
        out = outs.get(i)
        if out is None:
            out = sim._run_kernel(ctx)
        results.append(sim._phase_finish(ctx, out))
    return results
