# repro.sharding — name-based partitioning rules over parameter / input /
# decode-state pytrees, divisibility-aware (a dim is sharded over an axis
# only if evenly divisible; otherwise the next candidate or replication).

from repro.sharding.partition import (
    param_specs, input_specs_sharding, decode_state_specs, ShardingPolicy,
)

__all__ = ["param_specs", "input_specs_sharding", "decode_state_specs",
           "ShardingPolicy"]
