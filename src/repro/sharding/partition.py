"""Partitioning rules: parameter-name -> dimension roles -> mesh axes.

Role assignment (Megatron-style TP over the "model" axis; DP over
("pod","data")):

    vocab, heads, ff, inner, experts  ->  "model"   (TP / EP)
    d (hidden)                        ->  fsdp axis if ShardingPolicy.fsdp
    batch                             ->  ("pod","data") / ("data",)

Every rule is divisibility-checked against the mesh; a dim that does not
divide falls back to replication.  Stacked leading dims (the lax.scan layer
axis, or the hybrid's [n_super, period] prefix) are auto-detected by rank.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import Family, ModelConfig

# parameter-name -> dimension roles (rightmost dims; leading stacked dims
# are padded with None automatically)
_ROLE_RULES = {
    "embed": ("vocab", "d"),
    "lm_head": ("d", "vocab"),
    "pos_enc": (None, "d"),
    "wq": ("d", "heads"), "wk": ("d", "heads"), "wv": ("d", "heads"),
    "wo": ("heads", "d"),
    "bq": ("heads",), "bk": ("heads",), "bv": ("heads",),
    "w_in": ("d", "ff"), "w_gate": ("d", "ff"), "w_out": ("ff", "d"),
    "router": ("d", None),
    # mamba2 (split projections; see models/mamba2.py docstring)
    "w_z": ("d", "inner"), "w_x": ("d", "inner"),
    "w_b": ("d", None), "w_c": ("d", None), "w_dt": ("d", None),
    "conv_x_w": (None, "inner"), "conv_x_b": ("inner",),
    "conv_b_w": (None, None), "conv_c_w": (None, None),
    "conv_bb": (None,), "conv_cb": (None,),
    "a_log": (None,), "d_skip": (None,), "dt_bias": (None,),
    "norm_g": ("inner",),
    "out_proj": ("inner", "d"),
}
# MoE expert tensors carry an extra leading experts dim
_MOE_RULES = {
    "w_in": ("experts", "d", "ff"),
    "w_gate": ("experts", "d", "ff"),
    "w_out": ("experts", "ff", "d"),
}
_REPLICATED_NAMES = {"ln1", "ln2", "ln_f", "ln_x", "ln", "enc_ln", "gamma"}


@dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    dp_axes: tuple = ("data",)        # ("pod","data") on multi-pod meshes
    fsdp: bool = False                # shard the "d" role over dp axes
    #: EP: MoE expert dim over tp_axis (True) vs ff sharding (False)
    expert_parallel: bool = True

    def role_axis(self, role: Optional[str]):
        if role is None:
            return None
        if role in ("vocab", "heads", "ff", "inner"):
            return self.tp_axis
        if role == "experts":
            return self.tp_axis if self.expert_parallel else None
        if role == "d":
            return self.dp_axes if self.fsdp else None
        if role == "batch":
            return self.dp_axes
        return None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _spec_for_leaf(path_keys, leaf, mesh: Mesh, policy: ShardingPolicy,
                   cfg: ModelConfig):
    name = None
    in_moe = False
    for k in path_keys:
        if hasattr(k, "key"):
            if k.key == "moe":
                in_moe = True
            name = k.key
    if name in _REPLICATED_NAMES or name is None:
        return P()
    roles = None
    if in_moe and name in _MOE_RULES and leaf.ndim >= 3:
        roles = _MOE_RULES[name]
    elif name in _ROLE_RULES:
        roles = _ROLE_RULES[name]
    if roles is None:
        return P()
    ndim = leaf.ndim
    pad = ndim - len(roles)
    if pad < 0:  # scalar-ish leaf with fewer dims than roles
        roles = roles[-ndim:]
        pad = 0
    spec = [None] * pad
    used: set = set()
    for i, role in enumerate(roles):
        axis = policy.role_axis(role)
        dim = leaf.shape[pad + i]
        flat = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
        if (axis is not None and dim % _axis_size(mesh, axis) == 0
                and not (used & set(flat))):
            spec.append(axis)
            used |= set(flat)
        else:
            spec.append(None)
    return P(*spec)


def param_specs(params, cfg: ModelConfig, mesh: Mesh,
                policy: ShardingPolicy | None = None):
    """Pytree of NamedSharding matching `params`."""
    policy = policy or default_policy(mesh)

    def fn(path, leaf):
        return NamedSharding(mesh, _spec_for_leaf(path, leaf, mesh, policy,
                                                  cfg))

    return jax.tree_util.tree_map_with_path(fn, params)


def default_policy(mesh: Mesh) -> ShardingPolicy:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ShardingPolicy(tp_axis="model", dp_axes=dp)


def input_specs_sharding(specs: dict, cfg: ModelConfig, mesh: Mesh,
                         policy: ShardingPolicy | None = None):
    """Shardings for the input_specs dict (tokens/labels/frames/patches):
    batch over dp axes (when divisible), everything else replicated.
    For `long_500k` (global_batch=1) the sequence dim is sharded over the
    dp axes instead, so the KV/cache pressure spreads."""
    policy = policy or default_policy(mesh)
    dp = policy.dp_axes
    dp_size = _axis_size(mesh, dp)
    out = {}
    for k, v in specs.items():
        spec = [None] * len(v.shape)
        if v.shape and v.shape[0] % dp_size == 0 and v.shape[0] > 1:
            spec[0] = dp
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def decode_state_specs(state, cfg: ModelConfig, mesh: Mesh,
                       policy: ShardingPolicy | None = None):
    """Shardings for decode states (KV caches / SSM states).

    Rules per leaf (by rank/shape, since state pytrees are uniform):
      * batch dim (the first dim whose size == runtime batch) -> dp axes
        when divisible;
      * KV-cache head dim -> tp when divisible, else the sequence dim
        -> tp (long-context: spreads the 500k cache);
      * SSM state dims -> tp on the heads dim when divisible.
    """
    policy = policy or default_policy(mesh)
    tp = policy.tp_axis
    tp_size = mesh.shape[tp]
    dp = policy.dp_axes
    dp_size = _axis_size(mesh, dp)

    def fn(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        names = [getattr(k, "name", getattr(k, "key", "")) for k in path]
        # KV caches: [..., B, S, Hkv, hd]; mamba ssm: [..., B, H, N, P];
        # conv states: [..., B, K-1, C]
        if leaf.ndim >= 4:
            b_dim = leaf.ndim - 4
            s_dim, h_dim = leaf.ndim - 3, leaf.ndim - 2
            batch_sharded = (leaf.shape[b_dim] % dp_size == 0
                             and leaf.shape[b_dim] > 1)
            if batch_sharded:
                spec[b_dim] = dp
            if leaf.shape[h_dim] % tp_size == 0:
                spec[h_dim] = tp
                # long-context decode (global_batch == 1): spread the huge
                # seq dim over the idle dp axes instead
                if not batch_sharded and leaf.shape[s_dim] % dp_size == 0 \
                        and leaf.shape[s_dim] > dp_size:
                    spec[s_dim] = dp
            elif leaf.shape[s_dim] % tp_size == 0:
                spec[s_dim] = tp
        elif leaf.ndim >= 2:
            b_dim = 0 if leaf.ndim == 2 else leaf.ndim - 3
            c_dim = leaf.ndim - 1
            if leaf.shape[b_dim] % dp_size == 0 and leaf.shape[b_dim] > 1:
                spec[b_dim] = dp
            if leaf.shape[c_dim] % tp_size == 0:
                spec[c_dim] = tp
        del names
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(fn, state)
