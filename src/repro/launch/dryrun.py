import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run — deliverable (e).

For every (architecture x input shape) cell, lower + compile the
production step function (train_step / prefill / decode serve_step) on the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh, print
memory_analysis / cost_analysis, and derive the roofline terms from the
compiled HLO (analysis/).  The XLA_FLAGS line above MUST precede any other
import (jax locks the device count at first init).

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out report.jsonl]
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis.hlo_parse import parse_hlo
from repro.analysis.roofline import model_flops_estimate, roofline_terms
from repro.configs import (SHAPES, ShapeNotSupported, get_config,
                           input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import registry as model_registry
from repro.models.common import Family
from repro.sharding.partition import (decode_state_specs, default_policy,
                                      input_specs_sharding, param_specs)
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainConfig, train_step


def _sds(tree):
    """eval_shape pytree -> ShapeDtypeStruct pytree (already is)."""
    return tree


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               policy_overrides: dict | None = None,
               mesh_override: tuple | None = None,
               microbatch_override: int | None = None):
    """Lower + compile one (arch x shape x mesh) cell.

    Returns (report_dict, compiled) — compiled exposed for perf iteration.
    mesh_override: ((shape...), (axis names...)) — §Perf alternative
    parallelism splits of the same 256/512 chips.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)          # raises ShapeNotSupported
    if mesh_override is not None:
        from repro.launch.mesh import make_mesh_for
        mesh = make_mesh_for(*mesh_override)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    policy = default_policy(mesh)
    # big dense models cannot hold fp32 master+Adam state in TP-only
    # shards: enable FSDP (ZeRO-3-style "d"-dim sharding over dp) when the
    # per-chip optimizer footprint would exceed ~5 GB
    from repro.analysis.roofline import param_counts_analytic
    total_params, _ = param_counts_analytic(cfg)
    tp = mesh.shape[policy.tp_axis]
    if shape.kind == "train" and total_params * 12.0 / tp > 1.5e9:
        from dataclasses import replace as _replace
        policy = _replace(policy, fsdp=True)
    if policy_overrides:
        from dataclasses import replace
        policy = replace(policy, **policy_overrides)

    params_sds = jax.eval_shape(
        lambda: model_registry.init_params(cfg, 0))
    p_shard = param_specs(params_sds, cfg, mesh, policy)
    in_shard = input_specs_sharding(specs, cfg, mesh, policy)

    t0 = time.time()
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            from repro.train.train_step import auto_microbatch
            dp = 1
            for a in policy.dp_axes:
                dp *= mesh.shape[a]
            mb = auto_microbatch(cfg, shape.global_batch, shape.seq_len, dp)
            if microbatch_override is not None:
                mb = microbatch_override
            tcfg = TrainConfig(microbatch=mb)
            opt_sds = jax.eval_shape(adamw_init, params_sds)
            opt_shard = jax.tree_util.tree_map(
                lambda _: None, opt_sds)  # placeholder, built below
            from jax.sharding import NamedSharding, PartitionSpec as P
            scalar = NamedSharding(mesh, P())
            import repro.train.optimizer as _opt
            opt_shard = _opt.AdamWState(
                step=scalar, m=p_shard,
                v=jax.tree_util.tree_map(lambda s: s, p_shard))

            def fn(params, opt_state, batch):
                return train_step(params, opt_state, batch, cfg=cfg,
                                  tcfg=tcfg)

            lowered = jax.jit(
                fn,
                in_shardings=(p_shard, opt_shard, in_shard),
                out_shardings=(p_shard, opt_shard, None),
            ).lower(params_sds, opt_sds, specs)
        elif shape.kind == "prefill":
            state_sds = jax.eval_shape(
                lambda: model_registry.make_decode_state(
                    cfg, shape.global_batch,
                    shape.seq_len + _extra_prefix(cfg)))
            st_shard = decode_state_specs(state_sds, cfg, mesh, policy)

            def fn(params, batch, state):
                return model_registry.prefill(params, batch, cfg, state)

            lowered = jax.jit(
                fn, in_shardings=(p_shard, in_shard, st_shard),
                out_shardings=(None, st_shard), donate_argnums=(2,),
            ).lower(params_sds, specs, state_sds)
        else:  # decode
            state_sds = jax.eval_shape(
                lambda: model_registry.make_decode_state(
                    cfg, shape.global_batch,
                    shape.seq_len + _extra_prefix(cfg)))
            st_shard = decode_state_specs(state_sds, cfg, mesh, policy)

            def fn(params, token, state):
                return model_registry.decode_step(params, token, cfg, state)

            lowered = jax.jit(
                fn, in_shardings=(p_shard, in_shard["tokens"], st_shard),
                out_shardings=(None, st_shard), donate_argnums=(2,),
            ).lower(params_sds, specs["tokens"], state_sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    costs = parse_hlo(txt)
    mesh_shape = tuple(mesh_override[0]) if mesh_override else (
        (2, 16, 16) if multi_pod else (16, 16))
    rep = roofline_terms(
        costs, arch=arch, shape=shape_name, mesh_shape=mesh_shape,
        model_flops=model_flops_estimate(cfg, shape))
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh_shape)),
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "mem_args_gb": round(ma.argument_size_in_bytes / 2**30, 3),
        "mem_out_gb": round(ma.output_size_in_bytes / 2**30, 3),
        "mem_temp_gb": round(ma.temp_size_in_bytes / 2**30, 3),
        "mem_total_gb": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes) / 2**30, 3),
        "xla_flops_raw": ca.get("flops", 0.0),
        "hlo_flops_scaled": rep.hlo_flops_per_chip,
        "hlo_bytes_scaled": rep.hlo_bytes_per_chip,
        "compute_ms": round(rep.compute_s * 1e3, 4),
        "memory_ms": round(rep.memory_s * 1e3, 4),
        "collective_ms": round(rep.collective_s * 1e3, 4),
        "dominant": rep.dominant,
        "collective_intra_gb": round(rep.collective_intra_bytes / 2**30, 4),
        "collective_cross_gb": round(rep.collective_cross_bytes / 2**30, 4),
        "n_collectives": rep.n_collectives,
        "n_while": costs.n_while,
        "model_flops": rep.model_flops_total,
        "useful_flops_ratio": round(rep.useful_flops_ratio, 4),
        "roofline_fraction": round(rep.roofline_fraction, 4),
        "attn_scope_bytes": costs.scope_bytes.get("attn_core", 0.0),
        "attn_scope_flops": costs.scope_flops.get("attn_core", 0.0),
    }
    from repro.analysis.roofline import flash_adjusted
    adj_mem_s, adj_frac = flash_adjusted(rep, costs, cfg, shape)
    report["memory_ms_flash"] = round(adj_mem_s * 1e3, 4)
    report["roofline_fraction_flash"] = round(adj_frac, 4)
    return report, compiled


def _extra_prefix(cfg) -> int:
    if cfg.family == Family.VLM:
        return cfg.img_tokens
    return 0


def run_cells(cells, *, multi_pod: bool, out_path: str | None):
    results = []
    for arch, shape_name in cells:
        tag = f"{arch} x {shape_name} ({'2x16x16' if multi_pod else '16x16'})"
        try:
            rep, compiled = lower_cell(arch, shape_name, multi_pod=multi_pod)
            del compiled
            print(f"[ok]   {tag}: mem={rep['mem_total_gb']:.2f}GB/dev "
                  f"dominant={rep['dominant']} "
                  f"compute={rep['compute_ms']:.3f}ms "
                  f"mem={rep['memory_ms']:.3f}ms "
                  f"coll={rep['collective_ms']:.3f}ms "
                  f"(compile {rep['compile_s']:.1f}s)")
        except ShapeNotSupported as e:
            rep = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if multi_pod else "16x16",
                   "status": "skipped", "reason": str(e)}
            print(f"[skip] {tag}: {e}")
        except Exception as e:
            rep = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if multi_pod else "16x16",
                   "status": "error", "reason": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
        results.append(rep)
        if out_path:
            with open(out_path, "a") as f:
                f.write(json.dumps(rep) + "\n")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ARCHS
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    results = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        results += run_cells(cells, multi_pod=mp, out_path=args.out)
    n_fail = sum(r["status"] == "error" for r in results)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} documented skips, {n_fail} FAILED")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
