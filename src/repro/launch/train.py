"""End-to-end training driver with checkpoint/restart, straggler watch,
and elastic-aware restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container the driver runs the reduced (--smoke) configs; the
same code path drives the full configs on real pods (the mesh comes from
launch.mesh / the ElasticPlanner)."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives.modes import CollectiveMode
from repro.collectives.selector import ICICostModel, MeshSpec
from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import SyntheticLM
from repro.models import registry as model_registry
from repro.models.common import Family, param_count
from repro.policy import DecisionBatch, POLICY_NAMES, make_engine
from repro.runtime.straggler import StragglerMitigator
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, train_step
from repro.ckpt.checkpoint import CheckpointManager


def make_comm_engine(name: str, *, n_pods: int = 2, inner_chips: int = 256):
    """PolicyEngine arbitrating DIRECT vs HIERARCHICAL grad-reduce
    schedules for the training loop (the repro.policy path; the cost
    model self-feeds telemetry on this single-host container, exactly
    like the dry-run)."""
    cost_model = ICICostModel(MeshSpec(n_pods=n_pods,
                                       inner_chips=inner_chips))
    # "message" granularity: every bucket row is its own Algorithm-1
    # step (matching grad_comm.select_bucket_modes), not one decision
    # stamped across the whole step's buckets
    engine = make_engine(name, mode_a=CollectiveMode.HIERARCHICAL,
                         mode_b=CollectiveMode.DIRECT,
                         mode_a_alltoall=CollectiveMode.HIERARCHICAL,
                         static_mode=CollectiveMode.DIRECT,
                         granularity="message")
    return engine, cost_model


def decide_grad_schedule(engine, cost_model, bucket_bytes: list):
    """One vectorized decision per step over all gradient buckets."""
    modes = engine.decide(DecisionBatch.of(bucket_bytes, site="grad_comm"))
    perfs = [cost_model.predict(int(sz), m)
             for sz, m in zip(bucket_bytes, modes)]
    engine.bus.publish_flow_arrays(
        [p.latency_cycles / 1e3 for p in perfs],
        [p.stall_cycles_per_flit for p in perfs], source="model")
    return modes


def make_batch_np(cfg, gen, *, step: int, batch: int, seed: int):
    b = gen.batch(seed=seed, step=step, shard=0, n_shards=1,
                  batch_size=batch)
    rng = np.random.default_rng([seed, step, 99])
    if cfg.family == Family.ENCDEC:
        b["frames"] = rng.standard_normal(
            (batch, cfg.encoder_frames, cfg.d_model)).astype(np.float32) \
            * 0.02
    if cfg.family == Family.VLM:
        b["patches"] = rng.standard_normal(
            (batch, cfg.img_tokens, cfg.d_model)).astype(np.float32) * 0.02
    return b


def train_loop(cfg, *, steps: int, batch: int, seq: int, seed: int,
               ckpt_dir: str | None, ckpt_every: int, lr: float,
               resume: bool = True, log_every: int = 10,
               comm_policy: str | None = None):
    gen = SyntheticLM(vocab=cfg.vocab, seq_len=seq)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=lr, warmup_steps=max(
        steps // 20, 5), total_steps=steps))
    params = model_registry.init_params(cfg, seed)
    opt = adamw_init(params)
    print(f"[train] {cfg.name}: {param_count(params):,d} params")
    start = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt), start, _ = mgr.restore((params, opt))
        params = jax.tree_util.tree_map(jnp.asarray, params)
        opt = jax.tree_util.tree_map(jnp.asarray, opt)
        print(f"[train] resumed from step {start}")

    step_fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg=cfg,
                                                 tcfg=tcfg))
    strag = StragglerMitigator(n_workers=1)
    comm_engine = cost_model = None
    bucket_bytes: list = []
    if comm_policy:
        from repro.train.grad_comm import GradCommConfig, bucketize
        comm_engine, cost_model = make_comm_engine(comm_policy)
        gcfg = GradCommConfig()
        leaves = jax.tree_util.tree_leaves(params)
        bucket_bytes = [
            sum(int(np.prod(leaves[i].shape)) for i in b) * 2
            for b in bucketize(params, gcfg.bucket_bytes)]
        print(f"[train] comm policy '{comm_policy}': "
              f"{len(bucket_bytes)} grad buckets/step")
    losses = []
    for step in range(start, steps):
        t0 = time.time()
        b = make_batch_np(cfg, gen, step=step, batch=batch, seed=seed)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if comm_engine is not None:
            decide_grad_schedule(comm_engine, cost_model, bucket_bytes)
        params, opt, metrics = step_fn(params, opt, b)
        dt = time.time() - t0
        strag.record_step({0: dt})
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:6.0f}ms")
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save_async(step + 1, (params, opt),
                           meta={"loss": loss, "arch": cfg.name})
    if mgr:
        mgr.wait()
        mgr.save_async(steps, (params, opt), meta={"arch": cfg.name})
        mgr.wait()
    if comm_engine is not None:
        frac = comm_engine.traffic_fraction(CollectiveMode.HIERARCHICAL)
        print(f"[train] comm policy: {comm_engine.decide_calls} engine "
              f"calls, {comm_engine.rows_decided} bucket decisions, "
              f"{frac * 100:.0f}% bytes hierarchical")
    return params, opt, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--comm-policy", default=None, choices=POLICY_NAMES,
                    help="grad-reduce schedule policy (repro.policy)")
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    _, _, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        seed=args.seed, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        lr=args.lr, comm_policy=args.comm_policy)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
