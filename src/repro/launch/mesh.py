"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).  Mesh
construction goes through repro.compat, which applies Auto axis_types
on jax>=0.7 and omits them on 0.4.x (see docs/compat.md)."""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") single pod; 2x16x16 ("pod","data","model")
    for the 512-chip two-pod configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh_for(shape: tuple, axes: tuple):
    """Elastic variant: build whatever mesh the ElasticPlanner chose."""
    return compat.make_mesh(tuple(shape), tuple(axes))
