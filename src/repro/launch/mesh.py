"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first)."""

from __future__ import annotations

import jax


def _make_mesh(shape: tuple, axes: tuple):
    # jax.sharding.AxisType landed after 0.4.37; Auto is the default there,
    # so only pass axis_types when the installed jax knows it.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") single pod; 2x16x16 ("pod","data","model")
    for the 512-chip two-pod configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_for(shape: tuple, axes: tuple):
    """Elastic variant: build whatever mesh the ElasticPlanner chose."""
    return _make_mesh(tuple(shape), tuple(axes))
