# repro.launch — production mesh, multi-pod dry-run, train/serve drivers.
# NOTE: do not import repro.launch.dryrun from library code — it sets
# XLA_FLAGS at import time (must be the process's first jax-affecting act).
