"""Serving driver: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import registry as model_registry
from repro.models.common import Family
from repro.serve.engine import Request, ServeConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = model_registry.init_params(cfg, args.seed)
    scfg = ServeConfig(batch=args.requests,
                       max_len=args.prompt_len + args.new_tokens
                       + (cfg.img_tokens if cfg.family == Family.VLM else 0)
                       + 8)
    engine = ServeEngine(cfg, params, scfg)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                             args.prompt_len)),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    extra = {}
    if cfg.family == Family.ENCDEC:
        extra["frames"] = rng.standard_normal(
            (args.requests, cfg.encoder_frames, cfg.d_model)
        ).astype(np.float32) * 0.02
    if cfg.family == Family.VLM:
        extra["patches"] = rng.standard_normal(
            (args.requests, cfg.img_tokens, cfg.d_model)
        ).astype(np.float32) * 0.02
    t0 = time.time()
    out = engine.run(reqs, seed=args.seed, extra=extra or None)
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in out[:args.requests])
    print(f"[serve] {cfg.name}: {args.requests} requests, "
          f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s)")
    for i, r in enumerate(out[: min(3, args.requests)]):
        print(f"  req{i}: {r.out_tokens[:12]}...")


if __name__ == "__main__":
    main()
