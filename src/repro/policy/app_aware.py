"""Algorithm 1 — application-aware selection (paper §4.2/§4.3) as a Policy.

This is the canonical home of the paper's algorithm; the legacy
`repro.core.app_aware.AppAwareRouter` is a deprecated shim over it.

Faithful details reproduced from the paper (unchanged from the seed):
  * the application starts in ADAPTIVE (the Aries default);
  * for alltoall call sites, "default" means INCREASINGLY MINIMAL BIAS
    (ADAPTIVE_1), matching MPICH_GNI_A2A_ROUTING_MODE;
  * decision rule Eq. (4):  switch to HIGH BIAS iff
        f < (L_ad - L_bs)/(s_bs - s_ad) * (p+512)/1024
    and the dual inequality to switch back;
  * (L, s) for the *other* mode are estimated by scaling factors λ, σ when
    the stored sample is older than `max_sample_age` selector invocations;
  * a cumulative-size gate: the decision logic runs only once at least
    `cumulative_threshold_bytes` (4 KiB) of traffic has accumulated since
    the last decision; below the gate, messages are sent with HIGH BIAS
    (small messages are latency-bound and HIGH BIAS has lower latency);
  * counters are read after the send so the decision never delays the
    message (the policy is strictly one message behind, as in the paper).

New relative to the seed:
  * per-call-site state (`SiteState`) — one Algorithm-1 automaton per
    (call-site) key, batched through a single `AppAwarePolicy.decide`;
  * gate-forced traffic is ledgered separately from decision-routed
    traffic, so `traffic_fraction(mode, include_gated=False)` matches
    Fig. 8/9's '% sent via Default' semantics (gated small messages are
    physically HIGH BIAS but are not mode_b *decisions*);
  * two batching granularities: "message" replays the legacy per-message
    protocol row by row (used by the shim and the equivalence tests);
    "phase" runs one decision per (site, kind) group using the group's
    max message size — exactly what the benchmark runner did per phase —
    so a simulator step with thousands of flows costs one automaton step
    and pure NumPy fills.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable, Optional

import numpy as np

from repro.core.perf_model import flits_and_packets, transmission_cycles_eq2
from repro.core.strategies import ModePerformance
from repro.core.strategies import RoutingMode
from repro.policy.types import (DecisionBatch, Feedback, KIND_ALLTOALL,
                                TrafficLedger)


@dataclass(frozen=True)
class AppAwareConfig:
    """Configuration of Algorithm 1 (the seed's RouterConfig, renamed)."""

    mode_a: Hashable = RoutingMode.ADAPTIVE_0      # "Default"/spread schedule
    mode_b: Hashable = RoutingMode.ADAPTIVE_3      # high-bias/minimal schedule
    #: default mode_a replacement for alltoall call sites (paper §4.2 end).
    mode_a_alltoall: Hashable = RoutingMode.ADAPTIVE_1
    cumulative_threshold_bytes: int = 4 * 1024      # experimentally 4 KiB
    max_sample_age: int = 16                        # "too old" horizon
    #: λ, σ — scaling factors mapping mode_a's (L, s) to a mode_b estimate;
    #: medians over microbenchmark sweeps (core/calibration.py).
    lambda_latency: float = 0.8
    sigma_stalls: float = 1.6
    is_put: bool = True


@dataclass
class SiteState:
    """One Algorithm-1 automaton: the per-call-site selection state."""

    config: AppAwareConfig = field(default_factory=AppAwareConfig)
    current: Hashable = None
    samples: dict = field(default_factory=dict)  # mode -> ModePerformance
    cumulative_bytes: int = 0
    ledger: TrafficLedger = field(default_factory=TrafficLedger)
    decisions: int = 0
    _pending_mode: Optional[Hashable] = None

    def __post_init__(self) -> None:
        if self.current is None:
            self.current = self.config.mode_a  # start ADAPTIVE (paper §4.2)

    # ----------------------------------------------------------------- select
    def select(self, msg_size_bytes: int, *, alltoall: bool = False
               ) -> Hashable:
        """selectRouting(msgSize) — Algorithm 1, one message."""
        cfg = self.config
        mode_a = cfg.mode_a_alltoall if alltoall else cfg.mode_a
        self.cumulative_bytes += msg_size_bytes

        gated = self.cumulative_bytes < cfg.cumulative_threshold_bytes
        if gated:
            # Below the gate: latency-bound regime, always minimal-biased.
            chosen = cfg.mode_b
        else:
            self.cumulative_bytes = 0
            self.decisions += 1
            chosen = self._decide(msg_size_bytes, mode_a)
            self.current = chosen

        self._pending_mode = chosen
        self.ledger.add(chosen, msg_size_bytes, gated=gated)
        return chosen

    def _decide(self, msg_size_bytes: int, mode_a: Hashable) -> Hashable:
        cfg = self.config
        f, p = flits_and_packets(msg_size_bytes, cfg.is_put)

        if self.current == cfg.mode_b:
            # Dual branch: currently HIGH BIAS, maybe switch back to mode_a.
            perf_b = self.samples.get(cfg.mode_b)
            if perf_b is None:
                return cfg.mode_b  # nothing observed yet, keep going
            perf_a = self._estimate_other(
                perf_b, 1.0 / max(cfg.lambda_latency, 1e-9),
                1.0 / max(cfg.sigma_stalls, 1e-9), mode_a)
        else:
            # Currently mode_a (ADAPTIVE / INCR-MINIMAL for alltoall).
            perf_a = self.samples.get(self.current) \
                or self.samples.get(mode_a)
            if perf_a is None:
                return mode_a
            perf_b = self._estimate_other(
                perf_a, cfg.lambda_latency, cfg.sigma_stalls, cfg.mode_b)
        # Eq.(3): compare the Eq.(2) predictions directly (Eq.(4)'s flit
        # threshold is the rearrangement, valid only for s_b > s_a — the
        # direct form is equivalent there and correct in the corners).
        t_a = transmission_cycles_eq2(
            perf_a.latency_cycles, perf_a.stall_cycles_per_flit, f, p)
        t_b = transmission_cycles_eq2(
            perf_b.latency_cycles, perf_b.stall_cycles_per_flit, f, p)
        return cfg.mode_b if t_b < t_a else mode_a

    def _estimate_other(self, known: ModePerformance, lam: float, sig: float,
                        other_mode: Hashable) -> ModePerformance:
        """Return the stored sample for `other_mode` unless it is too old,
        in which case scale the known mode's sample by (λ, σ) — paper §4.2."""
        stored = self.samples.get(other_mode)
        if stored is not None and stored.age <= self.config.max_sample_age:
            return stored
        return ModePerformance(
            latency_cycles=known.latency_cycles * lam,
            stall_cycles_per_flit=known.stall_cycles_per_flit * sig,
        )

    # ---------------------------------------------------------------- observe
    def observe(self, latency_cycles: float, stalls_per_flit: float) -> None:
        """Feed back the NIC counters measured for the last-sent message.
        Called *after* the send (paper: 'Counters are read after sending the
        message to not introduce delays in the transmission')."""
        if self._pending_mode is None:
            return
        self.observe_for_mode(self._pending_mode, latency_cycles,
                              stalls_per_flit)
        self._pending_mode = None

    def observe_for_mode(self, mode: Hashable, latency_cycles: float,
                         stalls_per_flit: float) -> None:
        """observe() with an explicit mode — used by the batched policy,
        where several decisions may be pending at once."""
        # Age every stored sample, then refresh the used mode's slot.
        self.samples = {m: perf.aged() for m, perf in self.samples.items()}
        self.samples[mode] = ModePerformance(
            latency_cycles, stalls_per_flit, age=0)

    # ------------------------------------------------------------------ stats
    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        return self.ledger.traffic_fraction(mode,
                                            include_gated=include_gated)


class AppAwarePolicy:
    """Algorithm 1 as a batched, multi-call-site Policy.

    granularity:
      * "phase"  — one automaton step per (site, kind) group per decide();
        the group's max message size drives the gate/decision, all rows
        get the group's mode (the paper's per-phase protocol; what the
        benchmark runner always did).  No per-row Python work.
      * "message" — row-by-row replay of the legacy per-message protocol;
        decision-for-decision identical to the seed AppAwareRouter.
    """

    def __init__(self, config: AppAwareConfig | None = None, *,
                 granularity: str = "phase"):
        if granularity not in ("phase", "message"):
            raise ValueError(f"unknown granularity: {granularity!r}")
        self.config = config or AppAwareConfig()
        self.granularity = granularity
        self._sites: dict = {}
        #: per-row gate mask of the last decide() (engine ledger input)
        self.last_gated: np.ndarray | None = None
        self._pending: list = []   # [(SiteState, rows, modes_of_rows)]

    # ------------------------------------------------------------------ sites
    def site(self, key: Hashable = "default") -> SiteState:
        st = self._sites.get(key)
        if st is None:
            st = self._sites[key] = SiteState(self.config)
        return st

    # ----------------------------------------------------------------- decide
    def decide(self, batch: DecisionBatch) -> np.ndarray:
        n = len(batch)
        modes = np.empty(n, dtype=object)
        gated = np.zeros(n, dtype=bool)
        pending = []
        for site_key, kind, rows in batch.groups():
            st = self.site(site_key)
            a2a = kind == KIND_ALLTOALL
            if self.granularity == "phase":
                before = st.cumulative_bytes
                msg = float(batch.msg_bytes[rows].max())
                mode = st.select(int(msg), alltoall=a2a)
                modes[rows] = mode
                was_gated = before + msg \
                    < self.config.cumulative_threshold_bytes
                gated[rows] = was_gated
                # select() ledgered only the gate-driving max message;
                # account the rest of the group's bytes too so the site
                # ledger matches the engine's traffic truth
                rest = float(batch.msg_bytes[rows].sum()) - msg
                if rest > 0:
                    st.ledger.add(mode, rest, gated=was_gated)
                row_modes = np.full(len(rows), mode, dtype=object)
            else:
                row_modes = np.empty(len(rows), dtype=object)
                for j, i in enumerate(rows):
                    before = st.cumulative_bytes
                    size = int(batch.msg_bytes[i])
                    row_modes[j] = modes[i] = st.select(size, alltoall=a2a)
                    gated[i] = before + size \
                        < self.config.cumulative_threshold_bytes
            pending.append((st, rows, row_modes))
        self.last_gated = gated
        self._pending = pending
        return modes

    # ----------------------------------------------------------------- update
    def update(self, batch: DecisionBatch, feedback: Feedback) -> None:
        """Feed (L, s) back for the rows of the last decide().

        In "phase" granularity each group collapses to one weighted-mean
        sample (the runner's per-phase mean-counter observation); in
        "message" granularity every row refreshes its own mode's slot in
        row order, replaying the legacy select/observe interleave."""
        if not self._pending:
            return
        if len(feedback) != len(batch):
            raise ValueError("feedback rows must match the decided batch")
        lat, st_, w = (feedback.latency_cycles, feedback.stalls_per_flit,
                       feedback.weight)
        for site_state, rows, row_modes in self._pending:
            if self.granularity == "phase":
                wr = w[rows]
                tot = float(wr.sum()) or 1.0
                site_state.observe_for_mode(
                    row_modes[0],
                    float((lat[rows] * wr).sum() / tot),
                    float((st_[rows] * wr).sum() / tot))
                site_state._pending_mode = None
            else:
                for j, i in enumerate(rows):
                    site_state.observe_for_mode(row_modes[j],
                                                float(lat[i]), float(st_[i]))
                    site_state._pending_mode = None
        self._pending = []

    # ------------------------------------------------------------------ stats
    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        """Aggregated over all call sites."""
        merged = TrafficLedger()
        for st in self._sites.values():
            for m, b in st.ledger.sent.items():
                merged.sent[m] = merged.sent.get(m, 0.0) + b
            for m, b in st.ledger.gated.items():
                merged.gated[m] = merged.gated.get(m, 0.0) + b
            for m, b in st.ledger.decided.items():
                merged.decided[m] = merged.decided.get(m, 0.0) + b
        return merged.traffic_fraction(mode, include_gated=include_gated)
