"""Algorithm 1 — application-aware selection (paper §4.2/§4.3) as a Policy.

This is the canonical home of the paper's algorithm; the legacy
`repro.core.app_aware.AppAwareRouter` is a deprecated shim over it.

Faithful details reproduced from the paper (unchanged from the seed):
  * the application starts in ADAPTIVE (the Aries default);
  * for alltoall call sites, "default" means INCREASINGLY MINIMAL BIAS
    (ADAPTIVE_1), matching MPICH_GNI_A2A_ROUTING_MODE;
  * decision rule Eq. (4):  switch to HIGH BIAS iff
        f < (L_ad - L_bs)/(s_bs - s_ad) * (p+512)/1024
    and the dual inequality to switch back;
  * (L, s) for the *other* mode are estimated by scaling factors λ, σ when
    the stored sample is older than `max_sample_age` selector invocations;
  * a cumulative-size gate: the decision logic runs only once at least
    `cumulative_threshold_bytes` (4 KiB) of traffic has accumulated since
    the last decision; below the gate, messages are sent with HIGH BIAS
    (small messages are latency-bound and HIGH BIAS has lower latency);
  * counters are read after the send so the decision never delays the
    message (the policy is strictly one message behind, as in the paper).

New relative to the seed:
  * per-call-site state (`SiteState`) — one Algorithm-1 automaton per
    (call-site) key, batched through a single `AppAwarePolicy.decide`;
  * gate-forced traffic is ledgered separately from decision-routed
    traffic, so `traffic_fraction(mode, include_gated=False)` matches
    Fig. 8/9's '% sent via Default' semantics (gated small messages are
    physically HIGH BIAS but are not mode_b *decisions*);
  * two batching granularities: "message" replays the legacy per-message
    protocol row by row (used by the shim and the equivalence tests);
    "phase" runs one decision per (site, kind) group using the group's
    max message size — exactly what the benchmark runner did per phase —
    so a simulator step with thousands of flows costs one automaton step
    and pure NumPy fills.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable, Optional

import numpy as np

from repro.core.perf_model import (flits_and_packets, flits_and_packets_vec,
                                   transmission_cycles_eq2)
from repro.core.strategies import ModePerformance
from repro.core.strategies import RoutingMode
from repro.policy.types import (DecisionBatch, Feedback, KIND_ALLTOALL,
                                TrafficLedger)


@dataclass(frozen=True)
class AppAwareConfig:
    """Configuration of Algorithm 1 (the seed's RouterConfig, renamed)."""

    mode_a: Hashable = RoutingMode.ADAPTIVE_0      # "Default"/spread schedule
    mode_b: Hashable = RoutingMode.ADAPTIVE_3      # high-bias/minimal schedule
    #: default mode_a replacement for alltoall call sites (paper §4.2 end).
    mode_a_alltoall: Hashable = RoutingMode.ADAPTIVE_1
    cumulative_threshold_bytes: int = 4 * 1024      # experimentally 4 KiB
    max_sample_age: int = 16                        # "too old" horizon
    #: λ, σ — scaling factors mapping mode_a's (L, s) to a mode_b estimate;
    #: medians over microbenchmark sweeps (core/calibration.py).
    lambda_latency: float = 0.8
    sigma_stalls: float = 1.6
    is_put: bool = True


@dataclass
class SiteState:
    """One Algorithm-1 automaton: the per-call-site selection state."""

    config: AppAwareConfig = field(default_factory=AppAwareConfig)
    current: Hashable = None
    samples: dict = field(default_factory=dict)  # mode -> ModePerformance
    cumulative_bytes: int = 0
    ledger: TrafficLedger = field(default_factory=TrafficLedger)
    decisions: int = 0
    _pending_mode: Optional[Hashable] = None

    def __post_init__(self) -> None:
        if self.current is None:
            self.current = self.config.mode_a  # start ADAPTIVE (paper §4.2)

    # ----------------------------------------------------------------- select
    def select(self, msg_size_bytes: int, *, alltoall: bool = False
               ) -> Hashable:
        """selectRouting(msgSize) — Algorithm 1, one message."""
        cfg = self.config
        mode_a = cfg.mode_a_alltoall if alltoall else cfg.mode_a
        self.cumulative_bytes += msg_size_bytes

        gated = self.cumulative_bytes < cfg.cumulative_threshold_bytes
        if gated:
            # Below the gate: latency-bound regime, always minimal-biased.
            chosen = cfg.mode_b
        else:
            self.cumulative_bytes = 0
            self.decisions += 1
            chosen = self._decide(msg_size_bytes, mode_a)
            self.current = chosen

        self._pending_mode = chosen
        self.ledger.add(chosen, msg_size_bytes, gated=gated)
        return chosen

    def _decide(self, msg_size_bytes: int, mode_a: Hashable) -> Hashable:
        cfg = self.config
        f, p = flits_and_packets(msg_size_bytes, cfg.is_put)

        if self.current == cfg.mode_b:
            # Dual branch: currently HIGH BIAS, maybe switch back to mode_a.
            perf_b = self.samples.get(cfg.mode_b)
            if perf_b is None:
                return cfg.mode_b  # nothing observed yet, keep going
            perf_a = self._estimate_other(
                perf_b, 1.0 / max(cfg.lambda_latency, 1e-9),
                1.0 / max(cfg.sigma_stalls, 1e-9), mode_a)
        else:
            # Currently mode_a (ADAPTIVE / INCR-MINIMAL for alltoall).
            perf_a = self.samples.get(self.current) \
                or self.samples.get(mode_a)
            if perf_a is None:
                return mode_a
            perf_b = self._estimate_other(
                perf_a, cfg.lambda_latency, cfg.sigma_stalls, cfg.mode_b)
        # Eq.(3): compare the Eq.(2) predictions directly (Eq.(4)'s flit
        # threshold is the rearrangement, valid only for s_b > s_a — the
        # direct form is equivalent there and correct in the corners).
        t_a = transmission_cycles_eq2(
            perf_a.latency_cycles, perf_a.stall_cycles_per_flit, f, p)
        t_b = transmission_cycles_eq2(
            perf_b.latency_cycles, perf_b.stall_cycles_per_flit, f, p)
        return cfg.mode_b if t_b < t_a else mode_a

    def _estimate_other(self, known: ModePerformance, lam: float, sig: float,
                        other_mode: Hashable) -> ModePerformance:
        """Return the stored sample for `other_mode` unless it is too old,
        in which case scale the known mode's sample by (λ, σ) — paper §4.2."""
        stored = self.samples.get(other_mode)
        if stored is not None and stored.age <= self.config.max_sample_age:
            return stored
        return ModePerformance(
            latency_cycles=known.latency_cycles * lam,
            stall_cycles_per_flit=known.stall_cycles_per_flit * sig,
        )

    # ---------------------------------------------------------------- observe
    def observe(self, latency_cycles: float, stalls_per_flit: float) -> None:
        """Feed back the NIC counters measured for the last-sent message.
        Called *after* the send (paper: 'Counters are read after sending the
        message to not introduce delays in the transmission')."""
        if self._pending_mode is None:
            return
        self.observe_for_mode(self._pending_mode, latency_cycles,
                              stalls_per_flit)
        self._pending_mode = None

    def observe_for_mode(self, mode: Hashable, latency_cycles: float,
                         stalls_per_flit: float) -> None:
        """observe() with an explicit mode — used by the batched policy,
        where several decisions may be pending at once."""
        # Age every stored sample, then refresh the used mode's slot.
        self.samples = {m: perf.aged() for m, perf in self.samples.items()}
        self.samples[mode] = ModePerformance(
            latency_cycles, stalls_per_flit, age=0)

    # ------------------------------------------------------------------ stats
    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        return self.ledger.traffic_fraction(mode,
                                            include_gated=include_gated)


class _SiteTable:
    """Array-of-structs state for every call site of one policy.

    One row per site; one column per registered mode.  Replaces the
    per-site Python automaton objects on the "phase" path so a decide()
    touching many sites (per-flow sites, per-destination automata) is a
    handful of NumPy ops over [G]-shaped gathers instead of a Python
    loop of Algorithm-1 steps (ROADMAP: vectorize AppAwarePolicy across
    sites).  Sample slots use age == -1 as the "never observed" mark.
    """

    def __init__(self, config: AppAwareConfig):
        self.config = config
        self.keys: dict = {}            # site key -> row
        self.mode_of: list = []         # code -> mode object
        self.code_of: dict = {}         # mode object -> code
        n0, m0 = 0, 0
        self.cum = np.zeros(n0, dtype=np.int64)
        self.current = np.zeros(n0, dtype=np.int64)
        self.decisions = np.zeros(n0, dtype=np.int64)
        self.lat = np.zeros((n0, m0))
        self.stall = np.zeros((n0, m0))
        self.age = np.full((n0, m0), -1, dtype=np.int64)
        self.ledgers: list = []         # row -> TrafficLedger
        # pre-register the config's modes so hot decide()s never grow
        for m in (config.mode_a, config.mode_b, config.mode_a_alltoall):
            self.mode_code(m)

    # ------------------------------------------------------------ registry
    def mode_code(self, mode: Hashable) -> int:
        code = self.code_of.get(mode)
        if code is None:
            code = self.code_of[mode] = len(self.mode_of)
            self.mode_of.append(mode)
            grow = np.zeros((self.lat.shape[0], 1))
            self.lat = np.concatenate([self.lat, grow], axis=1)
            self.stall = np.concatenate([self.stall, grow], axis=1)
            self.age = np.concatenate(
                [self.age, np.full((self.age.shape[0], 1), -1,
                                   dtype=np.int64)], axis=1)
        return code

    def row(self, key: Hashable) -> int:
        r = self.keys.get(key)
        if r is None:
            r = self.keys[key] = len(self.ledgers)
            m = len(self.mode_of)
            self.cum = np.append(self.cum, 0)
            self.current = np.append(
                self.current, self.code_of[self.config.mode_a])
            self.decisions = np.append(self.decisions, 0)
            self.lat = np.concatenate([self.lat, np.zeros((1, m))])
            self.stall = np.concatenate([self.stall, np.zeros((1, m))])
            self.age = np.concatenate(
                [self.age, np.full((1, m), -1, dtype=np.int64)])
            self.ledgers.append(TrafficLedger())
        return r

    # ------------------------------------------------------ vectorized step
    def select_groups(self, rows_s: np.ndarray, msg_int: np.ndarray,
                      a2a: np.ndarray):
        """One Algorithm-1 step for G groups at once (unique site rows).

        Returns (chosen codes [G], gated [G]) and mutates the table the
        way G sequential SiteState.select() calls would."""
        cfg = self.config
        code_b = self.code_of[cfg.mode_b]
        code_a = np.where(a2a, self.code_of[cfg.mode_a_alltoall],
                          self.code_of[cfg.mode_a])
        self.cum[rows_s] += msg_int
        gated = self.cum[rows_s] < cfg.cumulative_threshold_bytes
        chosen = np.full(len(rows_s), code_b, dtype=np.int64)
        dec = ~gated
        if dec.any():
            s = rows_s[dec]
            self.cum[s] = 0
            self.decisions[s] += 1
            chosen[dec] = self._decide_vec(s, msg_int[dec], code_a[dec])
            self.current[s] = chosen[dec]
        return chosen, gated

    def _decide_vec(self, s: np.ndarray, msg_int: np.ndarray,
                    code_a: np.ndarray) -> np.ndarray:
        """Vectorized SiteState._decide: Eq.(3) over the Eq.(2) model,
        with the λ/σ-scaled estimate replacing too-old samples."""
        cfg = self.config
        code_b = self.code_of[cfg.mode_b]
        f, pk = flits_and_packets_vec(msg_int, cfg.is_put)
        cur = self.current[s]
        is_b = cur == code_b
        # the known side: mode_b's sample when currently B, else the
        # current mode's sample (falling back to mode_a's slot)
        cur_has = self.age[s, cur] >= 0
        known_code = np.where(is_b, code_b,
                              np.where(cur_has, cur, code_a))
        known_lat = self.lat[s, known_code]
        known_stall = self.stall[s, known_code]
        known_has = self.age[s, known_code] >= 0
        # the other side: stored sample unless too old, else λ/σ scaling
        other_code = np.where(is_b, code_a, code_b)
        lam = np.where(is_b, 1.0 / max(cfg.lambda_latency, 1e-9),
                       cfg.lambda_latency)
        sig = np.where(is_b, 1.0 / max(cfg.sigma_stalls, 1e-9),
                       cfg.sigma_stalls)
        o_age = self.age[s, other_code]
        use_stored = (o_age >= 0) & (o_age <= cfg.max_sample_age)
        est_lat = np.where(use_stored, self.lat[s, other_code],
                           known_lat * lam)
        est_stall = np.where(use_stored, self.stall[s, other_code],
                             known_stall * sig)
        t_known = transmission_cycles_eq2(known_lat, known_stall, f, pk)
        t_other = transmission_cycles_eq2(est_lat, est_stall, f, pk)
        t_a = np.where(is_b, t_other, t_known)
        t_b = np.where(is_b, t_known, t_other)
        decided = np.where(t_b < t_a, code_b, code_a)
        # nothing observed yet: keep going in the current regime
        return np.where(known_has, decided,
                        np.where(is_b, code_b, code_a))

    def observe_groups(self, rows_s: np.ndarray, codes: np.ndarray,
                       lat: np.ndarray, stall: np.ndarray) -> None:
        """Vectorized observe_for_mode over unique site rows: age every
        stored sample, then refresh the observed slots."""
        self.age[rows_s] += self.age[rows_s] >= 0
        self.lat[rows_s, codes] = lat
        self.stall[rows_s, codes] = stall
        self.age[rows_s, codes] = 0


class _SiteView:
    """SiteState-shaped read view over one _SiteTable row (so callers
    and tests can keep poking `site(...).current/.samples/...` on the
    vectorized "phase" path)."""

    def __init__(self, table: _SiteTable, row: int):
        self._table = table
        self._row = row

    config = property(lambda self: self._table.config)
    decisions = property(lambda self: int(self._table.decisions[self._row]))
    cumulative_bytes = property(lambda self: int(self._table.cum[self._row]))
    current = property(
        lambda self: self._table.mode_of[self._table.current[self._row]])
    ledger = property(lambda self: self._table.ledgers[self._row])

    @property
    def samples(self) -> dict:
        t, r = self._table, self._row
        return {t.mode_of[c]: ModePerformance(t.lat[r, c], t.stall[r, c],
                                              age=int(t.age[r, c]))
                for c in range(len(t.mode_of)) if t.age[r, c] >= 0}

    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        return self.ledger.traffic_fraction(mode,
                                            include_gated=include_gated)


def _waves(rows: np.ndarray):
    """Split group indices into passes with unique site rows, preserving
    order — duplicate sites in one batch step sequentially (the rare
    same-site-two-kinds case), everyone else in one vectorized pass."""
    order: dict = {}
    wave_of = np.empty(len(rows), dtype=np.int64)
    for i, r in enumerate(rows):
        k = order.get(r, 0)
        order[r] = k + 1
        wave_of[i] = k
    for wv in range(int(wave_of.max()) + 1 if len(rows) else 0):
        yield np.flatnonzero(wave_of == wv)


class AppAwarePolicy:
    """Algorithm 1 as a batched, multi-call-site Policy.

    granularity:
      * "phase"  — one automaton step per (site, kind) group per decide();
        the group's max message size drives the gate/decision, all rows
        get the group's mode (the paper's per-phase protocol; what the
        benchmark runner always did).  Site state is array-of-structs
        (`_SiteTable`): the gate, Eq.(3) decision and sample updates run
        vectorized across all groups of the batch.
      * "message" — row-by-row replay of the legacy per-message protocol
        over `SiteState` automatons; decision-for-decision identical to
        the seed AppAwareRouter.
    """

    def __init__(self, config: AppAwareConfig | None = None, *,
                 granularity: str = "phase"):
        if granularity not in ("phase", "message"):
            raise ValueError(f"unknown granularity: {granularity!r}")
        self.config = config or AppAwareConfig()
        self.granularity = granularity
        self._sites: dict = {}          # "message" path: key -> SiteState
        self._table = _SiteTable(self.config)   # "phase" path state
        #: per-row gate mask of the last decide() (engine ledger input)
        self.last_gated: np.ndarray | None = None
        self._pending: list = []   # [(site row/state, rows, modes_of_rows)]

    # ------------------------------------------------------------------ sites
    def site(self, key: Hashable = "default"):
        if self.granularity == "phase":
            return _SiteView(self._table, self._table.row(key))
        st = self._sites.get(key)
        if st is None:
            st = self._sites[key] = SiteState(self.config)
        return st

    # ----------------------------------------------------------------- decide
    def decide(self, batch: DecisionBatch) -> np.ndarray:
        if self.granularity == "phase":
            return self._decide_phase(batch)
        n = len(batch)
        modes = np.empty(n, dtype=object)
        gated = np.zeros(n, dtype=bool)
        pending = []
        for site_key, kind, rows in batch.groups():
            st = self.site(site_key)
            a2a = kind == KIND_ALLTOALL
            row_modes = np.empty(len(rows), dtype=object)
            for j, i in enumerate(rows):
                before = st.cumulative_bytes
                size = int(batch.msg_bytes[i])
                row_modes[j] = modes[i] = st.select(size, alltoall=a2a)
                gated[i] = before + size \
                    < self.config.cumulative_threshold_bytes
            pending.append((st, rows, row_modes))
        self.last_gated = gated
        self._pending = pending
        return modes

    def _decide_phase(self, batch: DecisionBatch) -> np.ndarray:
        n = len(batch)
        tbl = self._table
        groups = list(batch.groups())
        rows_s = np.array([tbl.row(k) for k, _, _ in groups],
                          dtype=np.int64)
        msgs = np.array([float(batch.msg_bytes[rows].max())
                         for _, _, rows in groups])
        sums = np.array([float(batch.msg_bytes[rows].sum())
                         for _, _, rows in groups])
        a2a = np.array([kind == KIND_ALLTOALL for _, kind, _ in groups])
        before = np.empty(len(groups))   # pre-step cum, filled per wave
        chosen = np.empty(len(groups), dtype=np.int64)
        gated_grp = np.empty(len(groups), dtype=bool)
        for wv in _waves(rows_s):
            # wave rows are unique -> the gate/decision math vectorizes;
            # `before` must still see earlier waves' mutations
            before[wv] = tbl.cum[rows_s[wv]]
            chosen[wv], gated_grp[wv] = tbl.select_groups(
                rows_s[wv], msgs[wv].astype(np.int64), a2a[wv])
        # Fig.8/9 gate semantics for the engine ledger: float comparison
        # over the pre-step cumulative counter (legacy behaviour)
        was_gated = before + msgs < self.config.cumulative_threshold_bytes
        modes = np.empty(n, dtype=object)
        gated = np.zeros(n, dtype=bool)
        pending = []
        for gi, (_, _, rows) in enumerate(groups):
            mode = tbl.mode_of[chosen[gi]]
            modes[rows] = mode
            gated[rows] = was_gated[gi]
            # the gate-driving max message is ledgered like select() did;
            # the rest of the group's bytes ride along so the site ledger
            # matches the engine's traffic truth
            led = tbl.ledgers[rows_s[gi]]
            led.add(mode, int(msgs[gi]), gated=bool(gated_grp[gi]))
            rest = sums[gi] - msgs[gi]
            if rest > 0:
                led.add(mode, rest, gated=bool(was_gated[gi]))
            pending.append((rows_s[gi], rows, mode))
        self.last_gated = gated
        self._pending = pending
        return modes

    # ----------------------------------------------------------------- update
    def update(self, batch: DecisionBatch, feedback: Feedback) -> None:
        """Feed (L, s) back for the rows of the last decide().

        In "phase" granularity each group collapses to one weighted-mean
        sample (the runner's per-phase mean-counter observation) and the
        sample-table refresh runs vectorized across groups; in "message"
        granularity every row refreshes its own mode's slot in row
        order, replaying the legacy select/observe interleave."""
        if not self._pending:
            return
        if len(feedback) != len(batch):
            raise ValueError("feedback rows must match the decided batch")
        lat, st_, w = (feedback.latency_cycles, feedback.stalls_per_flit,
                       feedback.weight)
        if self.granularity == "phase":
            tbl = self._table
            rows_s = np.array([site for site, _, _ in self._pending],
                              dtype=np.int64)
            codes = np.array([tbl.code_of[mode]
                              for _, _, mode in self._pending],
                             dtype=np.int64)
            lat_g = np.empty(len(self._pending))
            stall_g = np.empty(len(self._pending))
            for gi, (_, rows, _) in enumerate(self._pending):
                wr = w[rows]
                tot = float(wr.sum()) or 1.0
                lat_g[gi] = float((lat[rows] * wr).sum() / tot)
                stall_g[gi] = float((st_[rows] * wr).sum() / tot)
            for wv in _waves(rows_s):
                tbl.observe_groups(rows_s[wv], codes[wv], lat_g[wv],
                                   stall_g[wv])
            self._pending = []
            return
        for site_state, rows, row_modes in self._pending:
            for j, i in enumerate(rows):
                site_state.observe_for_mode(row_modes[j],
                                            float(lat[i]), float(st_[i]))
                site_state._pending_mode = None
        self._pending = []

    # ------------------------------------------------------------------ stats
    def site_keys(self) -> list:
        """Every call-site key this policy has seen (table row order)."""
        if self.granularity == "phase":
            return list(self._table.keys)
        return list(self._sites)

    def reset_samples(self, site_filter=None) -> int:
        """Forget latency/stall samples for the matching sites.

        Fault-epoch hook (docs/faults.md): when the machine's link set
        changes, est_memory-driven samples gathered BEFORE the epoch
        describe paths that may no longer exist — Algorithm 1 would keep
        regime-switching on contaminated evidence.  Dropping the samples
        (ages back to "never observed") makes the automaton re-measure
        both arms from scratch; the current regime and traffic ledgers
        are decisions, not measurements, and are kept.  `site_filter`
        (key -> bool, e.g. ``scoped_site_filter(tenant)``) restricts the
        reset to the affected sites; None resets every site.  Returns
        the number of sites reset."""
        n = 0
        if self.granularity == "phase":
            for key, row in self._table.keys.items():
                if site_filter is None or site_filter(key):
                    self._table.age[row, :] = -1
                    n += 1
        else:
            for key, st in self._sites.items():
                if site_filter is None or site_filter(key):
                    st.samples = {}
                    st._pending_mode = None
                    n += 1
        return n

    def _ledgers(self, site_filter=None) -> list:
        keyed = self._table.keys.items() if self.granularity == "phase" \
            else {k: st for k, st in self._sites.items()}.items()
        out = []
        for key, v in keyed:
            if site_filter is not None and not site_filter(key):
                continue
            out.append(self._table.ledgers[v]
                       if self.granularity == "phase" else v.ledger)
        return out

    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True,
                         site_filter=None) -> float:
        """Traffic fraction aggregated over call sites.

        `site_filter` (optional, key -> bool) slices the aggregate to a
        subset of sites — the _SiteTable slicing used by the tenancy
        engine, whose shared-engine mode namespaces every site key as
        ``(tenant_name, site)`` in ONE array-of-structs table and reads
        per-tenant fractions back out with
        ``site_filter=scoped_site_filter(tenant_name)``."""
        merged = TrafficLedger()
        for led in self._ledgers(site_filter):
            for m, b in led.sent.items():
                merged.sent[m] = merged.sent.get(m, 0.0) + b
            for m, b in led.gated.items():
                merged.gated[m] = merged.gated.get(m, 0.0) + b
            for m, b in led.decided.items():
                merged.decided[m] = merged.decided.get(m, 0.0) + b
        return merged.traffic_fraction(mode, include_gated=include_gated)


def scoped_site_filter(scope: Hashable):
    """site_filter matching keys namespaced as ``(scope, ...)`` tuples
    (and the bare ``scope`` key itself)."""
    def _match(key) -> bool:
        return key == scope or (isinstance(key, tuple) and len(key) >= 1
                                and key[0] == scope)
    return _match
