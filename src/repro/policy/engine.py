"""PolicyEngine — the single entry point for mode selection everywhere.

One engine call per simulator step / benchmark batch:

    engine = PolicyEngine(AppAwarePolicy(AppAwareConfig()))
    modes = engine.decide(DecisionBatch.of(bytes_array, site="a2a",
                                           kind=KIND_ALLTOALL))
    ... send ...
    engine.bus.publish_flow_arrays(latency_us, stalls_per_flit)  # -> update

The engine owns: the Policy, the TelemetryBus (subscribed so published
feedback flows straight into Policy.update for the last-decided batch),
and a TrafficLedger for Fig. 8/9-style traffic-fraction reporting.
"""

from __future__ import annotations

from typing import Hashable

import numpy as np

from repro.core.strategies import RoutingMode
from repro.policy.app_aware import AppAwareConfig, AppAwarePolicy
from repro.policy.notification import NotificationConfig, NotificationPolicy
from repro.policy.policies import EpsilonGreedyPolicy, StaticPolicy
from repro.policy.telemetry import TelemetryBus
from repro.policy.types import (DecisionBatch, Feedback, Policy,
                                TrafficLedger)

POLICY_NAMES = ("static", "app_aware", "eps_greedy", "notification")


class PolicyEngine:
    """Vectorized decision front-end over a pluggable Policy.

    Bounded-staleness guard (docs/faults.md): an adaptive policy steered
    by telemetry that stopped arriving (NIC-counter dropout, a crashed
    collector) is worse than no policy — it keeps acting on a frozen,
    possibly fault-contaminated estimate.  With ``staleness_limit=k``
    the engine counts decide() calls since the last feedback delivery;
    at >= k it stops consulting the policy and emits ``fallback_mode``
    (default minimal / ADAPTIVE_3, the paper's safe static arm) until
    telemetry resumes, which instantly restores the policy path.
    ``staleness_limit=None`` (default) disables the guard.
    """

    def __init__(self, policy: Policy, bus: TelemetryBus | None = None, *,
                 staleness_limit: int | None = None,
                 fallback_mode=None):
        self.policy = policy
        self.bus = bus if bus is not None else TelemetryBus()
        self.bus.subscribe(self._on_feedback)
        self.ledger = TrafficLedger()
        self.decide_calls = 0
        self.rows_decided = 0
        self._last_batch: DecisionBatch | None = None
        self.last_modes: np.ndarray | None = None
        self.staleness_limit = staleness_limit
        self.fallback_mode = (fallback_mode if fallback_mode is not None
                              else RoutingMode.ADAPTIVE_3)
        self.decides_since_feedback = 0
        self.fallback_decides = 0

    @property
    def degraded(self) -> bool:
        """True while the staleness guard forces fallback decisions."""
        return (self.staleness_limit is not None
                and self.decides_since_feedback >= self.staleness_limit)

    # ----------------------------------------------------------------- decide
    def decide(self, batch: DecisionBatch) -> np.ndarray:
        """One call, [n] decisions.  Returns an object array of modes."""
        if self.degraded:
            # stale telemetry: bypass the policy, emit the static
            # fallback arm (policy state stays frozen, not contaminated)
            modes = np.full(len(batch), self.fallback_mode, dtype=object)
            self.fallback_decides += 1
            gated = None
        else:
            modes = self.policy.decide(batch)
            self.decides_since_feedback += 1
            gated = getattr(self.policy, "last_gated", None)
        self.ledger.add_batch(modes, batch.msg_bytes, gated=gated)
        self.decide_calls += 1
        self.rows_decided += len(batch)
        self._last_batch = batch
        self.last_modes = modes
        return modes

    def decide_bytes(self, msg_bytes, *, site: Hashable = "default",
                     kind: str = "pt2pt") -> np.ndarray:
        """Convenience: build the batch and decide in one call."""
        return self.decide(DecisionBatch.of(msg_bytes, site, kind))

    # ----------------------------------------------------------------- update
    def update(self, feedback: Feedback,
               batch: DecisionBatch | None = None) -> None:
        """Feed telemetry back for `batch` (default: the last decide())."""
        b = batch if batch is not None else self._last_batch
        if b is None:
            return
        if len(feedback) == 1 and len(b) > 1:
            # one aggregate sample for the whole batch (counter-window
            # reads): broadcast it over the rows — the notification
            # signal rides along, None stays None (no signal != calm)
            feedback = Feedback.of(
                np.full(len(b), float(feedback.latency_cycles[0])),
                np.full(len(b), float(feedback.stalls_per_flit[0])),
                source=feedback.source,
                notified=None if feedback.notified is None
                else np.full(len(b), float(feedback.notified[0])))
        self.policy.update(b, feedback)

    def _on_feedback(self, feedback: Feedback) -> None:
        # telemetry arrived: the staleness clock restarts (recovering
        # from a degraded stretch the moment counters resume)
        self.decides_since_feedback = 0
        self.update(feedback)

    # ------------------------------------------------------------------ faults
    def on_fault_epoch(self, site_filter=None) -> int:
        """Fault-epoch notification (docs/faults.md): the machine's link
        set changed, so latency/stall samples gathered before the epoch
        no longer describe the paths being scored.  Forwards to the
        policy's ``reset_samples`` (AppAware/EpsilonGreedy; static
        policies have no state) for the sites matching ``site_filter``
        (None = all).  Returns the number of sites reset."""
        reset = getattr(self.policy, "reset_samples", None)
        return reset(site_filter) if reset is not None else 0

    # ------------------------------------------------------------------ stats
    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        return self.ledger.traffic_fraction(mode,
                                            include_gated=include_gated)

    def gated_fraction(self) -> float:
        return self.ledger.gated_fraction()


def make_engine(name: str, *,
                mode_a: Hashable = RoutingMode.ADAPTIVE_0,
                mode_b: Hashable = RoutingMode.ADAPTIVE_3,
                mode_a_alltoall: Hashable = None,
                config: AppAwareConfig | None = None,
                granularity: str = "phase",
                epsilon: float = 0.1,
                epsilon_decay: float = 0.15,
                static_mode: Hashable = None,
                seed: int = 0,
                bus: TelemetryBus | None = None,
                staleness_limit: int | None = None,
                fallback_mode: Hashable = None) -> PolicyEngine:
    """Factory mapping CLI names to engines.

    "static"       -> StaticPolicy(static_mode or mode_a)
    "app_aware"    -> AppAwarePolicy (Algorithm 1)
    "eps_greedy"   -> EpsilonGreedyPolicy over (mode_a, mode_b)
    "notification" -> NotificationPolicy: calm regime = mode_b (the
                      minimal arm), congested regime = mode_a (the
                      spreading arm), switched by the congestion-
                      notification signal (docs/policy_api.md)

    ``staleness_limit``/``fallback_mode`` arm the engine's bounded-
    staleness guard (docs/faults.md).
    """
    if mode_a_alltoall is None:
        # default-arm case: alltoall sites use INCR-MINIMAL (paper §4.2),
        # for app_aware AND eps_greedy alike, so the bandit arbitrates the
        # same two arms Algorithm 1 does; custom arms keep mode_a
        mode_a_alltoall = (AppAwareConfig.mode_a_alltoall
                          if mode_a is RoutingMode.ADAPTIVE_0 else mode_a)
    if name == "static":
        policy: Policy = StaticPolicy(
            static_mode if static_mode is not None else mode_a)
    elif name == "app_aware":
        cfg = config or AppAwareConfig(
            mode_a=mode_a, mode_b=mode_b,
            mode_a_alltoall=mode_a_alltoall)
        policy = AppAwarePolicy(cfg, granularity=granularity)
    elif name == "eps_greedy":
        policy = EpsilonGreedyPolicy(
            mode_a=mode_a, mode_b=mode_b,
            mode_a_alltoall=mode_a_alltoall, epsilon=epsilon,
            epsilon_decay=epsilon_decay, seed=seed)
    elif name == "notification":
        policy = NotificationPolicy(NotificationConfig(
            mode_calm=mode_b, mode_congested=mode_a))
    else:
        raise ValueError(
            f"unknown policy {name!r}; expected one of {POLICY_NAMES}")
    return PolicyEngine(policy, bus=bus, staleness_limit=staleness_limit,
                        fallback_mode=fallback_mode)
