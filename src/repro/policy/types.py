"""Core types of the unified policy API.

One vocabulary for every mode-selection decision in the repo:

  * `DecisionBatch` — a NumPy-shaped batch of pending sends: per row a
    message size, a call-site key and a collective kind.  The Dragonfly
    simulator submits one batch per phase (thousands of flows), the
    collective selector submits batches of gradient buckets, launchers
    submit one row per step.
  * `Feedback` — normalized telemetry for a previously-decided batch:
    the paper's (L, s) pair per row, in NIC cycles / stall-cycles-per-
    flit, regardless of whether it came from Aries NIC counters, HLO
    counters or simulator queue estimates (see telemetry.TelemetryBus).
  * `Policy` — the pluggable strategy protocol:
    ``decide(batch) -> modes`` and ``update(batch, feedback)``.

Modes are opaque Hashables (RoutingMode on the Dragonfly substrate,
CollectiveMode on the TPU mesh), exactly like the legacy AppAwareRouter.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable, Protocol, runtime_checkable

import numpy as np

#: Collective-kind labels.  `alltoall` is special-cased by Algorithm 1
#: (the Aries default for alltoall call sites is INCREASINGLY MINIMAL
#: BIAS, paper §4.2); everything else behaves like `pt2pt`.
KIND_PT2PT = "pt2pt"
KIND_ALLTOALL = "alltoall"
KIND_ALLREDUCE = "allreduce"
KIND_BROADCAST = "broadcast"


def _as_object_array(value, n: int) -> np.ndarray:
    """Broadcast a scalar (or pass through an array) to an [n] object array."""
    if isinstance(value, np.ndarray) and value.dtype == object:
        if value.shape != (n,):
            raise ValueError(f"expected shape ({n},), got {value.shape}")
        return value
    out = np.empty(n, dtype=object)
    if np.isscalar(value) or isinstance(value, (str, tuple)) \
            or not hasattr(value, "__len__"):
        out.fill(value)                  # scalar broadcast, no Python list
    else:
        if len(value) != n:
            raise ValueError(f"expected length {n}, got {len(value)}")
        out[:] = list(value)
    return out


@dataclass(frozen=True)
class DecisionBatch:
    """A batch of pending sends awaiting a mode decision.

    msg_bytes: [n] float64 — message sizes in bytes.
    site:      [n] object  — call-site keys; each site carries its own
               policy state (Algorithm 1 is a per-call-site automaton).
    kind:      [n] object  — collective kind labels (KIND_*).
    """

    msg_bytes: np.ndarray
    site: np.ndarray
    kind: np.ndarray

    def __post_init__(self):
        n = self.msg_bytes.shape[0]
        if self.site.shape != (n,) or self.kind.shape != (n,):
            raise ValueError("DecisionBatch fields must share shape [n]")

    @staticmethod
    def of(msg_bytes, site: Hashable = "default",
           kind: str = KIND_PT2PT) -> "DecisionBatch":
        """Build a batch, broadcasting scalar site/kind over the rows."""
        b = np.atleast_1d(np.asarray(msg_bytes, dtype=np.float64))
        n = b.shape[0]
        return DecisionBatch(b, _as_object_array(site, n),
                             _as_object_array(kind, n))

    @staticmethod
    def single(msg_bytes: float, site: Hashable = "default",
               kind: str = KIND_PT2PT) -> "DecisionBatch":
        return DecisionBatch.of([float(msg_bytes)], site, kind)

    def __len__(self) -> int:
        return int(self.msg_bytes.shape[0])

    @property
    def is_alltoall(self) -> np.ndarray:
        return self.kind == KIND_ALLTOALL

    def groups(self):
        """Yield (site, kind, row_indices) for each unique (site, kind)
        pair, in order of first appearance — the vectorization unit: the
        per-site automaton steps once per group, rows inside a group are
        filled with pure NumPy."""
        n = len(self)
        sites, kinds = self.site, self.kind
        # fast path for the hot case (a whole phase shares one site/kind):
        # no per-row Python loop.  The comparands are wrapped as 1-element
        # object arrays so tuple-valued sites (repro.tenancy's scoped
        # (tenant, site) keys) compare elementwise instead of being
        # broadcast as a length-2 array.
        s0 = np.empty(1, dtype=object)
        k0 = np.empty(1, dtype=object)
        if n:
            s0[0], k0[0] = sites[0], kinds[0]
        if n and (sites == s0).all() and (kinds == k0).all():
            yield sites[0], kinds[0], np.arange(n, dtype=np.intp)
            return
        seen: dict = {}
        for i in range(n):
            seen.setdefault((sites[i], kinds[i]), []).append(i)
        for (site, kind), rows in seen.items():
            yield site, kind, np.asarray(rows, dtype=np.intp)


@dataclass(frozen=True)
class Feedback:
    """Normalized telemetry for a decided batch (the paper's (L, s)).

    latency_cycles:  [n] — request->response latency L in NIC cycles.
    stalls_per_flit: [n] — mean stall cycles s per ready flit.
    weight:          [n] — optional averaging weight (bytes); used when a
                     policy aggregates rows of one phase into one sample.
    source: provenance tag, canonicalized by telemetry.normalize_kind
            ("nic" | "hlo" | "sim" | "model" | "notify").
    notified: [n] — optional congestion-notification exposure per row in
            [0, 1] (fraction of the row's bytes that crossed a link
            under a visible congestion flag; SimParams.notify_*).  None
            when the producer has no notification channel — consumers
            must treat None as "no signal", not "no congestion".
    """

    latency_cycles: np.ndarray
    stalls_per_flit: np.ndarray
    weight: np.ndarray = None
    source: str = "sim"
    notified: np.ndarray = None

    def __post_init__(self):
        n = self.latency_cycles.shape[0]
        if self.stalls_per_flit.shape != (n,):
            raise ValueError("Feedback fields must share shape [n]")
        if self.weight is None:
            object.__setattr__(self, "weight", np.ones(n))
        elif self.weight.shape != (n,):
            raise ValueError("Feedback weight must have shape [n]")
        if self.notified is not None and self.notified.shape != (n,):
            raise ValueError("Feedback notified must have shape [n]")

    @staticmethod
    def of(latency_cycles, stalls_per_flit, weight=None,
           source: str = "sim", notified=None) -> "Feedback":
        l = np.atleast_1d(np.asarray(latency_cycles, dtype=np.float64))
        s = np.atleast_1d(np.asarray(stalls_per_flit, dtype=np.float64))
        w = None if weight is None else \
            np.atleast_1d(np.asarray(weight, dtype=np.float64))
        nf = None if notified is None else \
            np.atleast_1d(np.asarray(notified, dtype=np.float64))
        return Feedback(l, s, w, source, nf)

    @staticmethod
    def single(latency_cycles: float, stalls_per_flit: float,
               source: str = "sim") -> "Feedback":
        return Feedback.of([latency_cycles], [stalls_per_flit],
                           source=source)

    def __len__(self) -> int:
        return int(self.latency_cycles.shape[0])


@runtime_checkable
class Policy(Protocol):
    """Pluggable mode-selection strategy.

    decide() returns an [n] object array of modes for the batch; update()
    feeds back telemetry for the batch decide() last saw (same row
    order).  Implementations keep whatever per-site state they need.
    """

    def decide(self, batch: DecisionBatch) -> np.ndarray: ...

    def update(self, batch: DecisionBatch, feedback: Feedback) -> None: ...


@dataclass
class TrafficLedger:
    """Byte accounting shared by policies and the engine (Fig. 8/9's
    '% of traffic sent via Default' axis).

    `sent` is physical truth: bytes that went out under each mode.
    `gated` sub-accounts the bytes the cumulative-size gate *forced* to
    the minimal mode without running the decision rule — kept separate so
    the decided fraction is not polluted (ISSUE satellite fix).
    `decided` counts only bytes routed by an actual Algorithm-1/bandit
    decision.
    """

    sent: dict = field(default_factory=dict)
    gated: dict = field(default_factory=dict)
    decided: dict = field(default_factory=dict)

    def add(self, mode: Hashable, nbytes: float, *, gated: bool) -> None:
        self.sent[mode] = self.sent.get(mode, 0.0) + nbytes
        bucket = self.gated if gated else self.decided
        bucket[mode] = bucket.get(mode, 0.0) + nbytes

    def add_batch(self, modes: np.ndarray, nbytes: np.ndarray,
                  gated=None) -> None:
        """Vectorized accounting: one pass per unique mode in the batch."""
        if gated is None:
            gated = np.zeros(len(modes), dtype=bool)
        for mode in {m for m in modes}:
            rows = modes == mode
            g = float(nbytes[rows & gated].sum())
            d = float(nbytes[rows & ~gated].sum())
            self.sent[mode] = self.sent.get(mode, 0.0) + g + d
            if g:
                self.gated[mode] = self.gated.get(mode, 0.0) + g
            if d:
                self.decided[mode] = self.decided.get(mode, 0.0) + d

    # -- fractions ---------------------------------------------------------
    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        """Fraction of bytes sent with `mode`.  With include_gated=False
        the fraction is over decision-routed bytes only — the Fig. 8/9
        semantics where gate-forced small messages are not counted as
        HIGH-BIAS *decisions*."""
        table = self.sent if include_gated else self.decided
        total = sum(table.values())
        return table.get(mode, 0.0) / total if total else 0.0

    def gated_fraction(self) -> float:
        """Fraction of all bytes that were gate-forced (never decided)."""
        total = sum(self.sent.values())
        return sum(self.gated.values()) / total if total else 0.0
