"""Baseline policies: StaticPolicy and EpsilonGreedyPolicy.

StaticPolicy is the vectorized form of "run everything with one routing
mode" (the Default / HIGH-BIAS arms of Fig. 7-10).  EpsilonGreedyPolicy
is a model-free bandit baseline over the same two arms Algorithm 1
arbitrates: it needs no λ/σ calibration and no cost model, so it bounds
how much of Algorithm 1's win comes from the paper's Eq.(2) structure
versus generic explore/exploit adaptation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable

import numpy as np

from repro.core.perf_model import (MAX_OUTSTANDING_PACKETS,
                                   PACKET_PAYLOAD_BYTES,
                                   PUT_FLITS_PER_PACKET)
from repro.policy.types import DecisionBatch, Feedback, KIND_ALLTOALL


@dataclass
class StaticPolicy:
    """Always the same mode; feedback is ignored."""

    mode: Hashable

    def decide(self, batch: DecisionBatch) -> np.ndarray:
        return np.full(len(batch), self.mode, dtype=object)

    def update(self, batch: DecisionBatch, feedback: Feedback) -> None:
        return None


def _eq2_cycles_per_byte(msg_bytes: np.ndarray, latency_cycles: np.ndarray,
                         stalls_per_flit: np.ndarray) -> np.ndarray:
    """Vectorized Eq.(2) per-byte cost — the bandit's loss signal."""
    b = np.maximum(msg_bytes, 1.0)
    packets = np.maximum(1.0, np.ceil(b / PACKET_PAYLOAD_BYTES))
    flits = packets * PUT_FLITS_PER_PACKET
    window = (packets + MAX_OUTSTANDING_PACKETS // 2) \
        / MAX_OUTSTANDING_PACKETS
    t = window * latency_cycles + flits * (stalls_per_flit + 1.0)
    return t / b


@dataclass
class _ArmStats:
    cost: float = 0.0          # EMA of Eq.(2) cycles/byte
    n: int = 0


@dataclass
class EpsilonGreedyPolicy:
    """ε-greedy over (mode_a, mode_b) per call site.

    decide(): with probability ε a row explores a uniform-random arm;
    otherwise it exploits the arm with the lowest EMA Eq.(2)-per-byte
    cost (unobserved arms are tried first).  Fully vectorized: one rng
    draw per row, one automaton touch per (site, kind) group.
    update(): per-arm weighted-mean cost folded into the EMA.

    ε follows the decayed schedule ``eps0 / (1 + k·t)`` where t counts
    prior decide() touches of the site and k is `epsilon_decay`: early
    phases explore, converged phases stop paying the exploration tax
    (constant-ε never beat Algorithm 1 in fig8 cells because it kept
    routing ε of the traffic through the losing arm forever).
    `epsilon_decay=0` recovers the constant-ε bandit.
    """

    mode_a: Hashable
    mode_b: Hashable
    mode_a_alltoall: Hashable = None
    epsilon: float = 0.1
    #: k in eps0 / (1 + k·t); t = prior decide() touches of the site
    epsilon_decay: float = 0.05
    ema: float = 0.3           # EMA weight of the newest cost sample
    seed: int = 0
    _rng: np.random.Generator = None
    _arms: dict = field(default_factory=dict)  # (site, mode) -> _ArmStats
    _site_steps: dict = field(default_factory=dict)  # site -> decide touches
    _pending: list = field(default_factory=list)

    def __post_init__(self):
        if self.mode_a_alltoall is None:
            self.mode_a_alltoall = self.mode_a
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)

    def _stats(self, site: Hashable, mode: Hashable) -> _ArmStats:
        key = (site, mode)
        st = self._arms.get(key)
        if st is None:
            st = self._arms[key] = _ArmStats()
        return st

    def effective_epsilon(self, site: Hashable) -> float:
        """Current ε at `site`: eps0 / (1 + k·t)."""
        t = self._site_steps.get(site, 0)
        return self.epsilon / (1.0 + self.epsilon_decay * t)

    def decide(self, batch: DecisionBatch) -> np.ndarray:
        n = len(batch)
        modes = np.empty(n, dtype=object)
        pending = []
        # ε is sampled once per site per decide() — a batch mixing kinds
        # at one site is still a single schedule step for that site
        site_eps: dict = {}
        for site, kind, rows in batch.groups():
            a = self.mode_a_alltoall if kind == KIND_ALLTOALL else self.mode_a
            b = self.mode_b
            sa, sb = self._stats(site, a), self._stats(site, b)
            # exploit arm: untried arms first, then lowest EMA cost
            if sa.n == 0:
                exploit = a
            elif sb.n == 0:
                exploit = b
            else:
                exploit = a if sa.cost <= sb.cost else b
            eps = site_eps.setdefault(site, self.effective_epsilon(site))
            explore = self._rng.random(len(rows)) < eps
            coin = self._rng.random(len(rows)) < 0.5
            row_modes = np.full(len(rows), exploit, dtype=object)
            row_modes[explore & coin] = a
            row_modes[explore & ~coin] = b
            modes[rows] = row_modes
            pending.append((site, rows, row_modes))
        for site in site_eps:
            self._site_steps[site] = self._site_steps.get(site, 0) + 1
        self._pending = pending
        return modes

    def update(self, batch: DecisionBatch, feedback: Feedback) -> None:
        if not self._pending:
            return
        if len(feedback) != len(batch):
            raise ValueError("feedback rows must match the decided batch")
        cost = _eq2_cycles_per_byte(batch.msg_bytes,
                                    feedback.latency_cycles,
                                    feedback.stalls_per_flit)
        w = feedback.weight
        for site, rows, row_modes in self._pending:
            for mode in {m for m in row_modes}:
                sel = rows[row_modes == mode]
                tot = float(w[sel].sum()) or 1.0
                c = float((cost[sel] * w[sel]).sum() / tot)
                st = self._stats(site, mode)
                st.cost = c if st.n == 0 else \
                    (1 - self.ema) * st.cost + self.ema * c
                st.n += 1
        self._pending = []

    def reset_samples(self, site_filter=None) -> int:
        """Fault-epoch hook (docs/faults.md): drop the per-arm cost EMAs
        for the matching sites — pre-fault costs describe a link set
        that no longer exists.  The decayed-ε schedule restarts with
        them, so the bandit re-explores the changed machine.  Returns
        the number of sites reset."""
        sites = {k[0] for k in self._arms} | set(self._site_steps)
        hit = [s for s in sites
               if site_filter is None or site_filter(s)]
        for s in hit:
            self._site_steps.pop(s, None)
        for key in [k for k in self._arms if k[0] in set(hit)]:
            del self._arms[key]
        return len(hit)
