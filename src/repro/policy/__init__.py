"""repro.policy — unified, pluggable, vectorized mode-selection API.

The single entry point for routing/schedule selection across the
Dragonfly simulator, the TPU collective layer and the launchers:

    from repro.policy import (AppAwareConfig, AppAwarePolicy,
                              DecisionBatch, PolicyEngine, make_engine)

    engine = make_engine("app_aware")
    modes = engine.decide(DecisionBatch.of(bytes_array, site="bucket0"))
    engine.bus.publish_flow_arrays(latency_us, stalls)   # feedback

See docs/policy_api.md for the architecture diagram and migration notes
from the deprecated `repro.core.app_aware.AppAwareRouter` shim.
"""

from repro.policy.app_aware import (AppAwareConfig, AppAwarePolicy,
                                    SiteState, scoped_site_filter)
from repro.policy.engine import PolicyEngine, POLICY_NAMES, make_engine
from repro.policy.notification import NotificationConfig, NotificationPolicy
from repro.policy.policies import EpsilonGreedyPolicy, StaticPolicy
from repro.policy.telemetry import (COUNTER_KINDS, TelemetryBus,
                                    normalize_kind)
from repro.policy.types import (DecisionBatch, Feedback, KIND_ALLREDUCE,
                                KIND_ALLTOALL, KIND_BROADCAST, KIND_PT2PT,
                                Policy, TrafficLedger)

__all__ = [
    "AppAwareConfig", "AppAwarePolicy", "SiteState", "scoped_site_filter",
    "PolicyEngine", "POLICY_NAMES", "make_engine",
    "EpsilonGreedyPolicy", "StaticPolicy",
    "NotificationConfig", "NotificationPolicy",
    "TelemetryBus", "COUNTER_KINDS", "normalize_kind",
    "DecisionBatch", "Feedback", "Policy", "TrafficLedger",
    "KIND_PT2PT", "KIND_ALLTOALL", "KIND_ALLREDUCE", "KIND_BROADCAST",
]
