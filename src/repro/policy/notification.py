"""NotificationPolicy — notification-driven adaptive routing.

Rocher-Gonzalez et al. (arXiv:2502.00616) study congestion-management
for Dragonflies built on *explicit notifications*: switches that detect
queue build-up past a threshold notify the sources, which throttle or
re-route until the congestion clears, with a two-level hysteresis so
the signal does not chatter around a single threshold.  That is the
third congestion signal next to this repo's queue-occupancy estimates
(UGAL) and app-aware bias — and this policy is its consumer.

The simulator side (``SimParams.notify_*``, docs/policy_api.md) raises
per-link flags, delays them by the propagation latency, penalizes
flagged links in the routing scores, and reports each flow's *notified
exposure* (fraction of sprayed bytes that crossed a flagged link)
through FlowResult / TelemetryBus / the NIC notification counter.
``NotificationPolicy`` closes the loop at the mode level, per call
site:

  * **calm regime** — no recent notifications: keep the minimal-biased
    arm (``mode_calm``, default HIGH BIAS), the cheap choice while the
    network is quiet;
  * **congested regime** — the site's notified-exposure EMA crossed
    ``on_threshold``: demote minimal paths and emit the spreading arm
    (``mode_congested``, default ADAPTIVE) until the EMA falls back
    below ``off_threshold`` (hysteresis) and the regime has dwelt at
    least ``min_dwell`` updates (no per-phase flip-flopping).

Like every policy in repro.policy it is vectorized (one automaton touch
per (site, kind) group) and carries the ``reset_samples`` fault-epoch
hook: notifications raised on a link set that no longer exists must not
steer the next epoch's decisions (docs/faults.md).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable

import numpy as np

from repro.core.strategies import RoutingMode
from repro.policy.types import DecisionBatch, Feedback


@dataclass(frozen=True)
class NotificationConfig:
    """Calibration of the notification-reactive automaton."""

    #: calm-regime arm: bias toward minimal paths while nothing notifies
    mode_calm: Hashable = RoutingMode.ADAPTIVE_3
    #: congested-regime arm: spread over non-minimal paths while notified
    mode_congested: Hashable = RoutingMode.ADAPTIVE_0
    #: notified-exposure EMA that trips the congested regime (high water)
    on_threshold: float = 0.05
    #: ... and that clears it again (low water; the hysteresis band keeps
    #: the automaton from chattering around one threshold, 2502.00616)
    off_threshold: float = 0.01
    #: EMA weight of the newest exposure sample
    ema: float = 0.5
    #: minimum feedback updates a regime persists before switching back
    min_dwell: int = 2

    def __post_init__(self):
        if not 0.0 <= self.off_threshold <= self.on_threshold:
            raise ValueError("need 0 <= off_threshold <= on_threshold")


@dataclass
class _SiteNotify:
    """Per-(site) automaton state."""

    ema: float = 0.0
    congested: bool = False
    dwell: int = 0          # updates since the last regime switch
    n: int = 0              # exposure samples folded in


@dataclass
class NotificationPolicy:
    """Threshold + hysteresis regime switching on notification telemetry."""

    config: NotificationConfig = field(default_factory=NotificationConfig)
    _sites: dict = field(default_factory=dict)   # site -> _SiteNotify

    def _state(self, site: Hashable) -> _SiteNotify:
        st = self._sites.get(site)
        if st is None:
            st = self._sites[site] = _SiteNotify()
        return st

    # ------------------------------------------------------------- decide
    def decide(self, batch: DecisionBatch) -> np.ndarray:
        cfg = self.config
        modes = np.empty(len(batch), dtype=object)
        for site, _kind, rows in batch.groups():
            st = self._state(site)
            modes[rows] = cfg.mode_congested if st.congested \
                else cfg.mode_calm
        return modes

    # ------------------------------------------------------------- update
    def update(self, batch: DecisionBatch, feedback: Feedback) -> None:
        """Fold the batch's notified exposure into each site's EMA and
        step the regime automaton.  Feedback without a notification
        signal (``feedback.notified is None`` — the channel is disabled
        or the producer predates it) leaves the state untouched, so the
        policy degrades to a static ``mode_calm`` arm."""
        sig = feedback.notified
        if sig is None:
            return
        cfg = self.config
        w = feedback.weight
        for site, _kind, rows in batch.groups():
            st = self._state(site)
            tot = float(w[rows].sum()) or 1.0
            x = float((sig[rows] * w[rows]).sum() / tot)
            st.ema = x if st.n == 0 else \
                (1.0 - cfg.ema) * st.ema + cfg.ema * x
            st.n += 1
            st.dwell += 1
            if not st.congested and st.ema >= cfg.on_threshold:
                st.congested, st.dwell = True, 0
            elif st.congested and st.ema <= cfg.off_threshold \
                    and st.dwell >= cfg.min_dwell:
                st.congested, st.dwell = False, 0

    # ------------------------------------------------------------- faults
    def reset_samples(self, site_filter=None) -> int:
        """Fault-epoch hook (docs/faults.md): notifications measured on
        the previous link set no longer describe any live path — matching
        sites drop back to the calm regime with a fresh EMA.  Returns the
        number of sites reset."""
        hit = [s for s in self._sites
               if site_filter is None or site_filter(s)]
        for s in hit:
            del self._sites[s]
        return len(hit)

    # -------------------------------------------------------------- stats
    def site_state(self, site: Hashable) -> _SiteNotify | None:
        """Introspection for tests/benchmarks (None = never touched)."""
        return self._sites.get(site)
