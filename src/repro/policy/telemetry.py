"""TelemetryBus — one feedback pipe for every counter source.

The paper's Algorithm 1 consumes a single (L, s) pair per observation;
the repo has three producers of that pair with three different units:

  * Aries NIC counters (`core/counters.py`): CounterDelta with
    mean_latency_us and stalls_per_flit — the faithful hardware path;
  * HLO counters (`collectives/hlo_counters.py`): the same NICCounters
    synthesized from a compiled XLA module, read through CounterWindow;
  * the Dragonfly simulator: per-flow latency_us / stalls_per_flit
    arrays straight out of the fluid model (FlowResult).

The bus normalizes all of them into `Feedback` records (latency in NIC
cycles, stalls per flit) and fans them out to subscribers — typically a
PolicyEngine, which forwards them to its Policy.  Publishing never
blocks or reorders: counters are read *after* the send, so policies stay
strictly one message behind, as in the paper (§4.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List

import numpy as np

from repro.core.counters import CounterDelta, CounterWindow
from repro.core.perf_model import NIC_CLOCK_GHZ
from repro.core.strategies import ModePerformance
from repro.policy.types import Feedback


def us_to_cycles(latency_us, clock_ghz: float = NIC_CLOCK_GHZ):
    return np.asarray(latency_us, dtype=np.float64) * clock_ghz * 1e3


@dataclass
class TelemetryBus:
    """Normalize heterogeneous counters into Feedback and fan out."""

    clock_ghz: float = NIC_CLOCK_GHZ
    _subscribers: List[Callable[[Feedback], None]] = field(
        default_factory=list)
    #: ring of recent feedback, handy for debugging/benchmark reporting
    history: list = field(default_factory=list)
    history_limit: int = 64

    # ----------------------------------------------------------- pub/sub
    def subscribe(self, callback: Callable[[Feedback], None]) -> None:
        self._subscribers.append(callback)

    def publish(self, feedback: Feedback) -> None:
        self.history.append(feedback)
        if len(self.history) > self.history_limit:
            del self.history[: len(self.history) - self.history_limit]
        for cb in self._subscribers:
            cb(feedback)

    # ------------------------------------------------------- normalizers
    def from_counter_delta(self, delta: CounterDelta, *,
                           source: str = "nic") -> Feedback:
        """Aries/HLO NIC counters -> one aggregate (L, s) sample."""
        return Feedback.of(
            us_to_cycles(delta.mean_latency_us, self.clock_ghz),
            [delta.stalls_per_flit],
            weight=[max(float(delta.flits), 1.0)],
            source=source)

    def from_counter_window(self, window: CounterWindow, *,
                            source: str = "nic") -> Feedback:
        """Read a CounterWindow delta and normalize it (§3.2-safe)."""
        return self.from_counter_delta(window.read(), source=source)

    def from_flow_arrays(self, latency_us, stalls_per_flit, *,
                         weight=None, source: str = "sim") -> Feedback:
        """Dragonfly FlowResult observables -> per-flow Feedback rows."""
        return Feedback.of(
            us_to_cycles(latency_us, self.clock_ghz), stalls_per_flit,
            weight=weight, source=source)

    def from_mode_performance(self, perf: ModePerformance, *,
                              source: str = "model") -> Feedback:
        """Cost-model prediction -> one sample (dry-run self-feeding)."""
        return Feedback.single(perf.latency_cycles,
                               perf.stall_cycles_per_flit, source=source)

    # ------------------------------------------------ publish shorthands
    def publish_counter_delta(self, delta: CounterDelta, *,
                              source: str = "nic") -> Feedback:
        fb = self.from_counter_delta(delta, source=source)
        self.publish(fb)
        return fb

    def publish_flow_arrays(self, latency_us, stalls_per_flit, *,
                            weight=None, source: str = "sim") -> Feedback:
        fb = self.from_flow_arrays(latency_us, stalls_per_flit,
                                   weight=weight, source=source)
        self.publish(fb)
        return fb
