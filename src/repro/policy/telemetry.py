"""TelemetryBus — one feedback pipe for every counter source.

The paper's Algorithm 1 consumes a single (L, s) pair per observation;
the repo has three producers of that pair with three different units:

  * Aries NIC counters (`core/counters.py`): CounterDelta with
    mean_latency_us and stalls_per_flit — the faithful hardware path;
  * HLO counters (`collectives/hlo_counters.py`): the same NICCounters
    synthesized from a compiled XLA module, read through CounterWindow;
  * the Dragonfly simulator: per-flow latency_us / stalls_per_flit
    arrays straight out of the fluid model (FlowResult).

The bus normalizes all of them into `Feedback` records (latency in NIC
cycles, stalls per flit) and fans them out to subscribers — typically a
PolicyEngine, which forwards them to its Policy.  Publishing never
blocks or reorders: counters are read *after* the send, so policies stay
strictly one message behind, as in the paper (§4.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, List

import numpy as np

from repro.core.counters import CounterDelta, CounterWindow
from repro.core.perf_model import NIC_CLOCK_GHZ
from repro.core.strategies import ModePerformance
from repro.policy.types import Feedback


def us_to_cycles(latency_us, clock_ghz: float = NIC_CLOCK_GHZ):
    return np.asarray(latency_us, dtype=np.float64) * clock_ghz * 1e3


#: canonical counter kinds the bus accepts as Feedback.source.  "notify"
#: is the congestion-notification channel (SimParams.notify_* +
#: NotificationPolicy): producers that only carry notification exposure
#: tag their feedback with it so subscribers can tell the signal apart
#: from ordinary (L, s) telemetry.
COUNTER_KINDS = ("nic", "hlo", "sim", "model", "notify")

#: accepted aliases -> canonical kind (every canonical kind maps to
#: itself implicitly, which is what makes normalize_kind idempotent)
_KIND_ALIASES = {
    "nics": "nic", "counter": "nic", "counters": "nic", "aries": "nic",
    "xla": "hlo", "simulator": "sim", "flows": "sim",
    "cost_model": "model", "notification": "notify",
    "notifications": "notify", "cn": "notify",
}


def normalize_kind(kind: str) -> str:
    """Canonicalize a counter-kind label.

    Case/whitespace-insensitive alias resolution into COUNTER_KINDS.
    Idempotent by construction — ``normalize_kind(normalize_kind(k)) ==
    normalize_kind(k)`` for every accepted input (property-tested in
    tests/test_telemetry_props.py).  Unknown kinds raise ValueError so a
    typoed provenance tag fails loudly instead of silently forking the
    telemetry namespace.
    """
    k = str(kind).strip().lower()
    k = _KIND_ALIASES.get(k, k)
    if k not in COUNTER_KINDS:
        raise ValueError(f"unknown counter kind {kind!r}; expected one "
                         f"of {COUNTER_KINDS} or an alias "
                         f"{tuple(_KIND_ALIASES)}")
    return k


@dataclass
class TelemetryBus:
    """Normalize heterogeneous counters into Feedback and fan out."""

    clock_ghz: float = NIC_CLOCK_GHZ
    _subscribers: List[Callable[[Feedback], None]] = field(
        default_factory=list)
    #: ring of recent feedback, handy for debugging/benchmark reporting
    history: list = field(default_factory=list)
    history_limit: int = 64

    # ----------------------------------------------------------- pub/sub
    def subscribe(self, callback: Callable[[Feedback], None]) -> None:
        self._subscribers.append(callback)

    def publish(self, feedback: Feedback) -> None:
        # the bus owns the counter-kind namespace: whatever alias the
        # producer used, subscribers always see the canonical kind
        src = normalize_kind(feedback.source)
        if src != feedback.source:
            feedback = replace(feedback, source=src)
        self.history.append(feedback)
        if len(self.history) > self.history_limit:
            del self.history[: len(self.history) - self.history_limit]
        for cb in self._subscribers:
            cb(feedback)

    # ------------------------------------------------------- normalizers
    def from_counter_delta(self, delta: CounterDelta, *,
                           source: str = "nic") -> Feedback:
        """Aries/HLO NIC counters -> one aggregate (L, s) sample (plus
        the window's notified fraction when the NIC saw notification
        events — zero-notification windows still carry the 0.0 signal,
        which is how reactive policies learn the congestion cleared)."""
        return Feedback.of(
            us_to_cycles(delta.mean_latency_us, self.clock_ghz),
            [delta.stalls_per_flit],
            weight=[max(float(delta.flits), 1.0)],
            source=source,
            notified=[delta.notified_fraction])

    def from_counter_window(self, window: CounterWindow, *,
                            source: str = "nic") -> Feedback:
        """Read a CounterWindow delta and normalize it (§3.2-safe)."""
        return self.from_counter_delta(window.read(), source=source)

    def from_flow_arrays(self, latency_us, stalls_per_flit, *,
                         weight=None, source: str = "sim",
                         notified=None) -> Feedback:
        """Dragonfly FlowResult observables -> per-flow Feedback rows.

        ``notified`` (optional, [n] in [0, 1]) is FlowResult.notified —
        the per-flow congestion-notification exposure.  Leave it None
        when the simulator's channel is disabled; passing an array keeps
        source semantics intact (the rows still carry (L, s)), it just
        adds the notification signal alongside."""
        return Feedback.of(
            us_to_cycles(latency_us, self.clock_ghz), stalls_per_flit,
            weight=weight, source=source, notified=notified)

    def from_mode_performance(self, perf: ModePerformance, *,
                              source: str = "model") -> Feedback:
        """Cost-model prediction -> one sample (dry-run self-feeding)."""
        return Feedback.single(perf.latency_cycles,
                               perf.stall_cycles_per_flit, source=source)

    # ------------------------------------------------ publish shorthands
    def publish_counter_delta(self, delta: CounterDelta, *,
                              source: str = "nic") -> Feedback:
        fb = self.from_counter_delta(delta, source=source)
        self.publish(fb)
        return fb

    def publish_flow_arrays(self, latency_us, stalls_per_flit, *,
                            weight=None, source: str = "sim",
                            notified=None) -> Feedback:
        fb = self.from_flow_arrays(latency_us, stalls_per_flit,
                                   weight=weight, source=source,
                                   notified=notified)
        self.publish(fb)
        return fb
