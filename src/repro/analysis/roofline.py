"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs(trip-scaled)      / peak_FLOP/s    per chip
    memory     = HLO_bytes(trip-scaled)      / HBM_bw         per chip
    collective = wire_bytes per link class   / link_bw        per chip

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM, ~50 GB/s/link ICI.  Cross-pod traffic rides DCN, charged at
a conservative 12.5 GB/s/chip.

The dominant term is the bottleneck the §Perf loop iterates on;
MODEL_FLOPS / HLO_FLOPs is the useful-compute ratio (catches remat and
dispatch-einsum waste).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.analysis.hlo_parse import HloCosts


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per chip per link class (intra-pod)
    dcn_bw: float              # bytes/s per chip (pod boundary)


V5E = HwSpec(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
             ici_bw=50e9, dcn_bw=12.5e9)


def classify_collective(group0_devices, mesh_shape) -> str:
    """'cross_pod' if the replica group spans pod boundaries, else 'intra'.

    Device ids are row-major over mesh_shape; for ("pod","data","model")
    the pod coordinate is id // (data*model)."""
    if len(mesh_shape) < 3 or not group0_devices:
        return "intra"
    per_pod = int(np.prod(mesh_shape[1:]))
    pods = {d // per_pod for d in group0_devices}
    return "cross_pod" if len(pods) > 1 else "intra"


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: tuple
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    collective_intra_bytes: float
    collective_cross_bytes: float
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    model_flops_total: float
    n_collectives: int
    extras: dict = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline step time = max of the three (perfectly overlapped)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        (useful FLOPs / chips / peak) / bound_s."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops_total / self.chips / V5E.peak_flops
        return useful_s / self.bound_s

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {'x'.join(map(str, self.mesh)):>9s} "
                f"{self.compute_s*1e3:9.3f} {self.memory_s*1e3:9.3f} "
                f"{self.collective_s*1e3:9.3f} {self.dominant:10s} "
                f"{self.useful_flops_ratio:7.3f} {self.roofline_fraction:7.3f}")


def roofline_terms(costs: HloCosts, *, arch: str, shape: str,
                   mesh_shape: tuple, model_flops: float,
                   hw: HwSpec = V5E) -> RooflineReport:
    chips = int(np.prod(mesh_shape))
    intra = 0.0
    cross = 0.0
    for c in costs.collectives:
        wb = c.wire_bytes() * c.multiplier
        if classify_collective(c.group0_devices, mesh_shape) == "cross_pod":
            cross += wb
        else:
            intra += wb
    collective_s = intra / hw.ici_bw + cross / hw.dcn_bw
    return RooflineReport(
        arch=arch, shape=shape, mesh=tuple(mesh_shape), chips=chips,
        compute_s=costs.flops / hw.peak_flops,
        memory_s=costs.bytes_accessed / hw.hbm_bw,
        collective_s=collective_s,
        collective_intra_bytes=intra,
        collective_cross_bytes=cross,
        hlo_flops_per_chip=costs.flops,
        hlo_bytes_per_chip=costs.bytes_accessed,
        model_flops_total=model_flops,
        n_collectives=len(costs.collectives),
    )


def flash_ideal_bytes_per_chip(cfg, shape, chips: int,
                               passes: float = 4.0) -> float:
    """HBM traffic of the Pallas flash kernel replacing the jnp attention:
    q,k,v reads + o write per layer, ~4 passes total (fwd + recompute +
    bwd dq/dkv), all intermediates staying in VMEM."""
    from repro.models.common import Family

    if cfg.family == Family.SSM or not cfg.n_heads:
        return 0.0
    tokens = shape.global_batch * shape.seq_len
    L = cfg.n_layers + (cfg.n_encoder_layers or 0)
    per_tok = (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) * cfg.hd * 2
    return tokens * per_tok * L * passes / chips


def flash_adjusted(rep: "RooflineReport", costs: HloCosts, cfg, shape,
                   hw: HwSpec = V5E):
    """(adjusted memory term, adjusted roofline fraction): subtract the
    measured "attn_core" scope traffic, add the kernel's ideal traffic."""
    removed = costs.scope_bytes.get("attn_core", 0.0)
    ideal = flash_ideal_bytes_per_chip(cfg, shape, rep.chips)
    adj_bytes = max(rep.hlo_bytes_per_chip - removed + ideal, 0.0)
    adj_memory_s = adj_bytes / hw.hbm_bw
    bound = max(rep.compute_s, adj_memory_s, rep.collective_s)
    useful_s = rep.model_flops_total / rep.chips / hw.peak_flops
    return adj_memory_s, (useful_s / bound if bound > 0 else 0.0)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for dense training (N = params, D = tokens);
    6*N_active*D for MoE; 2*N_active per generated token for decode."""
    from repro.models.common import Family

    n_total, n_active = param_counts_analytic(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token per seq


def param_counts_analytic(cfg) -> tuple:
    """(total, active) parameter counts from the config dims."""
    from repro.models.common import Family

    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        return d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2

    def mlp_params(f):
        return d * f * (3 if cfg.glu else 2)

    if cfg.family == Family.SSM:
        d_in = cfg.ssm_expand * d
        H = d_in // cfg.ssm_head_dim
        per = d * (2 * d_in + 2 * cfg.ssm_state + H) + d_in * d
        total = emb + L * per
        return total, total
    if cfg.family == Family.HYBRID:
        d_in = cfg.ssm_expand * d
        H = d_in // cfg.ssm_head_dim
        per = d * (2 * d_in + 2 * cfg.ssm_state + H) + d_in * d
        shared = attn_params() + mlp_params(cfg.d_ff)
        total = emb + L * per + shared
        return total, total
    if cfg.family == Family.MOE:
        fe = cfg.d_ff_expert or cfg.d_ff
        per_expert = d * fe * (3 if cfg.glu else 2)
        shared = mlp_params(fe * cfg.n_shared_experts) \
            if cfg.n_shared_experts else 0
        per = attn_params() + cfg.n_experts * per_expert + shared \
            + d * cfg.n_experts
        per_active = attn_params() + cfg.top_k * per_expert + shared \
            + d * cfg.n_experts
        return emb + L * per, emb + L * per_active
    if cfg.family == Family.ENCDEC:
        enc = cfg.n_encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
        dec = L * (2 * attn_params() + mlp_params(cfg.d_ff))
        total = emb + enc + dec
        return total, total
    # dense / vlm
    per = attn_params() + mlp_params(cfg.d_ff)
    total = emb + L * per
    return total, total
