# repro.analysis — compiled-HLO introspection (the dry-run "profiler").
#
# hlo_parse walks the compiled module text, scales while-loop bodies by
# their known_trip_count (XLA's cost_analysis() counts loop bodies ONCE —
# probed and documented in DESIGN.md), and extracts per-collective bytes +
# replica groups.  roofline turns that into the 3-term model.  These
# collective byte counts are also the TPU backend for the paper's NIC
# counters (collectives/hlo_counters.py).

from repro.analysis.hlo_parse import parse_hlo, HloCosts, CollectiveOp
from repro.analysis.roofline import roofline_terms, RooflineReport, V5E

__all__ = ["parse_hlo", "HloCosts", "CollectiveOp", "roofline_terms",
           "RooflineReport", "V5E"]
