"""Compiled-HLO text analysis with while-loop trip-count scaling.

Why this exists (probed, see DESIGN.md §3): XLA:CPU's ``cost_analysis()``
counts a ``while`` (lax.scan) body ONCE, so a 32-layer scanned transformer
reports 1/32nd of its FLOPs.  The compiled text, however, carries
``backend_config={"known_trip_count":{"n":"32"}}`` on the while op.  This
module parses the module text, multiplies every computation's costs by the
product of enclosing trip counts, and returns:

  * flops         — dot/convolution FLOPs, trip-scaled
  * bytes         — top-level operand+result bytes per computation
                    (fusions count once; their bodies are on-chip traffic),
                    trip-scaled — a consistent HBM-traffic model
  * collectives   — every all-reduce / all-gather / reduce-scatter /
                    all-to-all / collective-permute with operand bytes,
                    replica groups, and trip multiplier

Replica groups are resolved to device-id sets so the roofline layer can
split collective bytes into intra-pod vs pod-boundary link classes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
# NB: tuple types with >5 elements carry /*index=N*/ comments (which
# contain '='), so the tuple arm must be a lazy any-char match delimited by
# the following " kind(" — probed on real compiled modules.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                         r"(?:T\(([0-9,]+)\))?")
_RG_EXPL_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# HBM-traffic model: ops that represent real memory round trips on TPU.
# Bare elementwise ops / converts / copies / broadcasts are fused into
# neighbors by the TPU backend (XLA:CPU leaves many unfused — counting them
# would charge phantom traffic), so only these kinds accrue bytes:
_BYTES_KINDS = frozenset({
    "dot", "convolution", "fusion", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "reduce", "reduce-window",
    "sort", "rng", "cholesky", "triangular-solve", "pad", "select-and-scatter",
}) | set(COLLECTIVE_KINDS)
_CONVERT_FUSION_PREFIXES = ("wrapped_convert", "convert_", "copy_",
                            "wrapped_copy", "wrapped_broadcast",
                            "wrapped_transpose", "transpose_copy",
                            "bitcast_")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    rest: str           # operands + attrs (raw tail of the line)
    operands: list = field(default_factory=list)


@dataclass
class CollectiveOp:
    kind: str
    operand_bytes: int          # per participating device
    result_bytes: int
    multiplier: int             # enclosing trip-count product
    group_size: int
    group0_devices: tuple       # device ids of the first replica group
    computation: str
    name: str

    def wire_bytes(self) -> float:
        """Bytes on the wire per device, ring-algorithm formulas."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (n - 1) / n * self.operand_bytes
        if self.kind == "collective-permute":
            return float(self.operand_bytes)
        if self.kind == "all-gather":
            return (n - 1) / n * self.result_bytes      # result = full
        # reduce-scatter / all-to-all: operand is the full local buffer
        return (n - 1) / n * self.operand_bytes


@dataclass
class HloCosts:
    flops: float                      # trip-scaled, per device
    bytes_accessed: float             # trip-scaled HBM-traffic model
    collectives: list                 # [CollectiveOp]
    dot_flops_by_meta: dict           # op_name metadata -> flops
    n_while: int
    trip_counts: list
    scope_bytes: dict = field(default_factory=dict)  # named_scope -> bytes
    scope_flops: dict = field(default_factory=dict)

    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes() * c.multiplier for c in self.collectives)


#: named scopes tracked for §Perf adjustments (models/attention.py tags
#: the flash-replaceable region)
TRACKED_SCOPES = ("attn_core",)


def _parse_operand_names(rest: str) -> list:
    """Operand %names from the call tail (up to the closing paren depth)."""
    out, depth = [], 1
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    for m in re.finditer(r"%([\w.\-]+)", token):
        out.append(m.group(1))
    return out


def _iota_groups(g: int, s: int, dims, perm):
    n = int(np.prod(dims))
    arr = np.arange(n).reshape(dims)
    if perm is not None:
        arr = arr.transpose(perm)
    return arr.reshape(g, s)


def parse_replica_groups(rest: str):
    """-> (group_size, group0_device_ids) or (0, ())."""
    m = _RG_IOTA_RE.search(rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else None)
        groups = _iota_groups(g, s, dims, perm)
        return s, tuple(int(x) for x in groups[0])
    m = _RG_EXPL_RE.search(rest)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        ids = tuple(int(x) for x in first.split(",") if x)
        return len(ids), ids
    return 0, ()


def _dot_flops(op: Op, shapes: dict) -> float:
    """2 * result_elems * contraction_size (batch dims cancel out)."""
    result = _result_elems(op.type_str)
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not mc or not op.operands:
        return 2.0 * result  # degenerate
    lhs_shape = shapes.get(op.operands[0])
    if lhs_shape is None:
        return 2.0 * result
    contract = 1
    dims_str = mc.group(1)
    if dims_str:
        for d in dims_str.split(","):
            di = int(d)
            if di < len(lhs_shape):
                contract *= lhs_shape[di]
    return 2.0 * result * contract


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    if not dims:
        return ()
    return tuple(int(d) for d in dims.split(","))


def parse_hlo(text: str) -> HloCosts:
    # --- split into computations ---------------------------------------
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
            comps[cur] = {"ops": [], "entry": bool(mc.group(1))}
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = Op(name=mo.group(1), type_str=mo.group(2),
                    kind=mo.group(3), rest=mo.group(4))
            op.operands = _parse_operand_names(mo.group(4))
            comps[cur]["ops"].append(op)

    entry = next((n for n, c in comps.items() if c["entry"]), None)
    if entry is None:  # fall back: biggest computation
        entry = max(comps, key=lambda n: len(comps[n]["ops"]))

    # --- compute multipliers (BFS from entry through while/call/fusion) --
    mult: dict = {entry: 1}
    trip_counts: list = []
    n_while = 0
    stack = [entry]
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen:
            continue
        seen.add(cname)
        m = mult.get(cname, 1)
        for op in comps.get(cname, {"ops": []})["ops"]:
            if op.kind == "while":
                n_while += 1
                tm = _TRIP_RE.search(op.rest)
                trip = int(tm.group(1)) if tm else 1
                trip_counts.append(trip)
                for attr, extra in (("body", trip), ("condition", trip + 1)):
                    ma = re.search(attr + r"=%?([\w.\-]+)", op.rest)
                    if ma:
                        sub = ma.group(1)
                        mult[sub] = max(mult.get(sub, 0), m * extra)
                        stack.append(sub)
            else:
                for ma in _CALLS_RE.finditer(op.rest):
                    sub = ma.group(1)
                    if sub in comps:
                        mult[sub] = max(mult.get(sub, 0), m)
                        stack.append(sub)

    # --- accumulate costs -------------------------------------------------
    flops = 0.0
    bytes_accessed = 0.0
    collectives: list = []
    dot_by_meta: dict = {}
    scope_bytes: dict = {s: 0.0 for s in TRACKED_SCOPES}
    scope_flops: dict = {s: 0.0 for s in TRACKED_SCOPES}

    def _scope_of(rest: str):
        for s in TRACKED_SCOPES:
            if s in rest:
                return s
        return None
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # unreachable (dead computation)
        shapes = {op.name: _first_shape_dims(op.type_str)
                  for op in comp["ops"]}
        types = {op.name: op.type_str for op in comp["ops"]}
        for op in comp["ops"]:
            scope = _scope_of(op.rest)
            if op.kind in ("dot", "convolution"):
                fl = _dot_flops(op, shapes)
                flops += m * fl
                if scope:
                    scope_flops[scope] += m * fl
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                key = meta.group(1) if meta else op.name
                dot_by_meta[key] = dot_by_meta.get(key, 0.0) + m * fl
            opnd_bytes = sum(shape_bytes(types.get(o, ""))
                             for o in op.operands)
            if op.kind in COLLECTIVE_KINDS:
                gs, g0 = parse_replica_groups(op.rest)
                collectives.append(CollectiveOp(
                    kind=op.kind,
                    operand_bytes=opnd_bytes or shape_bytes(op.type_str),
                    result_bytes=shape_bytes(op.type_str),
                    multiplier=m, group_size=gs, group0_devices=g0,
                    computation=cname, name=op.name))
            # HBM-traffic model: only kinds that hit HBM on TPU (see
            # _BYTES_KINDS); dtype-convert/copy fusions are CPU artifacts
            if op.kind not in _BYTES_KINDS:
                continue
            if op.kind == "fusion" and op.name.startswith(
                    _CONVERT_FUSION_PREFIXES):
                continue
            if op.kind == "dynamic-update-slice" or (
                    op.kind == "fusion"
                    and op.name.startswith("dynamic-update-slice")):
                # in-place slice write: traffic = the update operand (the
                # smallest operand for dus-rooted fusions), NOT the whole
                # aliased buffer — critical for scan stashes and KV caches
                cand = [shape_bytes(types.get(o, "")) for o in op.operands]
                cand = [c for c in cand if c > 0]
                nb = 2 * min(cand) if cand else 0
            elif op.kind == "dynamic-slice":
                nb = 2 * shape_bytes(op.type_str)
            elif op.kind == "scatter" or (
                    op.kind == "fusion" and op.name.startswith("scatter")):
                # scatter-add RMW touches only the updated rows (operands:
                # target, indices, updates) — not the whole target buffer
                cand = sorted(shape_bytes(types.get(o, ""))
                              for o in op.operands)
                nb = 2 * (cand[-2] if len(cand) >= 2 else
                          (cand[-1] if cand else 0))
            else:
                nb = shape_bytes(op.type_str) + opnd_bytes
            bytes_accessed += m * nb
            if scope:
                scope_bytes[scope] += m * nb
    return HloCosts(flops=flops, bytes_accessed=bytes_accessed,
                    collectives=collectives, dot_flops_by_meta=dot_by_meta,
                    n_while=n_while, trip_counts=trip_counts,
                    scope_bytes=scope_bytes, scope_flops=scope_flops)
