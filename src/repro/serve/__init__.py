# repro.serve — batched serving engine (prefill + decode) over the family-
# uniform model API, with sharded KV caches / SSM states.

from repro.serve.engine import (ServeEngine, ServeConfig, Request,
                                route_kv_transfer)

__all__ = ["ServeEngine", "ServeConfig", "Request", "route_kv_transfer"]
