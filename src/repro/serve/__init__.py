# repro.serve — batched serving engine (prefill + decode) over the family-
# uniform model API, with sharded KV caches / SSM states.

from repro.serve.engine import ServeEngine, ServeConfig, Request

__all__ = ["ServeEngine", "ServeConfig", "Request"]
