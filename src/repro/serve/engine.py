"""Batched serving engine.

Static-batch engine (vLLM-style continuous batching is a scheduling layer
above this; the per-step compute below is what the decode_* dry-run shapes
lower): requests are padded into a fixed batch, prefilled once, then
decoded step-by-step with greedy/temperature sampling.  `serve_step` (the
jit'd decode) is the artifact the decode_32k / long_500k cells compile.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry as model_registry
from repro.models.common import Family, ModelConfig


@dataclass
class Request:
    prompt: list                     # token ids
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    max_len: int = 1024
    eos_id: int = -1                 # -1: never stop early


def make_serve_step(cfg: ModelConfig):
    """jit'd one-token decode step: (params, token, state) -> (tok, state)."""

    @jax.jit
    def step(params, token, state, temperature, rng):
        logits, state = model_registry.decode_step(params, token, cfg, state)
        lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)  # drop vocab pad
        greedy = jnp.argmax(lg, axis=-1)
        sampled = jax.random.categorical(
            rng, lg / jnp.maximum(temperature, 1e-6), axis=-1)
        tok = jnp.where(temperature > 0, sampled, greedy)
        return tok.astype(jnp.int32)[:, None], state

    return step


def make_prefill(cfg: ModelConfig):
    @jax.jit
    def pre(params, batch, state):
        return model_registry.prefill(params, batch, cfg, state)

    return pre


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._step = make_serve_step(cfg)
        self._prefill = make_prefill(cfg)

    def _pad_batch(self, requests: List[Request]):
        B = self.scfg.batch
        maxp = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, maxp), np.int32)
        for i, r in enumerate(requests):
            toks[i, maxp - len(r.prompt):] = r.prompt  # left-pad
        return jnp.asarray(toks)

    def run(self, requests: List[Request], *, seed: int = 0,
            extra: Optional[dict] = None) -> List[Request]:
        assert len(requests) <= self.scfg.batch
        while len(requests) < self.scfg.batch:
            requests.append(Request(prompt=[0], max_new_tokens=0))
        toks = self._pad_batch(requests)
        state = model_registry.make_decode_state(
            self.cfg, self.scfg.batch, self.scfg.max_len,
            **({"enc": None} if self.cfg.family != Family.ENCDEC else {}))
        batch = {"tokens": toks}
        if extra:
            batch.update(extra)
        logits, state = self._prefill(self.params, batch, state)
        tok = jnp.argmax(logits[:, -1, :self.cfg.vocab],
                         axis=-1).astype(jnp.int32)[:, None]
        rng = jax.random.PRNGKey(seed)
        temp = jnp.asarray(max(r.temperature for r in requests),
                           jnp.float32)
        n_steps = max(r.max_new_tokens for r in requests)
        done = np.zeros(self.scfg.batch, bool)
        for t in range(n_steps):
            for i, r in enumerate(requests):
                if not done[i] and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
                    if int(tok[i, 0]) == self.scfg.eos_id:
                        done[i] = True
                else:
                    done[i] = True
            if bool(done.all()):
                break
            rng, sub = jax.random.split(rng)
            tok, state = self._step(self.params, tok, state, temp, sub)
        return requests
