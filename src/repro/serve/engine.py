"""Batched serving engine.

Static-batch engine (vLLM-style continuous batching is a scheduling layer
above this; the per-step compute below is what the decode_* dry-run shapes
lower): requests are padded into a fixed batch, prefilled once, then
decoded step-by-step with greedy/temperature sampling.  `serve_step` (the
jit'd decode) is the artifact the decode_32k / long_500k cells compile.

Optional comm policy (repro.policy): multi-pod serving moves the prefill
KV cache to the decode replicas; `ServeConfig.comm_policy` routes that
transfer per batch through the unified PolicyEngine (DIRECT for small
latency-bound prompt batches, HIERARCHICAL once the KV volume makes the
pod-boundary links the bottleneck) — the same Algorithm-1 machinery the
Dragonfly substrate uses, fed by the ICI cost model on this container.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry as model_registry
from repro.models.common import Family, ModelConfig


@dataclass
class Request:
    prompt: list                     # token ids
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    max_len: int = 1024
    eos_id: int = -1                 # -1: never stop early
    #: repro.policy name routing the prefill->decode KV transfer
    #: (None: no policy, single-replica serving)
    comm_policy: Optional[str] = None
    n_pods: int = 2
    inner_chips: int = 256
    #: multi-allocation serving: the fabric-level tenant id of this
    #: engine.  KV-transfer decisions are keyed on the scoped site
    #: ``(allocation_id, "kv_transfer")`` so several ServeEngines sharing
    #: one PolicyEngine (see `comm_engine=` in __init__) keep independent
    #: Algorithm-1 automatons in one _SiteTable — the same tenant
    #: slicing the Dragonfly tenancy engine uses (docs/interference.md).
    allocation_id: Optional[str] = None


def route_kv_transfer(comm_engine, cost_model, nbytes: int, *,
                      site="kv_transfer", transfer=None, max_retries: int = 2,
                      backoff_s: float = 0.0, fallback_mode=None,
                      sleep=None):
    """One policy decision + model-fed feedback for a KV-cache transfer.

    Factored out of ServeEngine so multi-allocation serving paths (and
    tests) can route transfers against a SHARED engine with per-
    allocation scoped sites without building a model.

    Fault path (docs/faults.md): ``transfer`` (optional) is the callable
    that actually moves the bytes with the decided mode; a False return
    or an exception counts as a failed-path attempt.  The decided mode
    is retried up to ``max_retries`` times with exponential backoff
    (``backoff_s``, doubling; ``sleep`` is injectable for tests and
    defaults to ``time.sleep``), then the transfer falls back to
    ``fallback_mode`` — default ``CollectiveMode.DIRECT``, the
    single-path mode with no hierarchical staging to lose.  Feedback is
    published for the mode that finally carried the bytes, so the
    policy learns the fallback's cost, not the phantom cost of the
    failed decision.  ``transfer=None`` (default) keeps the legacy
    decide-and-predict behavior exactly.
    """
    from repro.policy import DecisionBatch
    mode = comm_engine.decide(DecisionBatch.single(nbytes, site=site))[0]
    used = mode
    if transfer is not None:
        def attempt(m):
            try:
                return transfer(m) is not False
            except Exception:
                return False

        if sleep is None:
            sleep = time.sleep
        ok = attempt(mode)
        delay = backoff_s
        for _ in range(max_retries):
            if ok:
                break
            if delay > 0.0:
                sleep(delay)
                delay *= 2.0
            ok = attempt(mode)
        if not ok:
            if fallback_mode is None:
                from repro.collectives.modes import CollectiveMode
                fallback_mode = CollectiveMode.DIRECT
            used = fallback_mode
            if not attempt(used):
                raise RuntimeError(
                    f"kv transfer failed: {max_retries} retries of "
                    f"{mode} and the {used} fallback all failed")
    perf = cost_model.predict(nbytes, used)
    comm_engine.bus.publish_flow_arrays(
        [perf.latency_cycles / 1e3], [perf.stall_cycles_per_flit],
        source="model")
    return used


def make_serve_step(cfg: ModelConfig):
    """jit'd one-token decode step: (params, token, state) -> (tok, state)."""

    @jax.jit
    def step(params, token, state, temperature, rng):
        logits, state = model_registry.decode_step(params, token, cfg, state)
        lg = logits[:, -1, :cfg.vocab].astype(jnp.float32)  # drop vocab pad
        greedy = jnp.argmax(lg, axis=-1)
        sampled = jax.random.categorical(
            rng, lg / jnp.maximum(temperature, 1e-6), axis=-1)
        tok = jnp.where(temperature > 0, sampled, greedy)
        return tok.astype(jnp.int32)[:, None], state

    return step


def make_prefill(cfg: ModelConfig):
    @jax.jit
    def pre(params, batch, state):
        return model_registry.prefill(params, batch, cfg, state)

    return pre


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 comm_engine=None):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._step = make_serve_step(cfg)
        self._prefill = make_prefill(cfg)
        self.comm_engine = self._cost_model = None
        #: [(kv_bytes, mode)] per run() — the KV-transfer schedule log
        self.policy_decisions: list = []
        if scfg.comm_policy or comm_engine is not None:
            from repro.collectives.modes import CollectiveMode
            from repro.collectives.selector import ICICostModel, MeshSpec
            from repro.policy import make_engine
            self._cost_model = ICICostModel(
                MeshSpec(n_pods=scfg.n_pods, inner_chips=scfg.inner_chips))
            if comm_engine is not None:
                # Multi-allocation serving: several engines share ONE
                # PolicyEngine; per-allocation scoped sites keep their
                # learned states separate (ISSUE: multi-allocation
                # backend_for).
                self.comm_engine = comm_engine
            else:
                self.comm_engine = make_engine(
                    scfg.comm_policy,
                    mode_a=CollectiveMode.HIERARCHICAL,
                    mode_b=CollectiveMode.DIRECT,
                    mode_a_alltoall=CollectiveMode.HIERARCHICAL,
                    static_mode=CollectiveMode.DIRECT)

    @property
    def kv_site(self):
        """Decision site for this engine's KV transfers.

        Scoped to the allocation when `ServeConfig.allocation_id` is set
        so co-tenant engines sharing a PolicyEngine don't pollute each
        other's per-site learned state; recover one tenant's view with
        `scoped_site_filter(allocation_id)`."""
        if self.scfg.allocation_id is not None:
            return (self.scfg.allocation_id, "kv_transfer")
        return "kv_transfer"

    def _kv_bytes(self, prompt_tokens: int) -> int:
        """KV cache volume of one prefilled batch (bf16, all layers)."""
        c = self.cfg
        heads_kv = getattr(c, "n_kv_heads", None) or \
            getattr(c, "n_heads", 1)
        head_dim = c.d_model // max(getattr(c, "n_heads", 1), 1)
        return int(2 * c.n_layers * heads_kv * head_dim
                   * prompt_tokens * 2)  # K+V, bf16

    def _route_kv_transfer(self, prompt_tokens: int):
        """One engine decision for this batch's prefill->decode transfer."""
        nbytes = self._kv_bytes(prompt_tokens)
        mode = route_kv_transfer(self.comm_engine, self._cost_model,
                                 nbytes, site=self.kv_site)
        self.policy_decisions.append((nbytes, mode))
        return mode

    def _pad_batch(self, requests: List[Request]):
        B = self.scfg.batch
        maxp = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, maxp), np.int32)
        for i, r in enumerate(requests):
            toks[i, maxp - len(r.prompt):] = r.prompt  # left-pad
        return jnp.asarray(toks)

    def run(self, requests: List[Request], *, seed: int = 0,
            extra: Optional[dict] = None) -> List[Request]:
        assert len(requests) <= self.scfg.batch
        while len(requests) < self.scfg.batch:
            requests.append(Request(prompt=[0], max_new_tokens=0))
        toks = self._pad_batch(requests)
        state = model_registry.make_decode_state(
            self.cfg, self.scfg.batch, self.scfg.max_len,
            **({"enc": None} if self.cfg.family != Family.ENCDEC else {}))
        batch = {"tokens": toks}
        if extra:
            batch.update(extra)
        if self.comm_engine is not None:
            self._route_kv_transfer(self.scfg.batch * toks.shape[1])
        logits, state = self._prefill(self.params, batch, state)
        tok = jnp.argmax(logits[:, -1, :self.cfg.vocab],
                         axis=-1).astype(jnp.int32)[:, None]
        rng = jax.random.PRNGKey(seed)
        temp = jnp.asarray(max(r.temperature for r in requests),
                           jnp.float32)
        n_steps = max(r.max_new_tokens for r in requests)
        done = np.zeros(self.scfg.batch, bool)
        for t in range(n_steps):
            for i, r in enumerate(requests):
                if not done[i] and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i, 0]))
                    if int(tok[i, 0]) == self.scfg.eos_id:
                        done[i] = True
                else:
                    done[i] = True
            if bool(done.all()):
                break
            rng, sub = jax.random.split(rng)
            tok, state = self._step(self.params, tok, state, temp, sub)
        return requests
