"""Loss + jit'd train step with explicit in/out shardings.

Cross-entropy streams over the sharded vocab dim (take_along_axis +
logsumexp in fp32) — the [B,S,V] logits stay bf16 and vocab-sharded, never
materialized replicated (paligemma's 257k vocab would be ~1 PB replicated
at train_4k).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import registry as model_registry
from repro.models.common import ModelConfig
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatch: int = 0        # 0 = no microbatching; else per-step split
    z_loss: float = 1e-4       # logit-norm regularizer (numerics at scale)


def auto_microbatch(cfg: ModelConfig, global_batch: int, seq_len: int,
                    dp_size: int, *, budget_bytes: float = 3e9) -> int:
    """Pick a microbatch size so the remat stash (~per-layer saved
    activations x depth) fits the budget.  Returns 0 (no microbatching)
    when the full batch already fits.  The microbatch stays a multiple of
    dp_size so each shard keeps >=1 row."""
    from repro.models.common import Family

    depth = cfg.n_layers + (cfg.n_encoder_layers or 0)
    if cfg.family == Family.HYBRID:
        depth += max(cfg.n_layers // cfg.shared_attn_period, 0)
    bytes_per_row = seq_len * cfg.d_model * 2 * max(depth, 1) * 1.3
    # family factors: SSD's quadratic-within-chunk buffers ([Q,Q,H] per
    # chunk) and MoE dispatch/capacity tensors dominate the plain-residual
    # estimate
    if cfg.family in (Family.SSM, Family.HYBRID) and cfg.ssm_chunk:
        d_inner = cfg.ssm_expand * cfg.d_model
        heads = max(d_inner // cfg.ssm_head_dim, 1)
        bytes_per_row *= 1.0 + (2.0 * cfg.ssm_chunk * heads * 4.0
                                / (cfg.d_model * 2.0))
    if cfg.family == Family.MOE:
        bytes_per_row *= 3.0
    rows_budget = max(int(budget_bytes / bytes_per_row), 1) * dp_size
    if rows_budget >= global_batch:
        return 0
    mb = dp_size
    while mb * 2 <= rows_budget and global_batch % (mb * 2) == 0:
        mb *= 2
    return mb


def loss_fn(logits, labels, *, z_loss: float = 0.0):
    """logits [B,S,V] (any float dtype), labels [B,S] int32 -> scalar f32."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)                      # [B,S]
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    if z_loss:
        ce = ce + z_loss * jnp.square(lse).mean()
    return ce


def _step_loss(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    logits, aux = model_registry.train_forward(params, batch, cfg)
    labels = batch["labels"]
    ce = loss_fn(logits, labels, z_loss=tcfg.z_loss)
    total = ce + cfg.router_aux_coef * aux
    return total, {"ce": ce, "aux": aux}


def train_step(params, opt_state: AdamWState, batch, *, cfg: ModelConfig,
               tcfg: TrainConfig):
    """One optimizer step.  Gradients are averaged over the dp axes by
    GSPMD (batch is dp-sharded; the partitioner inserts the all-reduce —
    the baseline "DIRECT" schedule; grad_comm.py provides the explicit
    alternatives for the §Perf hillclimb)."""
    if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
        return _train_step_micro(params, opt_state, batch, cfg=cfg,
                                 tcfg=tcfg)
    (loss, metrics), grads = jax.value_and_grad(
        _step_loss, has_aux=True)(params, batch, cfg, tcfg)
    new_params, new_opt, opt_metrics = adamw_update(
        tcfg.optimizer, params, grads, opt_state)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return new_params, new_opt, metrics


def _train_step_micro(params, opt_state, batch, *, cfg, tcfg):
    """Gradient accumulation over microbatches (lax.scan over splits)."""
    B = batch["tokens"].shape[0]
    mb = tcfg.microbatch
    n = B // mb

    def reshape(x):
        from repro.models.common import constrain, dp_spec
        r = x.reshape((n, mb) + x.shape[1:])
        # keep each *microbatch* dp-sharded (the reshape otherwise leaves
        # the scan axis sharded => every step gathers its slice)
        return constrain(r, None, dp_spec())

    scanned = jax.tree_util.tree_map(reshape, batch)

    def body(acc, mbatch):
        (loss, metrics), grads = jax.value_and_grad(
            _step_loss, has_aux=True)(params, mbatch, cfg, tcfg)
        acc_g, acc_l = acc
        acc_g = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        return (acc_g, acc_l + loss), metrics

    zero_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss_sum), metrics = jax.lax.scan(
        body, (zero_g, jnp.zeros((), jnp.float32)), scanned)
    grads = jax.tree_util.tree_map(lambda g: g / n, grads)
    new_params, new_opt, opt_metrics = adamw_update(
        tcfg.optimizer, params, grads, opt_state)
    last = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    out_metrics = dict(last, loss=loss_sum / n, **opt_metrics)
    return new_params, new_opt, out_metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                    param_shardings, input_shardings, opt_shardings=None):
    """jit-wrapped step with explicit shardings (dry-run lowers this)."""
    import jax.tree_util as jtu

    if opt_shardings is None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        scalar = NamedSharding(mesh, P())
        opt_shardings = AdamWState(step=scalar, m=param_shardings,
                                   v=jtu.tree_map(lambda s: s,
                                                  param_shardings))
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P())
    metric_shardings = None  # let jit infer (all replicated scalars)
    fn = partial(train_step, cfg=cfg, tcfg=tcfg)
    return jax.jit(
        fn,
        in_shardings=(param_shardings, opt_shardings, input_shardings),
        out_shardings=(param_shardings, opt_shardings, metric_shardings),
        donate_argnums=(0, 1),
    )
