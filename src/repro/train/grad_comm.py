"""Gradient communication: bucketing, compression, error feedback.

Distributed-optimization toolkit for the multi-pod mesh:

  * bucketize: flatten the grad pytree into fixed-size buckets issued at
    scanned-block boundaries so XLA's latency-hiding scheduler overlaps
    bucket k's reduce with block k-1's compute;
  * compress_decompress: bf16 wire format with fp32 error-feedback
    residuals (the classic EF trick: quantization error is carried to the
    next step, keeping convergence unbiased);
  * the schedule choice (DIRECT vs HIERARCHICAL) per bucket goes through
    the paper's Algorithm 1 (collectives/selector.py) using the bucket's
    byte size — the cumulative-size gate transfers verbatim.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives.modes import CollectiveMode
from repro.collectives.selector import AppAwareSelector


@dataclass(frozen=True)
class GradCommConfig:
    bucket_bytes: int = 32 * 1024 * 1024
    compress: bool = True          # bf16 on the wire
    error_feedback: bool = True


def bucketize(grads, bucket_bytes: int):
    """-> list of (leaf_indices, slices) grouping leaves into buckets of
    ~bucket_bytes (greedy, in tree order so locality follows layer order)."""
    leaves = jax.tree_util.tree_leaves(grads)
    buckets, cur, cur_bytes = [], [], 0
    for i, leaf in enumerate(leaves):
        nb = int(np.prod(leaf.shape)) * 4
        if cur and cur_bytes + nb > bucket_bytes:
            buckets.append(tuple(cur))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nb
    if cur:
        buckets.append(tuple(cur))
    return buckets


def compress_decompress(g, residual):
    """Error-feedback bf16 compression of one leaf.

    wire = bf16(g + residual); new_residual = (g + residual) - wire.
    Returns (wire_value_as_f32, new_residual)."""
    acc = g.astype(jnp.float32) + residual
    wire = acc.astype(jnp.bfloat16)
    back = wire.astype(jnp.float32)
    return back, acc - back


def select_bucket_modes(selector: AppAwareSelector, grads,
                        cfg: GradCommConfig) -> list:
    """Algorithm 1 per bucket: returns [(bucket, CollectiveMode), ...].

    Called once per step on the host; the chosen modes parameterize the
    shard_map reduce for each bucket.  ONE vectorized engine call decides
    every bucket of the step (repro.policy batch path), then the cost
    model self-feeds the batch (dry-run telemetry)."""
    buckets = bucketize(grads, cfg.bucket_bytes)
    leaves = jax.tree_util.tree_leaves(grads)
    sizes = [sum(int(np.prod(leaves[i].shape)) for i in b)
             * (2 if cfg.compress else 4) for b in buckets]
    modes = selector.decide_batch(sizes, site="grad_comm")
    selector.update_predicted(sizes)
    return list(zip(buckets, modes))


def reduce_bucketed(grads, mesh, selector: AppAwareSelector,
                    cfg: GradCommConfig, residuals=None):
    """Explicit bucketed + compressed + app-aware-scheduled grad reduce.

    Baseline GSPMD inserts one flat all-reduce per tensor; this path is
    the §Perf alternative measured in the hillclimb.  Returns
    (reduced_grads, new_residuals, modes)."""
    from repro.collectives.allreduce import grad_allreduce

    if residuals is None and cfg.error_feedback and cfg.compress:
        residuals = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    if cfg.compress:
        pairs = jax.tree_util.tree_map(compress_decompress, grads,
                                       residuals)
        wire = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                         is_leaf=lambda x: isinstance(x, tuple))
    else:
        wire, new_res = grads, residuals

    modes = select_bucket_modes(selector, wire, cfg)
    # one reduce per mode class (buckets of the same mode share a schedule)
    chosen = {m for _, m in modes} or {CollectiveMode.DIRECT}
    mode = (CollectiveMode.HIERARCHICAL
            if CollectiveMode.HIERARCHICAL in chosen
            else CollectiveMode.DIRECT)
    reduced = grad_allreduce(wire, mesh, mode=mode)
    return reduced, new_res, modes
