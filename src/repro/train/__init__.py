# repro.train — optimizer, loss, train step, gradient communication.

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train.train_step import TrainConfig, make_train_step, loss_fn
from repro.train.grad_comm import GradCommConfig, compress_decompress, bucketize

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "TrainConfig", "make_train_step", "loss_fn",
    "GradCommConfig", "compress_decompress", "bucketize",
]
