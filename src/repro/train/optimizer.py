"""AdamW (decoupled weight decay) + schedules, pure JAX pytrees.

Optimizer state mirrors the parameter sharding (m/v inherit the param
specs), so TP/EP-sharded tensors keep their moments sharded too.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """-> (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
