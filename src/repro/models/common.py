"""Shared model configuration and primitive layers.

One ModelConfig covers every assigned architecture family; family-specific
fields are ignored elsewhere.  All parameters are created as stacked
per-layer pytrees (leading dim = n_layers) so the layer stack runs under
jax.lax.scan — this keeps compiled HLO size O(1) in depth, which matters
for the 512-device dry-run on a single-core CPU container.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENCDEC = "encdec"
    VLM = "vlm"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int            # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0       # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    act: str = "silu"           # silu => SwiGLU; gelu => GeGLU/plain
    glu: bool = True
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0        # per-expert hidden size
    router_aux_coef: float = 0.01
    #: "einsum" = GShard-style dense dispatch (baseline); "ep" = shard_map
    #: expert-parallel all-to-all (§Perf; needs n_experts % ep_size == 0)
    moe_impl: str = "einsum"
    #: a2a schedule for the EP path: "direct" (one-phase) or "hierarchical"
    #: (pod-local first) — the knob Algorithm 1 drives on multi-pod meshes
    moe_a2a_mode: str = "direct"
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- hybrid (zamba2-like shared attention blocks) ---
    shared_attn_period: int = 6
    # --- enc-dec (whisper backbone; conv frontend is a stub) ---
    n_encoder_layers: int = 0
    encoder_frames: int = 1500
    # --- vlm (paligemma backbone; SigLIP frontend is a stub) ---
    img_tokens: int = 0
    # --- compute ---
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    #: "full" recomputes everything; "dots" saves non-batched matmul
    #: outputs (qkv/mlp projections) and recomputes only elementwise +
    #: attention internals — the §Perf middle ground between 1.33x
    #: recompute FLOPs and a full activation stash
    remat_policy: str = "full"
    #: embeddings/heads are padded to a multiple of this (Megatron-style)
    #: so the vocab dim shards evenly over the model axis
    pad_vocab_multiple: int = 128
    # documented skip: pure full-attention archs cannot run long_500k
    supports_long_context: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        m = max(self.pad_vocab_multiple, 1)
        return -(-self.vocab // m) * m

    @property
    def is_attention_free(self) -> bool:
        return self.family == Family.SSM

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return replace(self, **kw)


def checkpoint_wrap(fn, cfg: ModelConfig):
    """jax.checkpoint with the config's remat policy (or passthrough)."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies
            .dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------- utils
def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return _init(key, (d_in, d_out), scale, dtype)


def stacked(keys, fn):
    """Stack per-layer params along a new leading axis (scan-compatible)."""
    outs = [fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *outs)


# ------------------------------------------------------------------- norms
def rmsnorm(x, gamma, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def layernorm(x, gamma, beta, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma + beta


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


# ----------------------------------------------------- activation sharding
def mesh_axes() -> dict:
    """Axis sizes of the active abstract mesh ({} outside set_mesh)."""
    from repro.compat import abstract_axis_sizes
    return abstract_axis_sizes()


def dp_spec():
    axes = mesh_axes()
    if "pod" in axes and "data" in axes:
        return ("pod", "data")
    if "data" in axes:
        return "data"
    return None


def constrain(x, *spec):
    """Divisibility-checked with_sharding_constraint; no-op off-mesh.

    Each spec entry is None, an axis name, or a tuple of axis names; any
    entry whose axes are missing from the active mesh or whose dim does not
    divide evenly is dropped to None.  This is how model code pins
    activation layouts (e.g. attention heads over "model" when divisible,
    else sequence/context parallelism) without importing mesh objects."""
    from jax.sharding import PartitionSpec as P

    axes = mesh_axes()
    if not axes:
        return x
    cleaned = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            cleaned.append(None)
            continue
        group = ax if isinstance(ax, tuple) else (ax,)
        if not all(a in axes for a in group):
            cleaned.append(None)
            continue
        size = 1
        for a in group:
            size *= axes[a]
        cleaned.append(ax if dim % size == 0 and dim >= size else None)
    cleaned += [None] * (x.ndim - len(cleaned))
    return jax.lax.with_sharding_constraint(x, P(*cleaned))
