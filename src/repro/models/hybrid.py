"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block.

One set of attention+MLP weights (the "shared block", arXiv:2411.15242) is
applied every ``cfg.shared_attn_period`` Mamba2 layers.  Structure:

    super-block a (a = 0..n_super-1):
        [shared attention block]   (skipped for a == 0)
        `period` Mamba2 layers
    trailing:  n_layers % period Mamba2 layers

The super-blocks are scanned (stacked params reshaped [n_super, period, ..])
so HLO stays O(1) in depth, and each application point's KV cache is a scan
xs/ys slice — nothing per-*layer* is ever stacked, which keeps the 500k-
token decode cache at [n_apps, B, S, Hkv, hd] only.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, checkpoint_wrap,
                                 dense_init, rmsnorm, stacked)
from repro.models.mamba2 import (
    Mamba2State, init_mamba2, init_mamba2_state, mamba2_decode,
    mamba2_forward,
)
from repro.models.mlp import init_mlp, mlp


def hybrid_layout(cfg: ModelConfig):
    """(n_super, period, n_trailing, n_apps)."""
    period = cfg.shared_attn_period
    n_super = cfg.n_layers // period
    rem = cfg.n_layers % period
    return n_super, period, rem, max(n_super - 1, 0)


def _init_mamba_layer(key, cfg: ModelConfig):
    return {"ln": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "mamba": init_mamba2(key, cfg)}


def init_hybrid(key, cfg: ModelConfig):
    n_super, period, rem, _ = hybrid_layout(cfg)
    ks = jax.random.split(key, 6)
    main = stacked(jax.random.split(ks[1], n_super * period),
                   lambda k: _init_mamba_layer(k, cfg))
    main = jax.tree_util.tree_map(
        lambda x: x.reshape((n_super, period) + x.shape[1:]), main)
    p = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model))
                  * 0.02).astype(cfg.param_dtype),
        "main": main,
        "shared": {
            "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "attn": attn.init_attn(ks[2], cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "mlp": init_mlp(ks[3], cfg),
        },
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab_padded,
                              cfg.param_dtype, scale=0.02),
    }
    if rem:
        p["trailing"] = stacked(jax.random.split(ks[5], rem),
                                lambda k: _init_mamba_layer(k, cfg))
    return p


def _shared_block(p, x, cfg: ModelConfig, positions):
    h = rmsnorm(x, p["ln1"].astype(cfg.dtype), cfg.norm_eps)
    q, k, v = attn.qkv_project(p["attn"], h, cfg, positions)
    o = attn.gqa_attend(q, k, v, causal=True, q_positions=positions,
                        kv_positions=positions)
    x = x + attn.attn_output(p["attn"], o, cfg)
    h = rmsnorm(x, p["ln2"].astype(cfg.dtype), cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg), (k, v)


def _shared_block_decode(p, x, cfg, ck, cv, pos):
    B = x.shape[0]
    h = rmsnorm(x, p["ln1"].astype(cfg.dtype), cfg.norm_eps)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k, v = attn.qkv_project(p["attn"], h, cfg, positions)
    ck, cv = attn.cache_update(ck, cv, k, v, pos)
    valid = jnp.broadcast_to(pos + 1, (B,))
    o = attn.gqa_attend(q, ck, cv, causal=False, kv_valid_len=valid)
    x = x + attn.attn_output(p["attn"], o, cfg)
    h = rmsnorm(x, p["ln2"].astype(cfg.dtype), cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg), ck, cv


def _mamba_stack_fwd(layers, x, cfg):
    def inner(h, lp):
        hn = rmsnorm(h, lp["ln"].astype(cfg.dtype), cfg.norm_eps)
        y, _ = mamba2_forward(lp["mamba"], hn, cfg)
        return h + y, ()
    x, _ = jax.lax.scan(inner, x, layers)
    return x


def hybrid_apply(params, tokens, cfg: ModelConfig):
    """Training forward: tokens [B,S] -> (logits, aux=0)."""
    n_super, period, rem, _ = hybrid_layout(cfg)
    x = params["embed"].astype(cfg.dtype)[tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    shared = params["shared"]
    flags = jnp.arange(n_super) > 0

    def super_body(h, inp):
        layers, flag = inp

        def with_attn(h):
            out, _ = _shared_block(shared, h, cfg, positions)
            return out

        h = jax.lax.cond(flag, with_attn, lambda v: v, h)
        return _mamba_stack_fwd(layers, h, cfg), ()

    body = checkpoint_wrap(super_body, cfg)
    x, _ = jax.lax.scan(body, x, (params["main"], flags))
    if rem:
        x = _mamba_stack_fwd(params["trailing"], x, cfg)
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.dtype))
    return logits, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ serving
class HybridDecodeState(NamedTuple):
    mamba_main: Mamba2State      # [n_super, period, B, ...]
    mamba_trailing: Mamba2State  # [rem, B, ...] (rem may be 0)
    attn_cache: attn.KVCache     # [n_super, B, Smax, Hkv, hd] (slot0 unused)
    pos: jax.Array


def hybrid_make_state(cfg: ModelConfig, batch: int,
                      max_len: int) -> HybridDecodeState:
    n_super, period, rem, _ = hybrid_layout(cfg)
    m = init_mamba2_state(cfg, batch)

    def tile(pref):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(pref + x.shape, x.dtype), m)

    return HybridDecodeState(
        mamba_main=tile((n_super, period)),
        mamba_trailing=tile((max(rem, 1),)),
        attn_cache=attn.init_cache(cfg, batch, max_len, n_layers=n_super),
        pos=jnp.zeros((), jnp.int32),
    )


def _mamba_stack_prefill(layers, states: Mamba2State, x, cfg):
    def inner(h, inp):
        lp, st = inp
        hn = rmsnorm(h, lp["ln"].astype(cfg.dtype), cfg.norm_eps)
        y, new_st = mamba2_forward(lp["mamba"], hn, cfg, init_state=st)
        return h + y, new_st
    x, new_states = jax.lax.scan(inner, x, (layers, states))
    return x, new_states


def hybrid_prefill(params, tokens, cfg: ModelConfig,
                   state: "HybridDecodeState"):
    """Process the prompt, filling Mamba states and shared-attn caches."""
    n_super, period, rem, _ = hybrid_layout(cfg)
    x = params["embed"].astype(cfg.dtype)[tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    shared = params["shared"]
    flags = jnp.arange(n_super) > 0
    zero = jnp.zeros((), jnp.int32)

    def super_body(h, inp):
        layers, flag, m_st, ck, cv = inp

        def with_attn(args):
            h, ck, cv = args
            out, (k, v) = _shared_block(shared, h, cfg, positions)
            ck, cv = attn.cache_update(ck, cv, k, v, zero)
            return out, ck, cv

        h, ck, cv = jax.lax.cond(flag, with_attn,
                                 lambda args: args, (h, ck, cv))
        h, new_m = _mamba_stack_prefill(layers, m_st, h, cfg)
        return h, (new_m, ck, cv)

    body = checkpoint_wrap(super_body, cfg)
    x, (new_main, cks, cvs) = jax.lax.scan(
        body, x, (params["main"], flags, state.mamba_main,
                  state.attn_cache.k, state.attn_cache.v))
    new_trailing = state.mamba_trailing
    if rem:
        x, new_trailing = _mamba_stack_prefill(
            params["trailing"], state.mamba_trailing, x, cfg)
    x = rmsnorm(x[:, -1:, :], params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.dtype))
    return logits, HybridDecodeState(
        mamba_main=new_main,
        mamba_trailing=new_trailing,
        attn_cache=attn.KVCache(k=cks, v=cvs,
                                length=jnp.full_like(
                                    state.attn_cache.length, S)),
        pos=jnp.array(S, jnp.int32))


def _mamba_stack_decode(layers, states: Mamba2State, x, cfg):
    def inner(h, inp):
        lp, st = inp
        hn = rmsnorm(h, lp["ln"].astype(cfg.dtype), cfg.norm_eps)
        y, new_st = mamba2_decode(lp["mamba"], hn, st, cfg)
        return h + y, new_st
    x, new_states = jax.lax.scan(inner, x, (layers, states))
    return x, new_states


def hybrid_decode_step(params, token, cfg: ModelConfig,
                       state: HybridDecodeState):
    """token [B,1] -> (logits, new state).  O(1) in context for the Mamba
    backbone; shared-attention caches are [n_apps] slices only."""
    n_super, period, rem, _ = hybrid_layout(cfg)
    x = params["embed"].astype(cfg.dtype)[token]
    shared = params["shared"]
    pos = state.pos
    flags = jnp.arange(n_super) > 0

    def super_body(h, inp):
        layers, flag, mamba_st, ck, cv = inp

        def with_attn(args):
            h, ck, cv = args
            return _shared_block_decode(shared, h, cfg, ck, cv, pos)

        h, ck, cv = jax.lax.cond(flag, with_attn,
                                 lambda args: args, (h, ck, cv))
        h, new_m = _mamba_stack_decode(layers, mamba_st, h, cfg)
        return h, (new_m, ck, cv)

    x, (new_main, cks, cvs) = jax.lax.scan(
        super_body, x,
        (params["main"], flags, state.mamba_main,
         state.attn_cache.k, state.attn_cache.v))
    new_trailing = state.mamba_trailing
    if rem:
        x, new_trailing = _mamba_stack_decode(params["trailing"],
                                              state.mamba_trailing, x, cfg)
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.dtype))
    return logits, HybridDecodeState(
        mamba_main=new_main,
        mamba_trailing=new_trailing,
        attn_cache=attn.KVCache(k=cks, v=cvs,
                                length=state.attn_cache.length + 1),
        pos=pos + 1)
