"""Decoder-only transformer LM (dense and MoE variants).

Blocks are stacked along a leading layer axis and executed with
``jax.lax.scan`` (+ optional remat) so the compiled HLO is O(1) in depth.
Used directly by the dense/moe archs and as the backbone for the VLM
(prefix-LM mask) — the whisper enc-dec and the zamba2 hybrid compose these
same primitives in their own modules.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (Family, ModelConfig, checkpoint_wrap,
                                 dense_init, rmsnorm, stacked)
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_einsum


# ------------------------------------------------------------------- blocks
def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "attn": attn.init_attn(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if cfg.family in (Family.MOE,):
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def block_forward(p, x, cfg: ModelConfig, positions, *,
                  prefix_len: Optional[int] = None):
    """Training/prefill block: full-sequence causal (or prefix-LM) attn."""
    h = rmsnorm(x, p["ln1"].astype(cfg.dtype), cfg.norm_eps)
    q, k, v = attn.qkv_project(p["attn"], h, cfg, positions)
    # prefix_len: prefix-LM (paligemma) — the image prefix attends
    # bidirectionally, the text suffix causally
    o = attn.gqa_attend(q, k, v, causal=True, q_positions=positions,
                        kv_positions=positions, prefix_len=prefix_len)
    x = x + attn.attn_output(p["attn"], o, cfg)
    h = rmsnorm(x, p["ln2"].astype(cfg.dtype), cfg.norm_eps)
    if "moe" in p:
        if cfg.moe_impl == "ep":
            from repro.collectives.moe_ep import moe_ep
            from repro.collectives.modes import CollectiveMode
            mode = (CollectiveMode.HIERARCHICAL
                    if cfg.moe_a2a_mode == "hierarchical"
                    else CollectiveMode.DIRECT)
            y, aux = moe_ep(p["moe"], h, cfg, mode=mode)
        else:
            y, aux = moe_einsum(p["moe"], h, cfg)
    else:
        y, aux = mlp(p["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + y, (k, v, aux)


def block_decode(p, x, cfg: ModelConfig, cache_k, cache_v, pos):
    """One-token decode against a filled KV cache.

    x: [B,1,D]; cache_k/v: [B,Smax,Hkv,hd]; pos: [] int32 current position.
    """
    B = x.shape[0]
    h = rmsnorm(x, p["ln1"].astype(cfg.dtype), cfg.norm_eps)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k, v = attn.qkv_project(p["attn"], h, cfg, positions)
    ck, cv = attn.cache_update(cache_k, cache_v, k, v, pos)
    valid = jnp.broadcast_to(pos + 1, (B,))
    o = attn.gqa_attend(q, ck, cv, causal=False, kv_valid_len=valid)
    x = x + attn.attn_output(p["attn"], o, cfg)
    h = rmsnorm(x, p["ln2"].astype(cfg.dtype), cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_einsum(p["moe"], h, cfg)
    else:
        y = mlp(p["mlp"], h, cfg)
    return x + y, ck, cv


# ----------------------------------------------------------------------- LM
def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model))
                  * 0.02).astype(cfg.param_dtype),
        "blocks": stacked(jax.random.split(ks[1], cfg.n_layers),
                          partial(init_block, cfg=cfg)),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded,
                                       cfg.param_dtype, scale=0.02)
    return params


def _scan_blocks(params, x, cfg: ModelConfig, positions, prefix_len=None):
    def body(carry, layer_params):
        h, aux = carry
        h, (_, _, a) = block_forward(layer_params, h, cfg, positions,
                                     prefix_len=prefix_len)
        return (h, aux + a), ()

    body_fn = checkpoint_wrap(body, cfg)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def lm_apply(params, tokens, cfg: ModelConfig, *, extra_embeds=None,
             prefix_len=None):
    """tokens: [B,S] -> (logits [B,S,V] (cfg.dtype), aux_loss).

    extra_embeds: optional [B,P,D] prefix (VLM image / audio stub) that is
    prepended to the token embeddings.
    """
    x = params["embed"].astype(cfg.dtype)[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, aux = _scan_blocks(params, x, cfg, positions, prefix_len=prefix_len)
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    head = (params["embed"] if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, head)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, aux


# ------------------------------------------------------------------ serving
class LMDecodeState(NamedTuple):
    cache: attn.KVCache  # stacked [L, ...]
    pos: jax.Array       # [] int32


def lm_make_state(cfg: ModelConfig, batch: int, max_len: int) -> LMDecodeState:
    return LMDecodeState(cache=attn.init_cache(cfg, batch, max_len),
                         pos=jnp.zeros((), jnp.int32))


def lm_prefill(params, tokens, cfg: ModelConfig, state: LMDecodeState,
               *, extra_embeds=None, prefix_len=None):
    """Fill the cache with the prompt; returns (last-token logits, state)."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(carry, inp):
        h = carry
        layer_params, ck, cv = inp
        h, (k, v, _) = block_forward(layer_params, h, cfg, positions,
                                     prefix_len=prefix_len)
        ck, cv = attn.cache_update(ck, cv, k, v, jnp.zeros((), jnp.int32))
        return h, (ck, cv)

    body_fn = checkpoint_wrap(body, cfg)
    x, (ck, cv) = jax.lax.scan(
        body_fn, x, (params["blocks"], state.cache.k, state.cache.v))
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    head = (params["embed"] if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    last = x[:, -1:, :]
    logits = (jnp.einsum("bsd,vd->bsv", last, head) if cfg.tie_embeddings
              else jnp.einsum("bsd,dv->bsv", last, head))
    new_state = LMDecodeState(
        cache=attn.KVCache(k=ck, v=cv,
                           length=jnp.full((B,), S, jnp.int32)),
        pos=jnp.array(S, jnp.int32))
    return logits, new_state


def lm_decode_step(params, token, cfg: ModelConfig, state: LMDecodeState):
    """token: [B,1] int32 -> (logits [B,1,V], new state)."""
    x = params["embed"].astype(cfg.dtype)[token]

    def body(h, inp):
        layer_params, ck, cv = inp
        h, ck, cv = block_decode(layer_params, h, cfg, ck, cv, state.pos)
        return h, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["blocks"], state.cache.k, state.cache.v))
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    head = (params["embed"] if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    logits = (jnp.einsum("bsd,vd->bsv", x, head) if cfg.tie_embeddings
              else jnp.einsum("bsd,dv->bsv", x, head))
    new_state = LMDecodeState(
        cache=attn.KVCache(k=ck, v=cv, length=state.cache.length + 1),
        pos=state.pos + 1)
    return logits, new_state
