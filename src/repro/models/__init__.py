# repro.models — composable model definitions for all assigned architecture
# families: dense GQA transformers, MoE, Mamba2 (SSD), hybrid (zamba2-like),
# encoder-decoder (whisper backbone), and VLM (paligemma backbone).
#
# All models are pure functions over parameter pytrees with stacked
# (lax.scan-able) block parameters, so the production train/serve graphs
# stay small enough to compile for 512-device meshes on one CPU.

from repro.models.common import ModelConfig, Family
from repro.models.registry import (init_params, train_forward,
                                   make_decode_state, decode_step, prefill)

__all__ = [
    "ModelConfig", "Family", "init_params", "train_forward",
    "make_decode_state", "decode_step", "prefill",
]
