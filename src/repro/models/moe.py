"""Mixture-of-Experts layer (GShard-style dense dispatch + shared experts).

Two dispatch implementations:

  * ``einsum`` (default, used by the baseline dry-run): capacity-bounded
    one-hot dispatch/combine einsums.  Numerically standard and GSPMD-
    shardable out of the box, but the dispatch einsums add O(T*E*C*D) HLO
    FLOPs — the §Perf hillclimb for the MoE cells replaces it with the
    shard_map expert-parallel path below.

  * ``shard_map`` EP path (repro/collectives/moe_ep.py): local top-k,
    all-to-all token exchange (DIRECT or HIERARCHICAL schedule — this is
    where the paper's application-aware routing arbitration plugs in),
    dense per-expert matmuls, all-to-all back.

Router: softmax gating, top-k, load-balancing auxiliary loss (Switch/GShard
style), optional always-on shared experts (qwen2-moe).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, activation, dense_init
from repro.models.mlp import init_mlp, mlp

MOE_GROUP = 512  # tokens per dispatch group (capacity is per group)


def init_moe(key, cfg: ModelConfig):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, d, f)) * scale
                 ).astype(cfg.param_dtype),
        "w_gate": (jax.random.normal(ks[2], (E, d, f)) * scale
                   ).astype(cfg.param_dtype),
        "w_out": (jax.random.normal(ks[3], (E, f, d)) / math.sqrt(f)
                  ).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(
            ks[4], cfg, d_ff=(cfg.d_ff_expert or cfg.d_ff)
            * cfg.n_shared_experts)
    return p


def router_probs(p, x, cfg: ModelConfig):
    """fp32 router. x: [T,D] -> probs [T,E]."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    return jax.nn.softmax(logits, axis=-1)


def topk_dispatch(probs, cfg: ModelConfig, capacity: int):
    """Capacity-bounded top-k assignment.

    probs: [G, S, E] (grouped tokens). Returns:
      dispatch [G,S,E,C] in {0,1}, combine [G,S,E,C] (gate-weighted),
      aux loss scalar.
    """
    G, S, E = probs.shape
    k = cfg.top_k
    topv, topi = jax.lax.top_k(probs, k)              # [G,S,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    counts = jnp.zeros((G, E), jnp.int32)
    disp = jnp.zeros((G, S, E, capacity), jnp.float32)
    comb = jnp.zeros((G, S, E, capacity), jnp.float32)
    for j in range(k):                                 # k is small (<=8)
        oh = jax.nn.one_hot(topi[..., j], E, dtype=jnp.int32)   # [G,S,E]
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # [G,S,E]
        keep = (pos < capacity) & (oh > 0)
        pos_c = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                               capacity, dtype=jnp.float32)     # [G,S,E,C]
        sel = keep.astype(jnp.float32)[..., None] * pos_c
        disp = disp + sel
        comb = comb + sel * topv[..., j][..., None, None]
        counts = counts + oh.sum(axis=1)

    # load-balance auxiliary loss (Switch): E * mean_e(frac_e * prob_e)
    me = probs.mean(axis=(0, 1))                       # [E]
    top1 = jax.nn.one_hot(topi[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(me * top1)
    return disp, comb, aux


def moe_einsum(p, x, cfg: ModelConfig):
    """x: [B,S,D] -> (y, aux_loss). GShard-style grouped dense dispatch."""
    B, S, D = x.shape
    dt = cfg.dtype
    T = B * S
    xg = x.reshape(T, D)
    g = max(1, T // MOE_GROUP)
    while T % g:
        g -= 1
    Sg = T // g
    probs = router_probs(p, xg, cfg).reshape(g, Sg, cfg.n_experts)
    capacity = max(cfg.top_k, int(math.ceil(
        Sg * cfg.top_k * 1.25 / cfg.n_experts)))
    disp, comb, aux = topk_dispatch(probs, cfg, capacity)
    xt = xg.reshape(g, Sg, D)
    # dispatch: [g,s,e,c] x [g,s,d] -> [e,g,c,d]
    xe = jnp.einsum("gsec,gsd->egcd", disp.astype(dt), xt)
    h = jnp.einsum("egcd,edf->egcf", xe, p["w_in"].astype(dt))
    gate = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"].astype(dt))
    h = activation(gate, cfg.act) * h
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_out"].astype(dt))
    y = jnp.einsum("gsec,egcd->gsd", comb.astype(dt), ye)
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x, cfg)
    return y, aux.astype(jnp.float32)
