"""Mamba2 block — State Space Duality (SSD), arXiv:2405.21060.

Chunked SSD algorithm (the quadratic-within-chunk / linear-across-chunk
decomposition).  This pure-jnp implementation is the oracle for the Pallas
``ssd_scan`` kernel and the production path on CPU; state-passing prefill
and O(1) decode make the 500k-token long-context shapes tractable (DESIGN.md
§4: SSM/hybrid archs run `long_500k`, full-attention archs skip it).

Projections are SPLIT (w_z, w_x, w_b, w_c, w_dt + per-part depthwise conv)
rather than fused like the reference CUDA code: each output dim then has a
single semantic role, so tensor-parallel sharding of d_inner never slices
across concatenated segments (sharding/partition.py relies on this).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rmsnorm


# ----------------------------------------------------------------- SSD core
def ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:      [B,S,H,P]   (dt folded here)
    dt:     [B,S,H]     (positive, post-softplus)
    a_log:  [H]         A = -exp(a_log)
    b_mat:  [B,S,H,N]   (groups already broadcast to heads)
    c_mat:  [B,S,H,N]
    init_state: [B,H,N,P] or None
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    Q = min(chunk, S)
    while S % Q:          # arbitrary prompt lengths: largest divisor <= chunk
        Q -= 1
    Nc = S // Q
    f32 = jnp.float32

    A = -jnp.exp(a_log.astype(f32))                          # [H] (negative)
    xb = x.reshape(B, Nc, Q, H, P).astype(f32)
    dtb = dt.reshape(B, Nc, Q, H).astype(f32)
    Bb = b_mat.reshape(B, Nc, Q, H, N).astype(f32)
    Cb = c_mat.reshape(B, Nc, Q, H, N).astype(f32)

    xdt = xb * dtb[..., None]                                # dt * x
    dA = dtb * A                                             # [B,Nc,Q,H] <0
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- within-chunk (quadratic, attention-like) ----------------------
    diff = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # [B,Nc,i,j,H]
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Cb, Bb)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", CB * L, xdt)

    # --- chunk-final states --------------------------------------------
    decay_last = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)      # [B,Nc,Q,H]
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                        decay_last, Bb, xdt)                  # [B,Nc,H,N,P]

    # --- inter-chunk recurrence (linear scan) ---------------------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                # [B,Nc,H]
    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((B, H, N, P), f32))

    def step(s, inp):
        cd, st = inp                                          # [B,H], [B,H,N,P]
        entering = s
        s_new = cd[..., None, None] * s + st
        return s_new, entering

    final, entering = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                   # [B,Nc,H,N,P]

    # --- off-diagonal (state) contribution ------------------------------
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                       Cb, entering, jnp.exp(dA_cum))
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t):
    """One-token SSD update.  state [B,H,N,P]; x_t [B,H,P]; dt_t [B,H];
    b_t/c_t [B,H,N].  Returns (y_t [B,H,P], new_state)."""
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    dA = jnp.exp(dt_t.astype(f32) * A)                        # [B,H]
    upd = jnp.einsum("bhn,bhp->bhnp", b_t.astype(f32),
                     (x_t * dt_t[..., None]).astype(f32))
    new_state = dA[..., None, None] * state + upd
    y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(f32), new_state)
    return y.astype(x_t.dtype), new_state


# ------------------------------------------------------------- Mamba2 block
class Mamba2State(NamedTuple):
    ssm: jax.Array     # [B,H,N,P] fp32
    conv_x: jax.Array  # [B, conv-1, d_inner]
    conv_b: jax.Array  # [B, conv-1, G*N]
    conv_c: jax.Array  # [B, conv-1, G*N]


def _dims(cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = d_inner // cfg.ssm_head_dim
    G = 1
    N = cfg.ssm_state
    return d, d_inner, H, G, N


def init_mamba2(key, cfg: ModelConfig, d_model: int | None = None):
    d, d_inner, H, G, N = _dims(cfg, d_model)
    ks = jax.random.split(key, 6)
    K = cfg.ssm_conv

    def conv_init(k, ch):
        return (jax.random.normal(k, (K, ch)) / math.sqrt(K)
                ).astype(cfg.param_dtype)

    kc = jax.random.split(ks[3], 3)
    return {
        "w_z": dense_init(ks[0], d, d_inner, cfg.param_dtype),
        "w_x": dense_init(ks[1], d, d_inner, cfg.param_dtype),
        "w_b": dense_init(ks[2], d, G * N, cfg.param_dtype),
        "w_c": dense_init(ks[4], d, G * N, cfg.param_dtype),
        "w_dt": dense_init(ks[5], d, H, cfg.param_dtype),
        "conv_x_w": conv_init(kc[0], d_inner),
        "conv_b_w": conv_init(kc[1], G * N),
        "conv_c_w": conv_init(kc[2], G * N),
        "conv_x_b": jnp.zeros((d_inner,), cfg.param_dtype),
        "conv_bb": jnp.zeros((G * N,), cfg.param_dtype),
        "conv_cb": jnp.zeros((G * N,), cfg.param_dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(cfg.param_dtype),
        "d_skip": jnp.ones((H,), cfg.param_dtype),
        "dt_bias": jnp.full((H,), math.log(math.e - 1.0), cfg.param_dtype),
        "norm_g": jnp.ones((d_inner,), cfg.param_dtype),
        "out_proj": dense_init(ks[3], d_inner, d, cfg.param_dtype),
    }


def _causal_conv(x, prev, w, b, dtype):
    """Depthwise causal conv along seq.  x: [B,S,C]; prev: [B,K-1,C];
    w: [K,C]; returns (y [B,S,C], new_prev [B,K-1,C])."""
    K = w.shape[0]
    S = x.shape[1]
    xpad = jnp.concatenate([prev.astype(dtype), x], axis=1)
    new_prev = xpad[:, -(K - 1):, :] if K > 1 else xpad[:, :0, :]
    wins = jnp.stack([xpad[:, i:i + S, :] for i in range(K)], axis=2)
    y = jnp.einsum("bskc,kc->bsc", wins, w.astype(dtype)) + b.astype(dtype)
    return jax.nn.silu(y), new_prev


def mamba2_forward(p, x, cfg: ModelConfig,
                   init_state: Mamba2State | None = None,
                   d_model: int | None = None):
    """Full-sequence forward. x: [B,S,D].  Returns (y, final Mamba2State)."""
    d, d_inner, H, G, N = _dims(cfg, d_model)
    B, S, _ = x.shape
    dt_ = cfg.dtype
    K = cfg.ssm_conv
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt_))
    bm = jnp.einsum("bsd,de->bse", x, p["w_b"].astype(dt_))
    cm = jnp.einsum("bsd,de->bse", x, p["w_c"].astype(dt_))
    dt_raw = jnp.einsum("bsd,de->bse", x, p["w_dt"].astype(dt_))

    if init_state is None:
        zpad = lambda ch: jnp.zeros((B, K - 1, ch), dt_)
        prev_x, prev_b, prev_c = zpad(d_inner), zpad(G * N), zpad(G * N)
    else:
        prev_x, prev_b, prev_c = (init_state.conv_x, init_state.conv_b,
                                  init_state.conv_c)
    xs, new_px = _causal_conv(xs, prev_x, p["conv_x_w"], p["conv_x_b"], dt_)
    bm, new_pb = _causal_conv(bm, prev_b, p["conv_b_w"], p["conv_bb"], dt_)
    cm, new_pc = _causal_conv(cm, prev_c, p["conv_c_w"], p["conv_cb"], dt_)

    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    rep = H // G
    b_h = jnp.repeat(bm.reshape(B, S, G, N), rep, axis=2)
    c_h = jnp.repeat(cm.reshape(B, S, G, N), rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    y, ssm_final = ssd_chunked(
        xh, dt, p["a_log"], b_h, c_h, cfg.ssm_chunk,
        init_state.ssm if init_state is not None else None)
    y = y + xh * p["d_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"].astype(dt_), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    return out, Mamba2State(ssm=ssm_final, conv_x=new_px, conv_b=new_pb,
                            conv_c=new_pc)


def _conv_step(win, w, b, dtype):
    """win: [B,K,C] (already includes the new sample at the end)."""
    y = jnp.einsum("bkc,kc->bc", win, w.astype(dtype)) + b.astype(dtype)
    return jax.nn.silu(y)


def mamba2_decode(p, x_t, state: Mamba2State, cfg: ModelConfig,
                  d_model: int | None = None):
    """One-token decode. x_t: [B,1,D]."""
    d, d_inner, H, G, N = _dims(cfg, d_model)
    B = x_t.shape[0]
    dt_ = cfg.dtype
    z = jnp.einsum("bsd,de->bse", x_t, p["w_z"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x_t, p["w_x"].astype(dt_))[:, 0]
    bm = jnp.einsum("bsd,de->bse", x_t, p["w_b"].astype(dt_))[:, 0]
    cm = jnp.einsum("bsd,de->bse", x_t, p["w_c"].astype(dt_))[:, 0]
    dt_raw = jnp.einsum("bsd,de->bse", x_t, p["w_dt"].astype(dt_))[:, 0]

    def upd(prev, new):
        win = jnp.concatenate([prev.astype(dt_), new[:, None, :]], axis=1)
        return win, win[:, 1:, :]

    win_x, new_px = upd(state.conv_x, xs)
    win_b, new_pb = upd(state.conv_b, bm)
    win_c, new_pc = upd(state.conv_c, cm)
    xs = _conv_step(win_x, p["conv_x_w"], p["conv_x_b"], dt_)
    bm = _conv_step(win_b, p["conv_b_w"], p["conv_bb"], dt_)
    cm = _conv_step(win_c, p["conv_c_w"], p["conv_cb"], dt_)

    xh = xs.reshape(B, H, cfg.ssm_head_dim)
    rep = H // G
    b_h = jnp.repeat(bm.reshape(B, G, N), rep, axis=1)
    c_h = jnp.repeat(cm.reshape(B, G, N), rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    y, ssm_new = ssd_decode_step(state.ssm, xh, dt, p["a_log"], b_h, c_h)
    y = y + xh * p["d_skip"].astype(dt_)[None, :, None]
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"].astype(dt_), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    return out, Mamba2State(ssm=ssm_new, conv_x=new_px, conv_b=new_pb,
                            conv_c=new_pc)


def init_mamba2_state(cfg: ModelConfig, batch: int,
                      d_model: int | None = None) -> Mamba2State:
    d, d_inner, H, G, N = _dims(cfg, d_model)
    K = cfg.ssm_conv
    return Mamba2State(
        ssm=jnp.zeros((batch, H, N, cfg.ssm_head_dim), jnp.float32),
        conv_x=jnp.zeros((batch, K - 1, d_inner), cfg.dtype),
        conv_b=jnp.zeros((batch, K - 1, G * N), cfg.dtype),
        conv_c=jnp.zeros((batch, K - 1, G * N), cfg.dtype),
    )
