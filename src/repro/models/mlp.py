"""Dense feed-forward blocks: SwiGLU / GeGLU / plain two-layer MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, activation, dense_init


def init_mlp(key, cfg: ModelConfig, d_model: int | None = None,
             d_ff: int | None = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d, f, cfg.param_dtype),
        "w_out": dense_init(ks[1], f, d, cfg.param_dtype),
    }
    if cfg.glu:
        p["w_gate"] = dense_init(ks[2], d, f, cfg.param_dtype)
    return p


def mlp(p, x, cfg: ModelConfig):
    dt = cfg.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(dt))
    if cfg.glu:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = activation(g, cfg.act) * h
    else:
        h = activation(h, cfg.act)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(dt))
