"""VLM backbone (paligemma-3b shape): gemma-style decoder over
[image-patch embeddings ; text tokens] with a prefix-LM mask.

The SigLIP vision tower is a STUB per the assignment: the model consumes
precomputed patch embeddings [B, img_tokens, D] (what the projector would
emit) via `extra_embeds`.  Everything else reuses the dense transformer.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import (
    LMDecodeState, init_lm, lm_apply, lm_decode_step, lm_make_state,
    lm_prefill,
)

init_vlm = init_lm


def vlm_apply(params, patches, tokens, cfg: ModelConfig):
    """patches: [B, img_tokens, D] stub embeddings; tokens: [B, S_text]."""
    return lm_apply(params, tokens, cfg, extra_embeds=patches,
                    prefix_len=cfg.img_tokens)


def vlm_prefill(params, patches, tokens, cfg: ModelConfig,
                state: LMDecodeState):
    return lm_prefill(params, tokens, cfg, state, extra_embeds=patches,
                      prefix_len=cfg.img_tokens)


vlm_make_state = lm_make_state
vlm_decode_step = lm_decode_step
