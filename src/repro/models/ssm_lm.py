"""Pure-SSM language model (mamba2-130m): embed + scanned Mamba2 blocks."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, checkpoint_wrap,
                                 dense_init, rmsnorm, stacked)
from repro.models.mamba2 import (
    Mamba2State, init_mamba2, init_mamba2_state, mamba2_decode,
    mamba2_forward,
)


def init_ssm_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model))
                  * 0.02).astype(cfg.param_dtype),
        "blocks": stacked(jax.random.split(ks[1], cfg.n_layers),
                          lambda k: {"ln": jnp.ones((cfg.d_model,),
                                                    cfg.param_dtype),
                                     "mamba": init_mamba2(k, cfg)}),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }


def _logits(params, x, cfg):
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))


def ssm_lm_apply(params, tokens, cfg: ModelConfig):
    x = params["embed"].astype(cfg.dtype)[tokens]

    def body(h, lp):
        hn = rmsnorm(h, lp["ln"].astype(cfg.dtype), cfg.norm_eps)
        y, _ = mamba2_forward(lp["mamba"], hn, cfg)
        return h + y, ()

    body_fn = checkpoint_wrap(body, cfg)
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    return _logits(params, x, cfg), jnp.zeros((), jnp.float32)


class SSMDecodeState(NamedTuple):
    states: Mamba2State    # stacked [L, ...]
    pos: jax.Array


def ssm_make_state(cfg: ModelConfig, batch: int,
                   max_len: int = 0) -> SSMDecodeState:
    m = init_mamba2_state(cfg, batch)
    L = cfg.n_layers
    tiled = jax.tree_util.tree_map(
        lambda x: jnp.zeros((L,) + x.shape, x.dtype), m)
    return SSMDecodeState(states=tiled, pos=jnp.zeros((), jnp.int32))


def ssm_prefill(params, tokens, cfg: ModelConfig, state: SSMDecodeState):
    x = params["embed"].astype(cfg.dtype)[tokens]

    def body(h, inp):
        lp, st = inp
        hn = rmsnorm(h, lp["ln"].astype(cfg.dtype), cfg.norm_eps)
        y, new_st = mamba2_forward(lp["mamba"], hn, cfg, init_state=st)
        return h + y, new_st

    body_fn = checkpoint_wrap(body, cfg)
    x, new_states = jax.lax.scan(body_fn, x,
                                 (params["blocks"], state.states))
    logits = _logits(params, x[:, -1:, :], cfg)
    return logits, SSMDecodeState(states=new_states,
                                  pos=state.pos + tokens.shape[1])


def ssm_decode_step(params, token, cfg: ModelConfig, state: SSMDecodeState):
    x = params["embed"].astype(cfg.dtype)[token]

    def body(h, inp):
        lp, st = inp
        hn = rmsnorm(h, lp["ln"].astype(cfg.dtype), cfg.norm_eps)
        y, new_st = mamba2_decode(lp["mamba"], hn, st, cfg)
        return h + y, new_st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], state.states))
    return _logits(params, x, cfg), SSMDecodeState(states=new_states,
                                                   pos=state.pos + 1)
