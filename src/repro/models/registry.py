"""Family dispatch: one uniform API over all architecture families.

    init_params(cfg, seed)                         -> params
    train_forward(params, batch, cfg)              -> (logits, aux_loss)
    make_decode_state(cfg, batch, max_len)         -> state
    prefill(params, batch, cfg, state)             -> (logits, state)
    decode_step(params, token, cfg, state)         -> (logits, state)

`batch` is a dict: tokens [B,S] always; + frames [B,F,D] (encdec stub),
patches [B,P,D] (vlm stub).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec as _encdec
from repro.models import hybrid as _hybrid
from repro.models import ssm_lm as _ssm
from repro.models import transformer as _tf
from repro.models import vlm as _vlm
from repro.models.common import Family, ModelConfig


def init_params(cfg: ModelConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    if cfg.family in (Family.DENSE, Family.MOE):
        return _tf.init_lm(key, cfg)
    if cfg.family == Family.VLM:
        return _vlm.init_vlm(key, cfg)
    if cfg.family == Family.SSM:
        return _ssm.init_ssm_lm(key, cfg)
    if cfg.family == Family.HYBRID:
        return _hybrid.init_hybrid(key, cfg)
    if cfg.family == Family.ENCDEC:
        return _encdec.init_encdec(key, cfg)
    raise ValueError(cfg.family)


def train_forward(params, batch: dict, cfg: ModelConfig):
    """-> (logits [B,S,V] over the *token* part, aux_loss)."""
    tokens = batch["tokens"]
    if cfg.family in (Family.DENSE, Family.MOE):
        return _tf.lm_apply(params, tokens, cfg)
    if cfg.family == Family.VLM:
        logits, aux = _vlm.vlm_apply(params, batch["patches"], tokens, cfg)
        return logits[:, cfg.img_tokens:, :], aux   # loss on text positions
    if cfg.family == Family.SSM:
        return _ssm.ssm_lm_apply(params, tokens, cfg)
    if cfg.family == Family.HYBRID:
        return _hybrid.hybrid_apply(params, tokens, cfg)
    if cfg.family == Family.ENCDEC:
        return _encdec.encdec_apply(params, batch["frames"], tokens, cfg)
    raise ValueError(cfg.family)


def make_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      enc=None):
    if cfg.family in (Family.DENSE, Family.MOE):
        return _tf.lm_make_state(cfg, batch, max_len)
    if cfg.family == Family.VLM:
        return _vlm.vlm_make_state(cfg, batch, max_len)
    if cfg.family == Family.SSM:
        return _ssm.ssm_make_state(cfg, batch, max_len)
    if cfg.family == Family.HYBRID:
        return _hybrid.hybrid_make_state(cfg, batch, max_len)
    if cfg.family == Family.ENCDEC:
        return _encdec.encdec_make_state(cfg, batch, max_len, enc=enc)
    raise ValueError(cfg.family)


def prefill(params, batch: dict, cfg: ModelConfig, state):
    tokens = batch["tokens"]
    if cfg.family in (Family.DENSE, Family.MOE):
        return _tf.lm_prefill(params, tokens, cfg, state)
    if cfg.family == Family.VLM:
        return _vlm.vlm_prefill(params, batch["patches"], tokens, cfg, state)
    if cfg.family == Family.SSM:
        return _ssm.ssm_prefill(params, tokens, cfg, state)
    if cfg.family == Family.HYBRID:
        return _hybrid.hybrid_prefill(params, tokens, cfg, state)
    if cfg.family == Family.ENCDEC:
        enc = _encdec.encode(params, batch["frames"], cfg)
        state = state._replace(enc=enc)
        return _encdec.encdec_prefill(params, tokens, cfg, state)
    raise ValueError(cfg.family)


def decode_step(params, token, cfg: ModelConfig, state):
    if cfg.family in (Family.DENSE, Family.MOE):
        return _tf.lm_decode_step(params, token, cfg, state)
    if cfg.family == Family.VLM:
        return _vlm.vlm_decode_step(params, token, cfg, state)
    if cfg.family == Family.SSM:
        return _ssm.ssm_decode_step(params, token, cfg, state)
    if cfg.family == Family.HYBRID:
        return _hybrid.hybrid_decode_step(params, token, cfg, state)
    if cfg.family == Family.ENCDEC:
        return _encdec.encdec_decode_step(params, token, cfg, state)
    raise ValueError(cfg.family)
