"""Grouped-query attention with RoPE and a sharded KV cache.

Used by dense/moe/vlm decoders, the hybrid model's shared attention block,
and the whisper encoder/decoder (with `causal=False` / cross-attention).
The hot loop can be swapped for the Pallas flash kernel via cfg-level
`use_flash` (TPU target; CPU tests run the pure-jnp path, which is also the
oracle the kernel is validated against).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, apply_rope, constrain,
                                 dense_init, dp_spec, mesh_axes)


def init_attn(key, cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, cfg.param_dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.param_dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.param_dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, cfg.param_dtype,
                         scale=1.0 / math.sqrt(cfg.n_heads * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.param_dtype)
    return p


def qkv_project(p, x, cfg: ModelConfig, positions, *, rope: bool = True):
    """x: [B,S,D] -> q [B,S,H,hd], k/v [B,S,Hkv,hd] (RoPE applied)."""
    B, S, _ = x.shape
    hd = cfg.hd
    dt = cfg.dtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    # Pin sane layouts (GSPMD otherwise partially shards hd after the
    # un-merge reshape, paying a logits-sized all-reduce per q-chunk):
    # heads over "model" when divisible; else context-parallel q (seq over
    # "model") with replicated k/v.
    dp = dp_spec()
    tp_ok_q = cfg.n_heads and mesh_axes().get("model", 1) and \
        cfg.n_heads % max(mesh_axes().get("model", 1), 1) == 0
    if tp_ok_q:
        q = constrain(q, dp, None, "model", None)
    elif S > 1:
        q = constrain(q, dp, "model", None, None)
    else:
        q = constrain(q, dp, None, None, None)
    kv_ok = cfg.n_kv_heads and \
        cfg.n_kv_heads % max(mesh_axes().get("model", 1), 1) == 0
    if kv_ok:
        k = constrain(k, dp, None, "model", None)
        v = constrain(v, dp, None, "model", None)
    else:
        k = constrain(k, dp, None, None, None)
        v = constrain(v, dp, None, None, None)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend_dense(q, k, v, *, causal: bool, q_positions, kv_positions,
                  kv_valid_len, prefix_len):
    """Unchunked core.  q: [B,Sq,H,hd]; k/v: [B,Skv,Hkv,hd].

    Wrapped in the "attn_core" named scope: every HLO op lowered from here
    carries it in metadata, letting analysis/ identify exactly the traffic
    the Pallas flash kernel eliminates on TPU (§Perf flash adjustment)."""
    with jax.named_scope("attn_core"):
        return _attend_dense_inner(q, k, v, causal=causal,
                                   q_positions=q_positions,
                                   kv_positions=kv_positions,
                                   kv_valid_len=kv_valid_len,
                                   prefix_len=prefix_len)


def _attend_dense_inner(q, k, v, *, causal, q_positions, kv_positions,
                        kv_valid_len, prefix_len):
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, G, Hkv, hd)
    scale = 1.0 / math.sqrt(hd)
    # bf16 inputs, f32 accumulation (MXU-native; avoids materializing an
    # f32 copy of the KV cache)
    logits = jnp.einsum("bqghd,bkhd->bghqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = None
    if causal:
        qp = q_positions if q_positions is not None \
            else jnp.arange(Sq)[None, :]
        kp = kv_positions if kv_positions is not None \
            else jnp.arange(k.shape[1])[None, :]
        mask = kp[:, None, :] <= qp[:, :, None]          # [B,Sq,Skv]
        if prefix_len is not None:
            # prefix-LM: full attention among the first prefix_len slots
            in_pref = (kp[:, None, :] < prefix_len) \
                & (qp[:, :, None] < prefix_len)
            mask = mask | in_pref
    if kv_valid_len is not None:
        lim = jnp.arange(k.shape[1])[None, :] < kv_valid_len[:, None]
        lim = jnp.broadcast_to(lim[:, None, :], (B, Sq, k.shape[1]))
        mask = lim if mask is None else (mask & lim)
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bghqk,bkhd->bqghd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def _largest_divisor_le(n: int, cap: int) -> int:
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return n


def gqa_attend(q, k, v, *, causal: bool, q_positions=None, kv_positions=None,
               kv_valid_len=None, prefix_len=None, q_chunk: int = 1024):
    """Reference grouped-query attention (flash-attention oracle).

    q: [B,Sq,H,hd], k/v: [B,Skv,Hkv,hd].  H = G*Hkv.
    Causal masking uses absolute positions so it works for train (Sq==Skv),
    prefill, and decode (Sq==1 against a long cache).
    kv_valid_len: [B] — mask cache slots >= this (decode, partial cache).
    prefix_len: prefix-LM boundary (VLM image prefix attends bidirectionally).

    Long queries are processed in q-chunks under lax.scan so the fp32
    logits buffer stays [B,H,chunk,Skv] — the memory shape of the Pallas
    flash kernel's outer loop (which replaces this path on TPU).
    """
    B, Sq, H, hd = q.shape
    if Sq <= q_chunk:
        return _attend_dense(q, k, v, causal=causal, q_positions=q_positions,
                             kv_positions=kv_positions,
                             kv_valid_len=kv_valid_len,
                             prefix_len=prefix_len)
    C = _largest_divisor_le(Sq, q_chunk)
    nc = Sq // C
    qp = q_positions if q_positions is not None \
        else jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
    q_r = jnp.moveaxis(q.reshape(B, nc, C, H, hd), 1, 0)       # [nc,B,C,H,hd]
    qp_r = jnp.moveaxis(qp.reshape(B, nc, C), 1, 0)            # [nc,B,C]

    def chunk_fn(_, inp):
        qc, qpc = inp
        out = _attend_dense(qc, k, v, causal=causal, q_positions=qpc,
                            kv_positions=kv_positions,
                            kv_valid_len=kv_valid_len,
                            prefix_len=prefix_len)
        return (), out

    # checkpoint per chunk: the backward pass recomputes each chunk's
    # logits instead of stashing [nc, B, H, chunk, Skv] fp32 across chunks
    _, outs = jax.lax.scan(jax.checkpoint(chunk_fn), (), (q_r, qp_r))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


def attn_output(p, o, cfg: ModelConfig):
    B, S, H, hd = o.shape
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd),
                     p["wo"].astype(cfg.dtype))
    # restore the canonical [dp, None, None] layout after attention (if the
    # q path was context-parallel, this is the single all-gather point)
    return constrain(out, dp_spec(), None, None)


# ------------------------------------------------------------------ caching
class KVCache(NamedTuple):
    k: jax.Array      # [B, Smax, Hkv, hd]
    v: jax.Array      # [B, Smax, Hkv, hd]
    length: jax.Array  # [B] int32 — filled prefix length


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               n_layers: int | None = None) -> KVCache:
    """Stacked cache for the scanned layer stack: leading dim = n_layers."""
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(
        k=jnp.zeros(shape, cfg.dtype),
        v=jnp.zeros(shape, cfg.dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def cache_update(cache_k, cache_v, k_new, v_new, start: jax.Array):
    """Insert k/v_new [B,S,Hkv,hd] at position `start` [] (same for batch)."""
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                      (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                      (0, start, 0, 0))
    return ck, cv
