"""Encoder-decoder backbone (whisper-large-v3 shape).

The mel-spectrogram conv frontend is a STUB per the assignment: the model
consumes precomputed frame embeddings [B, frames, D] (what the two conv
layers would produce).  Encoder = non-causal self-attn stack; decoder =
causal self-attn + cross-attn + MLP, all scanned.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, checkpoint_wrap,
                                 dense_init, rmsnorm, stacked)
from repro.models.mlp import init_mlp, mlp


def init_enc_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "attn": attn.init_attn(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "mlp": init_mlp(ks[1], cfg),
    }


def init_dec_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "self_attn": attn.init_attn(ks[0], cfg),
        "ln_x": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "cross_attn": attn.init_attn(ks[1], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "mlp": init_mlp(ks[2], cfg),
    }


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model))
                  * 0.02).astype(cfg.param_dtype),
        "pos_enc": (jax.random.normal(ks[1], (cfg.encoder_frames,
                                               cfg.d_model))
                    * 0.02).astype(cfg.param_dtype),
        "enc_blocks": stacked(jax.random.split(ks[2], cfg.n_encoder_layers),
                              partial(init_enc_block, cfg=cfg)),
        "enc_ln": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "dec_blocks": stacked(jax.random.split(ks[3], cfg.n_layers),
                              partial(init_dec_block, cfg=cfg)),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab_padded,
                              cfg.param_dtype, scale=0.02),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, F, D] (stub conv output) -> encoder states [B, F, D]."""
    F = frames.shape[1]
    x = frames.astype(cfg.dtype) + params["pos_enc"][:F].astype(cfg.dtype)
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))

    def body(h, lp):
        hn = rmsnorm(h, lp["ln1"].astype(cfg.dtype), cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["attn"], hn, cfg, positions,
                                   rope=False)
        o = attn.gqa_attend(q, k, v, causal=False)
        h = h + attn.attn_output(lp["attn"], o, cfg)
        hn = rmsnorm(h, lp["ln2"].astype(cfg.dtype), cfg.norm_eps)
        return h + mlp(lp["mlp"], hn, cfg), ()

    body_fn = checkpoint_wrap(body, cfg)
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_ln"].astype(cfg.dtype), cfg.norm_eps)


def _dec_block(lp, h, enc, cfg, positions):
    hn = rmsnorm(h, lp["ln1"].astype(cfg.dtype), cfg.norm_eps)
    q, k, v = attn.qkv_project(lp["self_attn"], hn, cfg, positions)
    o = attn.gqa_attend(q, k, v, causal=True, q_positions=positions,
                        kv_positions=positions)
    h = h + attn.attn_output(lp["self_attn"], o, cfg)
    hn = rmsnorm(h, lp["ln_x"].astype(cfg.dtype), cfg.norm_eps)
    B, F, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))
    q2, _, _ = attn.qkv_project(lp["cross_attn"], hn, cfg, positions,
                                rope=False)
    _, k2, v2 = attn.qkv_project(lp["cross_attn"], enc, cfg, enc_pos,
                                 rope=False)
    o2 = attn.gqa_attend(q2, k2, v2, causal=False)
    h = h + attn.attn_output(lp["cross_attn"], o2, cfg)
    hn = rmsnorm(h, lp["ln2"].astype(cfg.dtype), cfg.norm_eps)
    return h + mlp(lp["mlp"], hn, cfg), (k, v)


def encdec_apply(params, frames, tokens, cfg: ModelConfig):
    """Training forward -> (decoder logits, aux=0)."""
    enc = encode(params, frames, cfg)
    x = params["embed"].astype(cfg.dtype)[tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(h, lp):
        h, _ = _dec_block(lp, h, enc, cfg, positions)
        return h, ()

    body_fn = checkpoint_wrap(body, cfg)
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.dtype))
    return logits, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ serving
class EncDecState(NamedTuple):
    cache: attn.KVCache     # decoder self-attn cache [L, ...]
    enc: jax.Array          # encoder states [B, F, D]
    cross_k: jax.Array      # precomputed cross-attn keys   [L, B, F, Hkv, hd]
    cross_v: jax.Array      # precomputed cross-attn values [L, B, F, Hkv, hd]
    pos: jax.Array


def encdec_make_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc=None) -> EncDecState:
    enc = enc if enc is not None else jnp.zeros(
        (batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    cross = jnp.zeros((cfg.n_layers, batch, cfg.encoder_frames,
                       cfg.n_kv_heads, cfg.hd), cfg.dtype)
    return EncDecState(cache=attn.init_cache(cfg, batch, max_len),
                       enc=enc, cross_k=cross, cross_v=jnp.copy(cross),
                       pos=jnp.zeros((), jnp.int32))


def precompute_cross_kv(params, enc, cfg: ModelConfig):
    """One-time cross-attention K/V projection of the encoder states.

    §Perf hillclimb (whisper decode): the baseline re-projected K/V over
    all 1500 frames **per generated token per layer** — ~99% of decode
    FLOPs.  Hoisting it to prefill leaves decode with only the q-side
    projection and the (cached) attention reads."""
    B, F, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))

    def per_layer(_, lp):
        _, k2, v2 = attn.qkv_project(lp["cross_attn"], enc, cfg, enc_pos,
                                     rope=False)
        return (), (k2, v2)

    _, (ks, vs) = jax.lax.scan(per_layer, (), params["dec_blocks"])
    return ks, vs


def encdec_prefill(params, tokens, cfg: ModelConfig, state: EncDecState):
    """Fill the decoder self-attn cache with the prompt (state.enc must
    already hold the encoder output)."""
    enc = state.enc
    x = params["embed"].astype(cfg.dtype)[tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    zero = jnp.zeros((), jnp.int32)

    def body(h, inp):
        lp, ck, cv = inp
        h, (k, v) = _dec_block(lp, h, enc, cfg, positions)
        ck, cv = attn.cache_update(ck, cv, k, v, zero)
        return h, (ck, cv)

    body_fn = checkpoint_wrap(body, cfg)
    x, (cks, cvs) = jax.lax.scan(
        body_fn, x, (params["dec_blocks"], state.cache.k, state.cache.v))
    x = rmsnorm(x[:, -1:, :], params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.dtype))
    xk, xv = precompute_cross_kv(params, enc, cfg)
    return logits, EncDecState(
        cache=attn.KVCache(k=cks, v=cvs,
                           length=jnp.full((B,), S, jnp.int32)),
        enc=enc, cross_k=xk, cross_v=xv, pos=jnp.array(S, jnp.int32))


def encdec_decode_step(params, token, cfg: ModelConfig, state: EncDecState):
    x = params["embed"].astype(cfg.dtype)[token]
    B = x.shape[0]
    pos = state.pos
    enc = state.enc

    def body(h, inp):
        lp, ck, cv, k2, v2 = inp
        positions = jnp.broadcast_to(pos[None, None], (B, 1))
        hn = rmsnorm(h, lp["ln1"].astype(cfg.dtype), cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["self_attn"], hn, cfg, positions)
        ck, cv = attn.cache_update(ck, cv, k, v, pos)
        valid = jnp.broadcast_to(pos + 1, (B,))
        o = attn.gqa_attend(q, ck, cv, causal=False, kv_valid_len=valid)
        h = h + attn.attn_output(lp["self_attn"], o, cfg)
        hn = rmsnorm(h, lp["ln_x"].astype(cfg.dtype), cfg.norm_eps)
        q2, _, _ = attn.qkv_project(lp["cross_attn"], hn, cfg, positions,
                                    rope=False)
        # cross-attn K/V precomputed at prefill (§Perf: the baseline
        # re-projected 1500 frames per token per layer)
        o2 = attn.gqa_attend(q2, k2, v2, causal=False)
        h = h + attn.attn_output(lp["cross_attn"], o2, cfg)
        hn = rmsnorm(h, lp["ln2"].astype(cfg.dtype), cfg.norm_eps)
        return h + mlp(lp["mlp"], hn, cfg), (ck, cv)

    x, (cks, cvs) = jax.lax.scan(
        body, x, (params["dec_blocks"], state.cache.k, state.cache.v,
                  state.cross_k, state.cross_v))
    x = rmsnorm(x, params["ln_f"].astype(cfg.dtype), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.dtype))
    return logits, EncDecState(
        cache=attn.KVCache(k=cks, v=cvs, length=state.cache.length + 1),
        enc=enc, cross_k=state.cross_k, cross_v=state.cross_v, pos=pos + 1)
