"""Elastic scaling plans: which mesh to rebuild after gaining/losing pods.

Given the healthy device inventory, pick the largest supported mesh
(keeping the model axis intact — TP degree is baked into the sharded
kernels' efficiency — and shrinking/growing the data/pod axes), plus the
batch re-plan that keeps tokens-per-step constant when possible."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ElasticConfig:
    model_axis: int = 16           # fixed TP degree
    min_data_axis: int = 2
    target_global_batch: int = 256


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple              # (pods, data, model) or (data, model)
    axis_names: tuple
    global_batch: int
    grad_accum: int                # microbatch steps to keep token count


class ElasticPlanner:
    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg

    def plan(self, healthy_chips: int) -> ElasticPlan:
        m = self.cfg.model_axis
        if healthy_chips < m * self.cfg.min_data_axis:
            raise ValueError(
                f"{healthy_chips} chips cannot host model axis {m}")
        slices = healthy_chips // m
        # prefer pod-structured meshes when slices factor as pods x data>=16
        if slices >= 32 and slices % 16 == 0:
            pods, data = slices // 16, 16
            shape, names = (pods, data, m), ("pod", "data", "model")
            dp = pods * data
        else:
            shape, names = (slices, m), ("data", "model")
            dp = slices
        gb = self.cfg.target_global_batch
        if gb % dp == 0:
            batch, accum = gb, 1
        else:
            # keep per-device batch >= 1; make up the token budget with
            # gradient accumulation
            per_dev = max(gb // dp, 1)
            batch = per_dev * dp
            accum = max(1, round(gb / batch))
        return ElasticPlan(mesh_shape=shape, axis_names=names,
                           global_batch=batch, grad_accum=accum)
