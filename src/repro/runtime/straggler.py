"""Straggler mitigation.

Per-step worker timings feed a robust deadline (median + k*MAD).  Workers
that repeatedly miss it get flagged; mitigation is (a) data re-balance —
shrink the straggler's shard of the global batch, handing tokens to fast
workers — and (b) eviction recommendation once persistent (network-noise
victims, in the paper's terms, are transient and recover; broken hosts
don't)."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class StragglerConfig:
    window: int = 20                # steps of history per worker
    deadline_mads: float = 6.0      # deadline = median + k * MAD
    persistent_misses: int = 10     # misses (of last window) => evict
    rebalance_step: float = 0.125   # batch fraction moved per rebalance
    min_share: float = 0.25         # floor on a straggler's batch share


@dataclass
class StragglerMitigator:
    n_workers: int
    cfg: StragglerConfig = StragglerConfig()
    times: dict = field(default_factory=dict)      # worker -> [t]
    misses: dict = field(default_factory=dict)
    shares: dict = field(default_factory=dict)     # batch share per worker

    def __post_init__(self):
        for w in range(self.n_workers):
            self.times[w] = []
            self.misses[w] = 0
            self.shares[w] = 1.0

    def record_step(self, step_times: dict) -> dict:
        """step_times: worker -> seconds for this step.
        Returns actions: worker -> 'ok' | 'rebalance' | 'evict'."""
        all_t = np.array(list(step_times.values()))
        med = float(np.median(all_t))
        mad = float(np.median(np.abs(all_t - med))) or 1e-3
        deadline = med + self.cfg.deadline_mads * mad
        actions = {}
        for w, t in step_times.items():
            hist = self.times[w]
            hist.append(t)
            if len(hist) > self.cfg.window:
                hist.pop(0)
            if t > deadline:
                self.misses[w] += 1
            else:
                self.misses[w] = max(0, self.misses[w] - 1)
            if self.misses[w] >= self.cfg.persistent_misses:
                actions[w] = "evict"
            elif t > deadline:
                self.shares[w] = max(self.cfg.min_share,
                                     self.shares[w]
                                     - self.cfg.rebalance_step)
                actions[w] = "rebalance"
            else:
                # recover share gradually when healthy
                self.shares[w] = min(1.0, self.shares[w]
                                     + self.cfg.rebalance_step / 4)
                actions[w] = "ok"
        return actions

    def batch_shares(self) -> dict:
        """Normalized per-worker batch fractions (sum == n_workers)."""
        total = sum(self.shares.values())
        scale = self.n_workers / total
        return {w: s * scale for w, s in self.shares.items()}
