# repro.runtime — fault tolerance, straggler mitigation, elastic scaling.
# Pure-python control-plane state machines (unit-testable without TPUs);
# launch/train.py wires them to the JAX runtime.

from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, FaultToleranceConfig, RestartPolicy, NodeState,
)
from repro.runtime.straggler import StragglerMitigator, StragglerConfig
from repro.runtime.elastic import ElasticPlanner, ElasticConfig

__all__ = [
    "HeartbeatMonitor", "FaultToleranceConfig", "RestartPolicy", "NodeState",
    "StragglerMitigator", "StragglerConfig",
    "ElasticPlanner", "ElasticConfig",
]
