"""Failure detection + restart policy.

At 1000+ nodes, MTBF is minutes-to-hours; the control plane must (a) detect
dead workers fast without false-positives from GC/compile pauses, (b)
decide restart-in-place vs elastic-shrink, (c) resume step-exact from the
last checkpoint.  HeartbeatMonitor implements phi-accrual-style detection
(suspicion grows with silence relative to observed inter-arrival jitter);
RestartPolicy turns failure events into actions."""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass(frozen=True)
class FaultToleranceConfig:
    heartbeat_interval_s: float = 5.0
    suspect_phi: float = 3.0       # suspicion threshold (std devs)
    dead_phi: float = 8.0
    min_std_s: float = 0.5         # jitter floor (compile pauses)
    max_restarts_per_hour: int = 6


@dataclass
class _NodeStats:
    last_seen: float = 0.0
    mean_gap: float = 5.0
    var_gap: float = 1.0
    n: int = 0


class HeartbeatMonitor:
    """phi-accrual failure detector over worker heartbeats."""

    def __init__(self, node_ids, cfg: FaultToleranceConfig, now_s: float = 0.0):
        self.cfg = cfg
        self.stats = {n: _NodeStats(last_seen=now_s) for n in node_ids}

    def heartbeat(self, node_id, now_s: float) -> None:
        st = self.stats[node_id]
        if st.n > 0:
            gap = now_s - st.last_seen
            alpha = 0.2
            delta = gap - st.mean_gap
            st.mean_gap += alpha * delta
            st.var_gap = (1 - alpha) * (st.var_gap + alpha * delta * delta)
        st.last_seen = now_s
        st.n += 1

    def phi(self, node_id, now_s: float) -> float:
        st = self.stats[node_id]
        silence = now_s - st.last_seen
        std = max(math.sqrt(st.var_gap), self.cfg.min_std_s)
        return max(0.0, (silence - st.mean_gap) / std)

    def state(self, node_id, now_s: float) -> NodeState:
        p = self.phi(node_id, now_s)
        if p >= self.cfg.dead_phi:
            return NodeState.DEAD
        if p >= self.cfg.suspect_phi:
            return NodeState.SUSPECT
        return NodeState.HEALTHY

    def dead_nodes(self, now_s: float) -> list:
        return [n for n in self.stats
                if self.state(n, now_s) == NodeState.DEAD]


class RestartAction(enum.Enum):
    NONE = "none"
    RESTART_IN_PLACE = "restart_in_place"   # spare available
    ELASTIC_SHRINK = "elastic_shrink"       # drop the pod, reshard
    ABORT = "abort"                         # restart budget exhausted


@dataclass
class RestartPolicy:
    cfg: FaultToleranceConfig
    spares_available: int = 0
    restart_times: list = field(default_factory=list)

    def on_failure(self, dead_nodes: list, now_s: float) -> RestartAction:
        if not dead_nodes:
            return RestartAction.NONE
        self.restart_times = [t for t in self.restart_times
                              if now_s - t < 3600.0]
        if len(self.restart_times) >= self.cfg.max_restarts_per_hour:
            return RestartAction.ABORT
        self.restart_times.append(now_s)
        if self.spares_available >= len(dead_nodes):
            self.spares_available -= len(dead_nodes)
            return RestartAction.RESTART_IN_PLACE
        return RestartAction.ELASTIC_SHRINK
