"""Checkpointing: flattened-pytree npz with zstd, async writer thread,
atomic rename, retention, and step-exact resume metadata.

Layout: <dir>/step_<n>/ {arrays.npz.zst, meta.json}; `latest` symlink is
only flipped after a fully-written checkpoint (crash-safe restore)."""

from __future__ import annotations

import io
import json
import os
import threading
from dataclasses import dataclass, field

import jax
import numpy as np

try:
    import zstandard as zstd
except ModuleNotFoundError:
    # Container without zstandard: fall back to zlib compression behind
    # the same two-class interface.  Fallback checkpoints are NOT
    # zstd-readable (and vice versa) — the decompressor checks the zstd
    # frame magic so a cross-environment restore fails with a clear
    # message instead of a bare zlib.error.
    import zlib

    _ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

    class _ZlibCompressor:
        def __init__(self, level: int = 3):
            self._level = level

        def compress(self, data: bytes) -> bytes:
            return zlib.compress(data, self._level)

    class _ZlibDecompressor:
        def decompress(self, data: bytes) -> bytes:
            if data[:4] == _ZSTD_MAGIC:
                raise RuntimeError(
                    "checkpoint was written with zstandard, which is not "
                    "installed here — install zstandard to restore it")
            return zlib.decompress(data)

    class _ZstdShim:
        ZstdCompressor = staticmethod(
            lambda level=3: _ZlibCompressor(level))
        ZstdDecompressor = staticmethod(_ZlibDecompressor)

    zstd = _ZstdShim()


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, meta: dict = None,
                    keep: int = 3) -> str:
    """Synchronous save.  Returns the checkpoint path."""
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    comp = zstd.ZstdCompressor(level=3).compress(buf.getvalue())
    with open(os.path.join(tmp, "arrays.npz.zst"), "wb") as f:
        f.write(comp)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "names": names, "meta": meta or {}}, f)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    _update_latest(ckpt_dir, final)
    _retain(ckpt_dir, keep)
    return final


def _update_latest(ckpt_dir: str, final: str):
    latest = os.path.join(ckpt_dir, "latest")
    tmp_link = latest + ".tmp"
    if os.path.islink(tmp_link) or os.path.exists(tmp_link):
        os.remove(tmp_link)
    os.symlink(os.path.basename(final), tmp_link)
    os.replace(tmp_link, latest)


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]), d) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for _, d in steps[:-keep] if keep > 0 else []:
        import shutil
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def load_checkpoint(ckpt_dir: str, tree_like, *, step: int | None = None):
    """Restore into the structure of `tree_like` (shapes/dtypes preserved
    from disk).  Returns (tree, step, meta)."""
    if step is None:
        latest = os.path.join(ckpt_dir, "latest")
        path = os.path.join(ckpt_dir, os.readlink(latest)) \
            if os.path.islink(latest) else latest
    else:
        path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    with open(os.path.join(path, "arrays.npz.zst"), "rb") as f:
        raw = zstd.ZstdDecompressor().decompress(f.read())
    arrays = np.load(io.BytesIO(raw))
    leaves = [arrays[f"a{i}"] for i in range(len(arrays.files))]
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta["step"], meta.get("meta", {})


@dataclass
class CheckpointManager:
    """Async manager: save() snapshots to host memory synchronously (so
    training can donate buffers) and writes to disk on a worker thread."""

    ckpt_dir: str
    keep: int = 3
    _thread: threading.Thread = field(default=None, repr=False)
    _error: list = field(default_factory=list)

    def __post_init__(self):
        os.makedirs(self.ckpt_dir, exist_ok=True)

    def save_async(self, step: int, tree, *, meta: dict = None):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now
        self.wait()

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, meta=meta,
                                keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def restore(self, tree_like, *, step: int | None = None):
        return load_checkpoint(self.ckpt_dir, tree_like, step=step)

    def latest_step(self) -> int | None:
        try:
            latest = os.path.join(self.ckpt_dir, "latest")
            target = os.readlink(latest)
            return int(target.split("_")[1])
        except OSError:
            return None
