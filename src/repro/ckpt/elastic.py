"""Elastic resharding: restore a checkpoint onto a DIFFERENT mesh.

Checkpoints store unsharded (host-gathered) arrays, so elasticity is a
placement problem, not a data problem: `reshard_checkpoint` re-places every
leaf with the sharding rules evaluated against the NEW mesh (divisibility
fallbacks included), letting a job restart on a shrunken/grown pod set —
e.g. 2x16x16 -> 16x16 after losing a pod, or onto a differently-shaped
model axis after re-planning TP."""

from __future__ import annotations

import jax

from repro.sharding.partition import ShardingPolicy, param_specs


def reshard_checkpoint(tree, cfg, new_mesh, *,
                       policy: ShardingPolicy | None = None):
    """Place restored host arrays onto `new_mesh` with fresh specs."""
    specs = param_specs(tree, cfg, new_mesh, policy)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, specs)
