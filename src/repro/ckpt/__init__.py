# repro.ckpt — checkpoint save/restore (npz + zstd, async writer) and
# elastic resharding onto changed meshes.

from repro.ckpt.checkpoint import CheckpointManager, save_checkpoint, load_checkpoint
from repro.ckpt.elastic import reshard_checkpoint

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "reshard_checkpoint"]
