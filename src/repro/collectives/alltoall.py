"""All-to-all schedules (shard_map) — MoE expert-parallel token exchange.

DIRECT:        one all_to_all over the full expert-parallel span.  With
               experts sharded across pods, token payloads cross the slow
               DCN links in many small per-peer messages.

HIERARCHICAL:  the paper's INCREASINGLY-MINIMAL analogue for alltoall:
               phase 1 exchanges within the pod (fast ICI) so that each
               chip aggregates all pod-local tokens bound for its
               cross-pod peer group; phase 2 crosses pods with fewer,
               larger messages.  (2-phase/hierarchical A2A.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def alltoall_direct(x, axis_name: str, *, split_axis: int = 0,
                    concat_axis: int = 0):
    """Inside shard_map. x: [n*k, ...] split over `axis_name` peers."""
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def alltoall_hierarchical(x, pod_axis: str, inner_axis: str):
    """Inside shard_map.  x: [P*I*k, ...] destined buckets laid out as
    (pod-major, inner-minor).  Phase 1: a2a over inner axis; phase 2: a2a
    over pod axis with aggregated payloads."""
    # phase 1: exchange within the pod (fast links)
    x = jax.lax.all_to_all(x, inner_axis, split_axis=0, concat_axis=0,
                           tiled=True)
    # phase 2: exchange across pods (aggregated messages on slow links)
    x = jax.lax.all_to_all(x, pod_axis, split_axis=0, concat_axis=0,
                           tiled=True)
    return x
