# repro.collectives — the paper's technique, adapted to TPU pods.
#
# Aries routing modes map to collective *schedules* (DESIGN.md §2):
#   minimal / high-bias  ->  DIRECT: one-phase flat collectives (fewest
#                            phases; every byte crosses the slow pod links)
#   adaptive / spread    ->  HIERARCHICAL: pod-local reduce-scatter, cross-
#                            pod exchange on shards, pod-local all-gather
#                            (more phases/hops; scarce links carry 1/N)
#
# selector.AppAwareSelector runs the paper's Algorithm 1 verbatim on these
# two modes, with (L, s) synthesized from HLO-derived link-class byte
# counters (hlo_counters.py) — the TPU analogue of the Aries NIC counters.

from repro.collectives.modes import CollectiveMode, mode_for_routing
from repro.collectives.allreduce import (
    allreduce_direct, allreduce_hierarchical, grad_allreduce,
)
from repro.collectives.alltoall import alltoall_direct, alltoall_hierarchical
from repro.collectives.selector import AppAwareSelector, ICICostModel
from repro.collectives.hlo_counters import HloCounterBackend

__all__ = [
    "CollectiveMode", "mode_for_routing",
    "allreduce_direct", "allreduce_hierarchical", "grad_allreduce",
    "alltoall_direct", "alltoall_hierarchical",
    "AppAwareSelector", "ICICostModel", "HloCounterBackend",
]
