"""Expert-parallel MoE via shard_map all-to-all — the §Perf replacement
for the GShard-style dense-dispatch einsums (models/moe.py).

Why: the einsum path's dispatch/combine tensors add O(T*E*C*D) HLO FLOPs
and giant intermediates (granite train_4k baseline: useful-FLOPs ratio
0.137, collective term 37 s).  The EP path routes tokens with a LOCAL
scatter (O(T*D)), exchanges only real token payloads with all-to-all over
the expert-parallel axis, and runs dense per-expert matmuls — the MoE
communication pattern the paper's alltoall analysis is about, with the
DIRECT vs HIERARCHICAL schedule choice (Algorithm 1) applied to the a2a.

Requires n_experts % ep_size == 0 (the hillclimb pairs granite/qwen2-moe
with a (64, 4) mesh: 40 % 4 == 0, 60 % 4 == 0).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.collectives.modes import CollectiveMode
from repro.models.common import ModelConfig, activation, dp_spec, mesh_axes
from repro.models.mlp import mlp


def _local_dispatch(x, probs, cfg: ModelConfig, capacity: int):
    """Local top-k -> per-expert buckets.

    x: [T, D]; probs: [T, E].  Returns (buffer [E, C, D], gates [T, k],
    expert_idx [T, k], slot_idx [T, k], aux)."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    topv, topi = jax.lax.top_k(probs, k)                  # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((E,), jnp.int32)
    buffer = jnp.zeros((E, capacity, D), x.dtype)
    slots = []
    for j in range(k):                                    # k <= 8
        e = topi[:, j]                                    # [T]
        oh = jax.nn.one_hot(e, E, dtype=jnp.int32)        # [T, E]
        pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(T), e] + counts[e]
        keep = pos < capacity
        slot = jnp.where(keep, pos, capacity)             # OOB -> dropped
        buffer = buffer.at[e, slot.clip(0, capacity - 1)].add(
            jnp.where(keep[:, None], x, 0).astype(x.dtype))
        slots.append(jnp.where(keep, slot, -1))
        counts = counts + oh.sum(axis=0)
    me = probs.mean(axis=0)
    top1 = jax.nn.one_hot(topi[:, 0], E).mean(axis=0)
    aux = E * jnp.sum(me * top1)
    return buffer, topv, topi, jnp.stack(slots, 1), aux


def _expert_ffn(p, xe, cfg: ModelConfig):
    """xe: [E_local, C_all, D] -> same; dense per-expert matmuls."""
    dt = cfg.dtype
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    h = activation(g, cfg.act) * h
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))


def moe_ep(p, x, cfg: ModelConfig, *,
           mode: CollectiveMode = CollectiveMode.DIRECT,
           ep_axis: str = "model", capacity_factor: float = 1.25):
    """Drop-in replacement for models.moe.moe_einsum (x: [B,S,D]).

    Must run under jit with an active mesh whose `ep_axis` divides
    n_experts.  Expert weights are expected EP-sharded ([E, D, F] with E
    over ep_axis — sharding/partition.py's rule)."""
    axes = mesh_axes()
    ep = axes.get(ep_axis, 1)
    assert cfg.n_experts % max(ep, 1) == 0, (cfg.n_experts, ep)
    B, S, D = x.shape
    dp = dp_spec()
    from jax.sharding import PartitionSpec as P

    E, k = cfg.n_experts, cfg.top_k
    mesh = compat.get_abstract_mesh()
    dp_tuple = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    n_dp = 1
    for a in dp_tuple:
        n_dp *= axes[a]
    T_loc = (B // max(n_dp, 1)) * S
    capacity = max(k, int(math.ceil(T_loc * k * capacity_factor / E)))

    def body(xl, router_w, w_in, w_gate, w_out, shared):
        # xl: [B/n_dp, S, D] (replicated over ep_axis); experts local E/ep
        Bl = xl.shape[0]
        xt = xl.reshape(-1, D)
        probs = jax.nn.softmax(
            jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w), -1)
        buf, gates, eidx, slots, aux = _local_dispatch(xt, probs, cfg,
                                                       capacity)
        # [E, C, D] -> a2a -> [E/ep * ep? ...]: send expert-major shards
        if mode == CollectiveMode.HIERARCHICAL and "pod" in axes:
            from repro.collectives.alltoall import alltoall_hierarchical
            recv = alltoall_hierarchical(buf, "pod", ep_axis)
        else:
            recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        # recv: [E? -> (ep * E_local), C, D] grouped as [ep, E_local, C, D]
        E_loc = E // ep
        recv = recv.reshape(ep, E_loc, capacity, D) \
            .transpose(1, 0, 2, 3).reshape(E_loc, ep * capacity, D)
        out = _expert_ffn({"w_in": w_in, "w_gate": w_gate,
                           "w_out": w_out}, recv, cfg)
        out = out.reshape(E_loc, ep, capacity, D).transpose(1, 0, 2, 3) \
            .reshape(E, capacity, D)
        if mode == CollectiveMode.HIERARCHICAL and "pod" in axes:
            from repro.collectives.alltoall import alltoall_hierarchical
            back = alltoall_hierarchical(out, "pod", ep_axis)
        else:
            back = jax.lax.all_to_all(out, ep_axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        # combine: gather each (token, choice) slot, weight by gate
        y = jnp.zeros_like(xt)
        for j in range(k):
            slot = slots[:, j]
            val = back[eidx[:, j], slot.clip(0, capacity - 1)]
            val = jnp.where((slot >= 0)[:, None], val, 0)
            y = y + gates[:, j][:, None].astype(val.dtype) * val
        y = y.reshape(Bl, S, D)
        aux = jax.lax.pmean(aux, dp_tuple + (ep_axis,)) \
            if (dp_tuple or ep) else aux
        return y, aux

    w = p  # param dict
    E_loc = E // ep
    y, aux = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp if dp else None, None, None), P(), P(ep_axis),
                  P(ep_axis), P(ep_axis), P()),
        out_specs=(P(dp if dp else None, None, None), P()),
        check_vma=False,
    )(x, w["router"], w["w_in"], w["w_gate"], w["w_out"], 0)
    if cfg.n_shared_experts:
        y = y + mlp(w["shared"], x, cfg)
    return y, aux.astype(jnp.float32)


def moe_ep_ref(p, x, cfg: ModelConfig, capacity_factor: float = 1.25):
    """Single-device oracle: same dispatch math, no collectives."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    T = xt.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    capacity = max(k, int(math.ceil(T * k * capacity_factor / E)))
    probs = jax.nn.softmax(
        jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"]), -1)
    buf, gates, eidx, slots, aux = _local_dispatch(xt, probs, cfg, capacity)
    out = _expert_ffn(p, buf, cfg)
    y = jnp.zeros_like(xt)
    for j in range(k):
        slot = slots[:, j]
        val = out[eidx[:, j], slot.clip(0, capacity - 1)]
        val = jnp.where((slot >= 0)[:, None], val, 0)
        y = y + gates[:, j][:, None].astype(val.dtype) * val
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x, cfg)
    return y, aux.astype(jnp.float32)
