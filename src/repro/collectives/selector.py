"""Application-aware collective-schedule selection — Algorithm 1 on TPU.

`AppAwareSelector` is a thin adapter over the unified policy API
(repro.policy.PolicyEngine + AppAwarePolicy): mode_a (the
"adaptive"/spread schedule) = HIERARCHICAL, mode_b (the minimal/low-latency
schedule) = DIRECT.  Small messages are latency-bound -> DIRECT (fewest
phases), exactly like the paper's 4 KiB high-bias gate; large messages are
bandwidth-bound on the slow pod links -> HIERARCHICAL wins once
bytes/dcn_bw dominates the extra phase latency.

`ICICostModel` supplies the a-priori (L, s) estimates per mode the same
way the paper's λ/σ scaling factors do; live observations (HLO counters or
measured step times) refine them through the engine's TelemetryBus.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.collectives.modes import CollectiveMode
from repro.core.strategies import ModePerformance
from repro.analysis.roofline import HwSpec, V5E
from repro.policy import (AppAwareConfig, AppAwarePolicy, DecisionBatch,
                          KIND_ALLTOALL, KIND_PT2PT, PolicyEngine)

NS_PER_CYCLE = 1.0  # 1 GHz NIC-cycle convention, matching hlo_counters


@dataclass(frozen=True)
class MeshSpec:
    n_pods: int
    inner_chips: int          # chips per pod participating in the collective

    @property
    def total(self) -> int:
        return self.n_pods * self.inner_chips


@dataclass
class ICICostModel:
    mesh: MeshSpec
    hw: HwSpec = V5E
    #: per-phase software+switch latency (cycles @1GHz = ns)
    phase_latency_intra: float = 1_000.0
    phase_latency_cross: float = 5_000.0

    def predict(self, size_bytes: int, mode: CollectiveMode,
                kind: str = "all-reduce") -> ModePerformance:
        """(L, s) estimate for transferring `size_bytes` with `mode`.

        L (latency cycles): number of phases x per-phase latency — DIRECT
        has a single phase whose ring spans pods (cross latency); the
        HIERARCHICAL schedule pays 3 phases (RS + cross-AR + AG).
        s (stall cycles/flit): serialization occupancy of the bottleneck
        link class — flits wait when the slow link is the bottleneck.
        """
        n, p, i = self.mesh.total, self.mesh.n_pods, self.mesh.inner_chips
        if mode == CollectiveMode.DIRECT:
            phases_lat = self.phase_latency_cross if p > 1 \
                else self.phase_latency_intra
            # full ring share crosses the slowest link class
            wire_slow = 2.0 * (n - 1) / n * size_bytes if p > 1 else 0.0
            wire_fast = 2.0 * (n - 1) / n * size_bytes
        else:
            phases_lat = (2.0 * self.phase_latency_intra
                          + self.phase_latency_cross)
            wire_fast = 2.0 * (i - 1) / i * size_bytes * 2.0  # RS + AG
            wire_slow = 2.0 * (p - 1) / p * (size_bytes / max(i, 1)) \
                if p > 1 else 0.0
        # stall model: cycles per flit = how much slower the bottleneck
        # link class drains than the NIC flit clock (1 flit/cycle @ 1 GHz)
        t_slow = wire_slow / self.hw.dcn_bw
        t_fast = wire_fast / self.hw.ici_bw
        t_ser = max(t_slow, t_fast)
        flits = max(size_bytes / 64.0 * 5.0, 1.0)
        t_flit_clock = flits * 1e-9          # stall-free serialization (s)
        s = max(0.0, t_ser / t_flit_clock - 1.0)
        return ModePerformance(latency_cycles=phases_lat,
                               stall_cycles_per_flit=s)


@dataclass
class AppAwareSelector:
    """Thin adapter: the legacy per-call scalar API over a PolicyEngine.

    Batched callers (grad_comm's per-step bucket list) should use
    `decide_batch`; `select`/`observe*` keep the seed's scalar protocol
    for existing call sites."""

    cost_model: ICICostModel
    engine: PolicyEngine = None
    #: traffic log (size, mode), mirrors Fig. 8's %-default reporting
    decisions: list = field(default_factory=list)

    def __post_init__(self):
        if self.engine is None:
            lam, sig = self._calibrate_scaling()
            self.engine = PolicyEngine(AppAwarePolicy(AppAwareConfig(
                mode_a=CollectiveMode.HIERARCHICAL,
                mode_a_alltoall=CollectiveMode.HIERARCHICAL,
                mode_b=CollectiveMode.DIRECT,
                lambda_latency=lam, sigma_stalls=sig,
            ), granularity="message"))

    def _calibrate_scaling(self):
        """λ, σ from the cost model at a reference size (the paper derives
        them as median ratios over microbenchmark sweeps)."""
        ref = 16 * 1024 * 1024
        a = self.cost_model.predict(ref, CollectiveMode.HIERARCHICAL)
        b = self.cost_model.predict(ref, CollectiveMode.DIRECT)
        lam = (b.latency_cycles / a.latency_cycles
               if a.latency_cycles else 1.0)
        sig = (b.stall_cycles_per_flit / a.stall_cycles_per_flit
               if a.stall_cycles_per_flit > 1e-9 else 2.0)
        # clamp away degenerate single-pod calibrations (0 or inf ratios)
        lam = min(max(lam, 0.05), 20.0)
        sig = min(max(sig, 0.05), 20.0)
        return lam, sig

    # ------------------------------------------------------------ batch API
    def decide_batch(self, sizes_bytes, *, site="default",
                     alltoall: bool = False):
        """One engine call for a batch of collective payloads."""
        kind = KIND_ALLTOALL if alltoall else KIND_PT2PT
        modes = self.engine.decide(
            DecisionBatch.of(sizes_bytes, site=site, kind=kind))
        self.decisions.extend(
            (float(sz), m) for sz, m in zip(sizes_bytes, modes))
        return modes

    def update_predicted(self, sizes_bytes) -> None:
        """Self-feed the last-decided batch with the cost model (dry-run
        path, where no wall-clock exists)."""
        modes = self.engine.last_modes
        if modes is None:
            return
        perfs = [self.cost_model.predict(int(sz), m)
                 for sz, m in zip(sizes_bytes, modes)]
        self.engine.bus.publish_flow_arrays(
            [p.latency_cycles / 1e3 for p in perfs],  # cycles->us @1GHz
            [p.stall_cycles_per_flit for p in perfs],
            source="model")

    # ----------------------------------------------------------- scalar API
    def select(self, size_bytes: int, *, alltoall: bool = False
               ) -> CollectiveMode:
        kind = KIND_ALLTOALL if alltoall else KIND_PT2PT
        mode = self.engine.decide(
            DecisionBatch.single(size_bytes, kind=kind))[0]
        self.decisions.append((size_bytes, mode))
        return mode

    def observe(self, latency_cycles: float, stalls_per_flit: float):
        self.engine.bus.publish(
            self.engine.bus.from_mode_performance(ModePerformance(
                latency_cycles, stalls_per_flit), source="nic"))

    def observe_predicted(self, size_bytes: int):
        """Self-feed with the cost model (used in the dry-run, where no
        wall-clock exists): predicted (L, s) for the mode just used."""
        modes = self.engine.last_modes
        if modes is None or len(modes) == 0:
            return
        perf = self.cost_model.predict(size_bytes, modes[-1])
        self.observe(perf.latency_cycles, perf.stall_cycles_per_flit)

    def traffic_fraction_direct(self) -> float:
        return self.engine.traffic_fraction(CollectiveMode.DIRECT)
