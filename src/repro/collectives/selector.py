"""Application-aware collective-schedule selection — Algorithm 1 on TPU.

`AppAwareSelector` arbitrates DIRECT vs HIERARCHICAL per collective call
site, reusing repro.core.app_aware.AppAwareRouter verbatim: mode_a (the
"adaptive"/spread schedule) = HIERARCHICAL, mode_b (the minimal/low-latency
schedule) = DIRECT.  Small messages are latency-bound -> DIRECT (fewest
phases), exactly like the paper's 4 KiB high-bias gate; large messages are
bandwidth-bound on the slow pod links -> HIERARCHICAL wins once
bytes/dcn_bw dominates the extra phase latency.

`ICICostModel` supplies the a-priori (L, s) estimates per mode the same
way the paper's λ/σ scaling factors do; live observations (HLO counters or
measured step times) refine them through router.observe().
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.collectives.modes import CollectiveMode
from repro.core.app_aware import AppAwareRouter, RouterConfig
from repro.core.strategies import ModePerformance
from repro.analysis.roofline import HwSpec, V5E

NS_PER_CYCLE = 1.0  # 1 GHz NIC-cycle convention, matching hlo_counters


@dataclass(frozen=True)
class MeshSpec:
    n_pods: int
    inner_chips: int          # chips per pod participating in the collective

    @property
    def total(self) -> int:
        return self.n_pods * self.inner_chips


@dataclass
class ICICostModel:
    mesh: MeshSpec
    hw: HwSpec = V5E
    #: per-phase software+switch latency (cycles @1GHz = ns)
    phase_latency_intra: float = 1_000.0
    phase_latency_cross: float = 5_000.0

    def predict(self, size_bytes: int, mode: CollectiveMode,
                kind: str = "all-reduce") -> ModePerformance:
        """(L, s) estimate for transferring `size_bytes` with `mode`.

        L (latency cycles): number of phases x per-phase latency — DIRECT
        has a single phase whose ring spans pods (cross latency); the
        HIERARCHICAL schedule pays 3 phases (RS + cross-AR + AG).
        s (stall cycles/flit): serialization occupancy of the bottleneck
        link class — flits wait when the slow link is the bottleneck.
        """
        n, p, i = self.mesh.total, self.mesh.n_pods, self.mesh.inner_chips
        if mode == CollectiveMode.DIRECT:
            phases_lat = self.phase_latency_cross if p > 1 \
                else self.phase_latency_intra
            # full ring share crosses the slowest link class
            wire_slow = 2.0 * (n - 1) / n * size_bytes if p > 1 else 0.0
            wire_fast = 2.0 * (n - 1) / n * size_bytes
        else:
            phases_lat = (2.0 * self.phase_latency_intra
                          + self.phase_latency_cross)
            wire_fast = 2.0 * (i - 1) / i * size_bytes * 2.0  # RS + AG
            wire_slow = 2.0 * (p - 1) / p * (size_bytes / max(i, 1)) \
                if p > 1 else 0.0
        # stall model: cycles per flit = how much slower the bottleneck
        # link class drains than the NIC flit clock (1 flit/cycle @ 1 GHz)
        t_slow = wire_slow / self.hw.dcn_bw
        t_fast = wire_fast / self.hw.ici_bw
        t_ser = max(t_slow, t_fast)
        flits = max(size_bytes / 64.0 * 5.0, 1.0)
        t_flit_clock = flits * 1e-9          # stall-free serialization (s)
        s = max(0.0, t_ser / t_flit_clock - 1.0)
        return ModePerformance(latency_cycles=phases_lat,
                               stall_cycles_per_flit=s)


@dataclass
class AppAwareSelector:
    """Per-call-site Algorithm 1 instance for collective scheduling."""

    cost_model: ICICostModel
    router: AppAwareRouter = None
    #: traffic log (mode -> bytes), mirrors Fig. 8's %-default reporting
    decisions: list = field(default_factory=list)

    def __post_init__(self):
        if self.router is None:
            lam, sig = self._calibrate_scaling()
            self.router = AppAwareRouter(RouterConfig(
                mode_a=CollectiveMode.HIERARCHICAL,
                mode_a_alltoall=CollectiveMode.HIERARCHICAL,
                mode_b=CollectiveMode.DIRECT,
                lambda_latency=lam, sigma_stalls=sig,
            ))

    def _calibrate_scaling(self):
        """λ, σ from the cost model at a reference size (the paper derives
        them as median ratios over microbenchmark sweeps)."""
        ref = 16 * 1024 * 1024
        a = self.cost_model.predict(ref, CollectiveMode.HIERARCHICAL)
        b = self.cost_model.predict(ref, CollectiveMode.DIRECT)
        lam = (b.latency_cycles / a.latency_cycles
               if a.latency_cycles else 1.0)
        sig = (b.stall_cycles_per_flit / a.stall_cycles_per_flit
               if a.stall_cycles_per_flit > 1e-9 else 2.0)
        # clamp away degenerate single-pod calibrations (0 or inf ratios)
        lam = min(max(lam, 0.05), 20.0)
        sig = min(max(sig, 0.05), 20.0)
        return lam, sig

    def select(self, size_bytes: int, *, alltoall: bool = False
               ) -> CollectiveMode:
        mode = self.router.select(size_bytes, alltoall=alltoall)
        self.decisions.append((size_bytes, mode))
        return mode

    def observe(self, latency_cycles: float, stalls_per_flit: float):
        self.router.observe(latency_cycles, stalls_per_flit)

    def observe_predicted(self, size_bytes: int):
        """Self-feed with the cost model (used in the dry-run, where no
        wall-clock exists): predicted (L, s) for the mode just used."""
        mode = self.router._pending_mode
        if mode is None:
            return
        perf = self.cost_model.predict(size_bytes, mode)
        self.router.observe(perf.latency_cycles, perf.stall_cycles_per_flit)

    def traffic_fraction_direct(self) -> float:
        return self.router.traffic_fraction(CollectiveMode.DIRECT)
