"""HLO-backed NIC counters — the TPU analogue of Aries counters (§2.3).

Given a compiled module's HloCosts, synthesize the paper's four counters
for one executed step:

  request flits            <- wire bytes / 64B "packets" * 5 flits (PUT)
  request packets          <- wire bytes / 64B
  stalled cycles           <- serialization excess on the bottleneck link
                              class: cycles the NIC would wait because the
                              offered collective bytes exceed what the link
                              moves in the step's compute window
  cumulative latency (us)  <- per-collective phase latency (hop count x
                              per-hop latency) summed over executions

This gives Algorithm 1 the same (L, s) observables it reads on Aries,
derived from the compiled artifact instead of hardware MMRs.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.analysis.hlo_parse import HloCosts
from repro.analysis.roofline import HwSpec, V5E, classify_collective
from repro.core.counters import InMemoryBackend, NICCounters

#: per-hop latency of one collective phase (us): ICI hop + switch overhead
PHASE_LATENCY_US = {"intra": 1.0, "cross_pod": 5.0}


@dataclass
class HloCounterBackend:
    """CounterBackend over successive dry-run steps."""

    mesh_shape: tuple
    hw: HwSpec = V5E
    _mem: InMemoryBackend = None

    def __post_init__(self):
        if self._mem is None:
            self._mem = InMemoryBackend()

    # -- CounterBackend protocol --
    def read_counters(self) -> NICCounters:
        return self._mem.read_counters()

    def now_s(self) -> float:
        return self._mem.now_s()

    # -- feeding --
    def observe_step(self, costs: HloCosts, *, compute_window_s: float):
        """Account one executed step of the compiled module."""
        intra_b = 0.0
        cross_b = 0.0
        lat_us = 0.0
        n_packets = 0.0
        for c in costs.collectives:
            wb = c.wire_bytes() * c.multiplier
            cls = classify_collective(c.group0_devices, self.mesh_shape)
            if cls == "cross_pod":
                cross_b += wb
            else:
                intra_b += wb
            # phases ~ ring steps = group_size - 1
            hops = max(c.group_size - 1, 1)
            lat_us += PHASE_LATENCY_US[cls] * hops * c.multiplier
            n_packets += wb / 64.0
        # stall estimate: serialization time beyond the compute window
        ser_s = intra_b / self.hw.ici_bw + cross_b / self.hw.dcn_bw
        flits = n_packets * 5.0
        excess_s = max(0.0, ser_s - compute_window_s)
        stall_cycles = excess_s * 1e9  # 1 GHz NIC-cycle convention
        self._mem.counters.observe(
            flits=int(flits),
            stalled_cycles=int(stall_cycles),
            packets=int(n_packets),
            latency_us_total=lat_us,
        )
        self._mem.advance(max(compute_window_s, ser_s))
