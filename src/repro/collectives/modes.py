"""Collective schedule modes — the TPU analogue of Aries routing modes."""

from __future__ import annotations

import enum

from repro.core.strategies import RoutingMode


class CollectiveMode(enum.Enum):
    #: one-phase flat collective over all participating axes (minimal:
    #: fewest phases, lowest latency; slow pod-boundary links carry the
    #: full ring share)
    DIRECT = "direct"
    #: pod-aware multi-phase schedule (non-minimal: more hops, but the
    #: cross-pod links carry only the per-chip shard)
    HIERARCHICAL = "hierarchical"


#: Aries mode -> schedule, per the DESIGN.md §2 mapping table.
_ROUTING_TO_MODE = {
    RoutingMode.ADAPTIVE_0: CollectiveMode.HIERARCHICAL,
    RoutingMode.ADAPTIVE_1: CollectiveMode.HIERARCHICAL,
    RoutingMode.ADAPTIVE_2: CollectiveMode.DIRECT,
    RoutingMode.ADAPTIVE_3: CollectiveMode.DIRECT,
    RoutingMode.MIN_HASH: CollectiveMode.DIRECT,
    RoutingMode.IN_ORDER: CollectiveMode.DIRECT,
    RoutingMode.NMIN_HASH: CollectiveMode.HIERARCHICAL,
}


def mode_for_routing(mode: RoutingMode) -> CollectiveMode:
    return _ROUTING_TO_MODE[mode]
