"""All-reduce schedules (shard_map) — DIRECT vs HIERARCHICAL.

DIRECT:        psum over every participating axis in one phase.  On a
               multi-pod mesh the ring spans pods, so the slow DCN links
               carry the full 2(n-1)/n ring share.

HIERARCHICAL:  psum_scatter over the intra-pod axis (fast ICI), psum over
               the pod axis on the 1/inner shard (slow links carry
               bytes/inner_size), all_gather back over the intra-pod axis.
               One extra phase ("hop") in exchange for offloading the
               scarce links — exactly the minimal/non-minimal trade the
               paper arbitrates per message.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def _flatten_pad(x, n):
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def allreduce_direct(x, axes):
    """Inside shard_map: one-phase psum over (possibly multiple) axes."""
    return jax.lax.psum(x, axes)


def allreduce_hierarchical(x, pod_axis: str, inner_axis: str,
                           inner_size: int):
    """Inside shard_map: RS(inner) -> AR(pod) -> AG(inner).

    Works for any tensor shape (flattens + pads to inner_size)."""
    orig_shape = x.shape
    flat, pad = _flatten_pad(x, inner_size)
    shard = jax.lax.psum_scatter(
        flat.reshape(inner_size, -1), inner_axis, scatter_dimension=0,
        tiled=False)
    shard = jax.lax.psum(shard, pod_axis)
    full = jax.lax.all_gather(shard, inner_axis, axis=0, tiled=False)
    flat = full.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(orig_shape)


def grad_allreduce(grads, mesh, *, mode, pod_axis: str = "pod",
                   inner_axis: str = "data"):
    """Mean-reduce a gradient pytree across the data-parallel axes with the
    chosen schedule.  Entry point used by train/grad_comm.py.

    grads leaves are data-parallel replicas (one per (pod, data) position);
    the tree is returned averaged."""
    from repro.collectives.modes import CollectiveMode

    axis_names = mesh.axis_names
    has_pod = pod_axis in axis_names
    dp_axes = ((pod_axis, inner_axis) if has_pod else (inner_axis,))
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    inner_size = mesh.shape[inner_axis]

    def reduce_leaf(g):
        if mode == CollectiveMode.HIERARCHICAL and has_pod:
            g = allreduce_hierarchical(g, pod_axis, inner_axis, inner_size)
        else:
            g = allreduce_direct(g, dp_axes)
        return g / n_dp

    def spec_for(leaf):
        return P()  # per-device partial sums along the dp axes

    in_specs = jax.tree_util.tree_map(spec_for, grads)
    return compat.shard_map(
        lambda g: jax.tree_util.tree_map(reduce_leaf, g),
        mesh=mesh, in_specs=(in_specs,), out_specs=in_specs,
        check_vma=False,
    )(grads)
