"""jit'd public wrapper for the flash attention kernel.

On TPU the Pallas kernel runs natively; everywhere else (this CPU
container) it runs in interpret mode, or falls back to the jnp reference
for large shapes where interpretation would be slow.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention_op(q, k, v, *, causal: bool = True,
                       block_q: int = 512, block_k: int = 512,
                       force_kernel: bool = False):
    """Dispatch: Pallas kernel on TPU (or when forced, in interpret mode);
    jnp reference otherwise."""
    if _on_tpu():
        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=False)
    if force_kernel:
        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=True)
    return attention_ref(q, k, v, causal=causal)
