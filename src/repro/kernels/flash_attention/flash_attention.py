"""Blocked causal GQA flash attention — Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the kv dimension is
the innermost ("arbitrary") axis, accumulated across steps via VMEM scratch
(online softmax: running max m, normalizer l, accumulator acc).

BlockSpec tiling (VMEM working set per grid step):
    q   [1, 1, block_q, head_dim]
    k,v [1, 1, block_k, head_dim]     (kv head = q head // group)
    acc [block_q, head_dim] fp32 scratch + m,l [block_q, 1] fp32 scratch

Defaults block_q = block_k = 512 with head_dim 128: working set
~(512*128*2)*3 bytes + fp32 scratch ~ 0.7 MB — comfortably inside the
16 MB v5e VMEM while keeping the MXU matmul dims (block, 128) aligned.

Causal blocks with q_block < k_block are skipped entirely (the index map
still runs, so we guard with pl.when on the compute).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)               # [bk, hd]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]                               # [bq, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)        # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                   # [bq, 1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip fully-masked blocks (strictly above the diagonal)
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q: [B, H, Sq, hd]; k, v: [B, Hkv, Skv, hd] -> [B, H, Sq, hd]."""
    B, H, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=bq, block_k=bk,
        num_kv_blocks=nk)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, group=group: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, group=group: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            _scratch((bq, 1)),      # running max m
            _scratch((bq, 1)),      # running normalizer l
            _scratch((bq, hd)),     # fp32 output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
