"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: [B,H,Sq,hd]; k,v: [B,Hkv,Skv,hd] -> [B,H,Sq,hd] (fp32 math)."""
    B, H, Sq, hd = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(hd)
    if causal:
        Skv = k.shape[2]
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)
