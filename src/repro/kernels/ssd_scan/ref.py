"""Pure-jnp oracle for the SSD within-chunk kernel."""

from __future__ import annotations

import jax.numpy as jnp


def ssd_inner_ref(xdt, b_mat, c_mat, dacum):
    """Same contract as ssd_scan.ssd_inner (fp32 math)."""
    xdt = xdt.astype(jnp.float32)
    b_mat = b_mat.astype(jnp.float32)
    c_mat = c_mat.astype(jnp.float32)
    dacum = dacum.astype(jnp.float32)
    Q = xdt.shape[-2]
    diff = dacum[..., :, None] - dacum[..., None, :]      # [B,Nc,H,i,j]
    ii = jnp.arange(Q)
    L = jnp.where(ii[:, None] >= ii[None, :], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bchin,bchjn->bchij", c_mat, b_mat)
    y = jnp.einsum("bchij,bchjp->bchip", cb * L, xdt)
    decay_last = jnp.exp(dacum[..., -1:] - dacum)          # [B,Nc,H,Q]
    states = jnp.einsum("bchq,bchqn,bchqp->bchnp", decay_last, b_mat, xdt)
    return y, states
