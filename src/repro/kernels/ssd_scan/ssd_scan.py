"""Mamba2 SSD within-chunk block — Pallas TPU kernel.

Computes, for each (batch, chunk, head) grid cell, the quadratic
within-chunk term and the chunk-final state of the SSD decomposition
(arXiv:2405.21060):

    y_diag[i] = sum_{j<=i} (C_i . B_j) * exp(dAcum_i - dAcum_j) * xdt_j
    state     = sum_j exp(dAcum_last - dAcum_j) * B_j^T xdt_j     [N, P]

The cross-chunk recurrence (a cheap [N,P]-state scan over chunks) and the
off-diagonal C_i.state_entering term stay outside the kernel (see ops.py) —
they are O(S*N*P) and bandwidth-trivial next to the O(S*Q*(N+P)) block.

BlockSpec tiling per grid step (VMEM):
    xdt [Q, P], B/C [Q, N], dAcum [1, Q] -> y [Q, P], state [N, P]
    With Q=128 (chunk), P=64, N=128: ~0.2 MB — MXU-aligned matmuls
    (Q x N @ N x Q, Q x Q @ Q x P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xdt_ref, b_ref, c_ref, dacum_ref, y_ref, state_ref):
    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)        # [Q, P]
    b = b_ref[0, 0, 0].astype(jnp.float32)            # [Q, N]
    c = c_ref[0, 0, 0].astype(jnp.float32)            # [Q, N]
    dacum = dacum_ref[0, 0, 0].astype(jnp.float32)    # [Q]
    Q = xdt.shape[0]

    # decay matrix L[i,j] = exp(dacum_i - dacum_j) for j <= i else 0
    diff = dacum[:, None] - dacum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # [Q, Q]
    y_ref[0, 0, 0] = jnp.dot(cb * L, xdt,
                             preferred_element_type=jnp.float32
                             ).astype(y_ref.dtype)

    decay_last = jnp.exp(dacum[-1] - dacum)                    # [Q]
    state_ref[0, 0, 0] = jnp.dot((b * decay_last[:, None]).T, xdt,
                                 preferred_element_type=jnp.float32
                                 ).astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_inner(xdt, b_mat, c_mat, dacum, *, interpret: bool = False):
    """xdt: [B,Nc,H,Q,P]; b/c_mat: [B,Nc,H,Q,N]; dacum: [B,Nc,H,Q].

    Returns (y_diag [B,Nc,H,Q,P], states [B,Nc,H,N,P]) — both fp32.
    """
    B, Nc, H, Q, P = xdt.shape
    N = b_mat.shape[-1]
    grid = (B, Nc, H)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Nc, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, Nc, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, b_mat, c_mat, dacum)
