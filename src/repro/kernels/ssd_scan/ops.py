"""jit'd public wrapper: full SSD scan built on the within-chunk kernel.

Composes the Pallas within-chunk block with the cheap cross-chunk state
recurrence + off-diagonal term, reproducing models.mamba2.ssd_chunked
exactly (the test asserts equality against it)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_inner_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_inner


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan_op(x, dt, a_log, b_mat, c_mat, chunk: int, *,
                init_state=None, force_kernel: bool = False):
    """Same contract as models.mamba2.ssd_chunked; Pallas inner block."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    Q = min(chunk, S)
    while S % Q:          # match models.mamba2: largest divisor <= chunk
        Q -= 1
    Nc = S // Q
    f32 = jnp.float32

    A = -jnp.exp(a_log.astype(f32))
    xb = x.reshape(B, Nc, Q, H, P).astype(f32)
    dtb = dt.reshape(B, Nc, Q, H).astype(f32)
    Bb = b_mat.reshape(B, Nc, Q, H, N).astype(f32)
    Cb = c_mat.reshape(B, Nc, Q, H, N).astype(f32)
    xdt = (xb * dtb[..., None]).transpose(0, 1, 3, 2, 4)   # [B,Nc,H,Q,P]
    dacum = jnp.cumsum(dtb * A, axis=2).transpose(0, 1, 3, 2)  # [B,Nc,H,Q]
    b_t = Bb.transpose(0, 1, 3, 2, 4)
    c_t = Cb.transpose(0, 1, 3, 2, 4)

    if _on_tpu():
        y_diag, states = ssd_inner(xdt, b_t, c_t, dacum, interpret=False)
    elif force_kernel:
        y_diag, states = ssd_inner(xdt, b_t, c_t, dacum, interpret=True)
    else:
        y_diag, states = ssd_inner_ref(xdt, b_t, c_t, dacum)

    # cross-chunk recurrence + off-diagonal term (cheap, outside kernel)
    chunk_decay = jnp.exp(dacum[..., -1])                  # [B,Nc,H]
    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((B, H, N, P), f32))

    def step(s, inp):
        cd, st = inp
        return cd[..., None, None] * s + st, s

    final, entering = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                # [B,Nc,H,N,P]
    y_off = jnp.einsum("bchqn,bchnp,bchq->bchqp",
                       c_t, entering, jnp.exp(dacum))
    y = (y_diag + y_off).transpose(0, 1, 3, 2, 4).reshape(B, S, H, P)
    return y.astype(x.dtype), final
