"""Fused RMSNorm — Pallas TPU kernel.

Row-blocked: each grid step normalizes a [block_rows, D] tile in fp32 and
applies the gain, writing back in the input dtype.  One pass over HBM
(read x, write y) instead of the unfused read-reduce-read-scale pattern.

BlockSpec: x [block_rows, D] with D up to ~8k in VMEM (block_rows=256,
D=4096, bf16: 2 MB tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fused(x, gamma, *, eps: float = 1e-5, block_rows: int = 256,
                  interpret: bool = False):
    """x: [..., D]; gamma: [D]."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out.reshape(orig_shape)
