"""jit'd public wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

import jax

from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm_fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rmsnorm_op(x, gamma, *, eps: float = 1e-5, force_kernel: bool = False):
    if _on_tpu():
        return rmsnorm_fused(x, gamma, eps=eps, interpret=False)
    if force_kernel:
        return rmsnorm_fused(x, gamma, eps=eps, interpret=True)
    return rmsnorm_ref(x, gamma, eps=eps)
