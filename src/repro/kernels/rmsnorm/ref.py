"""Pure-jnp oracle for the fused RMSNorm kernel (same as models.common)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, gamma, *, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps))
            * gamma.astype(jnp.float32)).astype(dt)
