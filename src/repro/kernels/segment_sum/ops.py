"""jit'd public wrapper for the segment-sum kernel."""

from __future__ import annotations

from repro.compat.runtime import on_tpu
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.segment_sum.segment_sum import segment_sum_pallas


def segment_sum_op(values, segment_ids, num_segments: int, *,
                   force_kernel: bool | None = None):
    """Dispatch with the same tri-state as ``SimParams.pallas_kernel``:

    ``None`` (auto) — Pallas kernel on TPU, ``jax.ops.segment_sum``
    reference elsewhere; ``True`` — always Pallas (interpret mode
    off-TPU, the parity-testing path); ``False`` — never Pallas, even
    on TPU."""
    if force_kernel is None:
        force_kernel = on_tpu()
    if force_kernel:
        return segment_sum_pallas(values, segment_ids, num_segments,
                                  interpret=not on_tpu())
    return segment_sum_ref(values, segment_ids, num_segments)
