"""jit'd public wrapper for the segment-sum kernel."""

from __future__ import annotations

import jax

from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.segment_sum.segment_sum import segment_sum_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_sum_op(values, segment_ids, num_segments: int, *,
                   force_kernel: bool = False):
    """Dispatch: Pallas kernel on TPU (or when forced, in interpret
    mode); jax.ops.segment_sum reference otherwise."""
    if _on_tpu():
        return segment_sum_pallas(values, segment_ids, num_segments,
                                  interpret=False)
    if force_kernel:
        return segment_sum_pallas(values, segment_ids, num_segments,
                                  interpret=True)
    return segment_sum_ref(values, segment_ids, num_segments)
