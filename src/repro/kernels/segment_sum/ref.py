"""Pure-jnp oracle for the segment-sum kernel."""

from __future__ import annotations

import jax


def segment_sum_ref(values, segment_ids, num_segments: int):
    """sum of `values` per segment id — np.bincount(weights=...) in jax."""
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)
