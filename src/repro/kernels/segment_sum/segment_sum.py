"""Blocked one-hot segment-sum — Pallas TPU kernel.

The Dragonfly fast path's link-load accumulation is a scatter-add
(np.bincount with weights): 1-2M (link id, bytes) pairs accumulated
into ~56k link bins, four times per phase.  Scatter is the one shape
TPUs hate, so the kernel recasts it MXU/VPU-friendly as a blocked
one-hot reduction:

  * the pair stream is tiled into [block_pairs] chunks, the segment
    axis into [block_segs] chunks;
  * grid = (segment_blocks, pair_blocks) with the PAIR dim innermost,
    so each output block stays resident in VMEM across the whole pair
    sweep (init at pair-block 0, accumulate, flush once);
  * each step builds the one-hot mask (ids == seg_base + iota) for its
    tile and reduces mask*values over the pair axis.

Out-of-range ids (the padding the wrapper adds to reach a block
multiple) match no segment and vanish.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_sum_kernel(ids_ref, val_ref, o_ref, *, block_segs: int):
    j = pl.program_id(1)                  # pair-block index (inner dim)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg_base = pl.program_id(0) * block_segs
    ids = ids_ref[...]                    # [block_pairs] int32
    vals = val_ref[...].astype(jnp.float32)
    seg = seg_base + jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], block_segs), 1)
    hit = ids[:, None] == seg             # [block_pairs, block_segs]
    o_ref[...] += jnp.sum(jnp.where(hit, vals[:, None], 0.0), axis=0)


@functools.partial(jax.jit, static_argnames=("num_segments", "block_pairs",
                                             "block_segs", "interpret"))
def segment_sum_pallas(values, segment_ids, num_segments: int, *,
                       block_pairs: int = 1024, block_segs: int = 512,
                       interpret: bool = False):
    """values: [n] float; segment_ids: [n] int -> [num_segments] float32."""
    n = values.shape[0]
    bp = max(1, min(block_pairs, n))
    bs = max(1, min(block_segs, num_segments))
    n_pad = -(-max(n, 1) // bp) * bp
    segs_pad = -(-num_segments // bs) * bs
    ids = jnp.full(n_pad, segs_pad, dtype=jnp.int32)
    ids = ids.at[:n].set(segment_ids.astype(jnp.int32))
    vals = jnp.zeros(n_pad, dtype=jnp.float32)
    vals = vals.at[:n].set(values.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(_segment_sum_kernel, block_segs=bs),
        grid=(segs_pad // bs, n_pad // bp),
        in_specs=[
            pl.BlockSpec((bp,), lambda i, j: (j,)),
            pl.BlockSpec((bp,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((segs_pad,), jnp.float32),
        interpret=interpret,
    )(ids, vals)
    return out[:num_segments]
