from repro.kernels.segment_sum.ops import segment_sum_op
from repro.kernels.segment_sum.ref import segment_sum_ref
from repro.kernels.segment_sum.segment_sum import segment_sum_pallas

__all__ = ["segment_sum_op", "segment_sum_ref", "segment_sum_pallas"]
