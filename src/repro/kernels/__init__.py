# repro.kernels — Pallas TPU kernels for the framework's compute hot-spots.
#
# The paper (application-aware routing) has no kernel-level contribution —
# per DESIGN.md §8 these kernels serve the FRAMEWORK's perf-critical layers:
#   flash_attention/  blocked online-softmax GQA attention (train/prefill)
#   ssd_scan/         Mamba2 SSD within-chunk quadratic block
#   rmsnorm/          fused RMSNorm
#
# Each kernel directory holds <name>.py (pl.pallas_call + BlockSpec VMEM
# tiling), ops.py (jit'd wrapper, interpret=True on CPU), ref.py (pure-jnp
# oracle the tests assert against).
