"""NIC network-counter abstraction — paper §2.3.

The paper relies *only* on NIC-side counters (request flits, stalled cycles,
request packets, cumulative latency) because (a) users cannot see network
tiles outside their job and (b) tile counters mix traffic from other jobs
(§3.2).  We model exactly those four counters and the derived (L, s) pair.

Backends:
  * the Dragonfly simulator (repro.dragonfly.simulator) increments counters
    as its fluid model moves flits — the faithful reproduction path;
  * the HLO backend (repro.collectives.hlo_counters) synthesizes the same
    counters from a compiled XLA module's collective ops — the TPU dry-run
    path, where "request flits" become bytes-on-wire per link class.

Counters are monotonically increasing, like the hardware; consumers read
deltas through CounterWindow (which also fixes the §3.2 pitfall: deltas are
normalized per observation window, never correlated raw).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol


@dataclass
class NICCounters:
    """The four Aries NIC counters used by the paper (monotonic), plus
    the congestion-notification event counter of the notification
    channel (SimParams.notify_*, docs/policy_api.md) — one event per
    sent message whose bytes crossed a visibly-flagged link.  Like the
    other four it is NIC-side and allocation-scoped: a job only ever
    counts notifications its own traffic received (§3.2)."""

    request_flits: int = 0
    request_flits_stalled_cycles: int = 0
    request_packets: int = 0
    request_packets_cumulative_latency_us: float = 0.0
    congestion_notifications: int = 0

    def observe(self, flits: int, stalled_cycles: int, packets: int,
                latency_us_total: float, notifications: int = 0) -> None:
        self.request_flits += flits
        self.request_flits_stalled_cycles += stalled_cycles
        self.request_packets += packets
        self.request_packets_cumulative_latency_us += latency_us_total
        self.congestion_notifications += notifications

    def snapshot(self) -> "NICCounters":
        return NICCounters(
            self.request_flits,
            self.request_flits_stalled_cycles,
            self.request_packets,
            self.request_packets_cumulative_latency_us,
            self.congestion_notifications,
        )


@dataclass(frozen=True)
class CounterDelta:
    """Counter difference over one observation window, with derived L and s."""

    flits: int
    stalled_cycles: int
    packets: int
    latency_us_total: float
    window_s: float  # wall-clock length of the observation window
    notifications: int = 0  # congestion-notification events in the window

    @property
    def mean_latency_us(self) -> float:
        """L — average request->response latency (us)."""
        return self.latency_us_total / self.packets if self.packets else 0.0

    @property
    def notified_fraction(self) -> float:
        """Fraction of the window's messages that saw a congestion
        notification (the notification channel's per-window signal)."""
        return self.notifications / self.packets if self.packets else 0.0

    @property
    def stalls_per_flit(self) -> float:
        """s — average stall cycles per ready flit."""
        return self.stalled_cycles / self.flits if self.flits else 0.0

    @property
    def flit_rate(self) -> float:
        """Flits per second — the §3.2-safe normalized traffic intensity."""
        return self.flits / self.window_s if self.window_s > 0 else 0.0


class CounterBackend(Protocol):
    """Anything that exposes live NICCounters and a wall clock."""

    def read_counters(self) -> NICCounters: ...
    def now_s(self) -> float: ...


@dataclass
class CounterWindow:
    """Delta reader over a CounterBackend (fixes §3.2: always windowed)."""

    backend: CounterBackend
    _last: NICCounters = field(default_factory=NICCounters)
    _last_t: float = 0.0
    _primed: bool = False

    def read(self) -> CounterDelta:
        cur = self.backend.read_counters()
        now = self.backend.now_s()
        if not self._primed:
            self._last, self._last_t, self._primed = cur.snapshot(), now, True
            return CounterDelta(0, 0, 0, 0.0, 0.0)
        delta = CounterDelta(
            flits=cur.request_flits - self._last.request_flits,
            stalled_cycles=(cur.request_flits_stalled_cycles
                            - self._last.request_flits_stalled_cycles),
            packets=cur.request_packets - self._last.request_packets,
            latency_us_total=(cur.request_packets_cumulative_latency_us
                              - self._last.request_packets_cumulative_latency_us),
            window_s=now - self._last_t,
            notifications=(cur.congestion_notifications
                           - self._last.congestion_notifications),
        )
        self._last, self._last_t = cur.snapshot(), now
        return delta


@dataclass
class InMemoryBackend:
    """Trivial backend for unit tests and for the TPU/HLO adapter, which
    pushes synthesized counter increments into it."""

    counters: NICCounters = field(default_factory=NICCounters)
    clock_s: float = 0.0

    def read_counters(self) -> NICCounters:
        return self.counters

    def now_s(self) -> float:
        return self.clock_s

    def advance(self, dt_s: float) -> None:
        self.clock_s += dt_s
