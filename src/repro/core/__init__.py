# repro.core — the paper's primary contribution.
#
# "Mitigating Network Noise on Dragonfly Networks through Application-Aware
# Routing" (De Sensi, Di Girolamo, Hoefler — SC'19) contributes:
#   1. a NIC-counter methodology for isolating network noise (noise.py),
#   2. a LogP-inspired counter-driven performance model, Eq.(1)/(2)
#      (perf_model.py),
#   3. evidence that adaptive non-minimal routing is itself a noise source,
#   4. Algorithm 1 — per-message application-aware routing-mode selection
#      (app_aware.py), with counter backends (counters.py) and scaling-factor
#      calibration (calibration.py).
#
# Everything here is network-agnostic: the same Algorithm 1 instance drives
# the Cray-Aries Dragonfly simulator (repro.dragonfly) for the faithful
# reproduction AND the TPU collective-schedule selector (repro.collectives)
# for the framework integration.

from repro.core.strategies import RoutingMode, ARIES_MODES, ADAPTIVE_MODES
from repro.core.perf_model import (
    AriesNICModel,
    MessageShape,
    predict_transmission_cycles,
    flits_and_packets,
)
from repro.core.counters import NICCounters, CounterWindow, CounterBackend
from repro.core.noise import qcd, iqr, NoiseReport, estimate_noise
from repro.core.calibration import ScalingFactors, calibrate_scaling_factors


def __getattr__(name):
    # Lazy: the deprecated app_aware shim pulls repro.policy, which pulls
    # repro.core.perf_model — an eager import here would make
    # `import repro.policy` (as the first repro import) a circular error.
    if name in ("AppAwareRouter", "RouterConfig"):
        from repro.core import app_aware
        return getattr(app_aware, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "RoutingMode", "ARIES_MODES", "ADAPTIVE_MODES",
    "AriesNICModel", "MessageShape", "predict_transmission_cycles",
    "flits_and_packets",
    "NICCounters", "CounterWindow", "CounterBackend",
    "qcd", "iqr", "NoiseReport", "estimate_noise",
    "AppAwareRouter", "RouterConfig",
    "ScalingFactors", "calibrate_scaling_factors",
]
