"""LogP-inspired NIC-counter performance model — paper §2.4.

The model predicts the time between a PUT/GET command reaching the sender's
NIC and the last flit arriving at the receiver's NIC, **from NIC counters
only** (no host-side delays), which is the property §3.3 of the paper needs.

Quantities (paper notation):
    L    packet latency in NIC cycles (counter: cumulative latency / packets)
    s    mean stall cycles a ready-to-forward flit waits (counter: stalled
         cycles / request flits)
    k    flits per packet (5 for PUT: 1 header + 4 payload; 1 for GET)
    f    flits of the whole application message
    p    packets of the whole application message (1 per 64B)

Eq. (1):  T_msg = L/2 + f*(s+1)
Eq. (2):  T_msg ~= (p+512)/1024 * L + f*(s+1)
          (Aries NICs allow 1024 outstanding packets; one latency stall every
          1024 packets in the best case, plus the initial L/2 ~ averaged into
          the (p+512)/1024 coefficient.)

The same two-term structure is reused for the TPU adaptation: L ↦ phase/hop
latency of a collective schedule, f*(s+1) ↦ serialization time inflated by
the observed occupancy factor. See repro/collectives/selector.py.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# --- Aries constants (paper §2.1/§2.4) -------------------------------------
PACKET_PAYLOAD_BYTES = 64     # one request packet per 64 bytes
PUT_FLITS_PER_PACKET = 5      # 1 header + up to 4 payload flits
GET_FLITS_PER_PACKET = 1      # request carries no payload
MAX_OUTSTANDING_PACKETS = 1024
NIC_CLOCK_GHZ = 1.0           # cycle<->ns conversion used by counters


@dataclass(frozen=True)
class MessageShape:
    """Flit/packet decomposition of one application message."""

    size_bytes: int
    is_put: bool = True

    @property
    def packets(self) -> int:
        return max(1, math.ceil(self.size_bytes / PACKET_PAYLOAD_BYTES))

    @property
    def flits_per_packet(self) -> int:
        return PUT_FLITS_PER_PACKET if self.is_put else GET_FLITS_PER_PACKET

    @property
    def flits(self) -> int:
        # Last packet may carry fewer payload flits; the paper's model uses
        # the aggregate f, so account for the possibly-short tail packet.
        if not self.is_put:
            return self.packets
        full, rem = divmod(self.size_bytes, PACKET_PAYLOAD_BYTES)
        tail = 1 + math.ceil(rem / 16) if rem else 0  # 16B per payload flit
        return full * PUT_FLITS_PER_PACKET + tail


def flits_and_packets(size_bytes: int, is_put: bool = True) -> tuple[int, int]:
    m = MessageShape(size_bytes, is_put)
    return m.flits, m.packets


def flits_and_packets_vec(size_bytes, is_put: bool = True):
    """Vectorized MessageShape over an int array (same values as the
    scalar path; integer ceilings avoid float rounding)."""
    import numpy as np

    size = np.asarray(size_bytes, dtype=np.int64)
    packets = np.maximum(1, (size + PACKET_PAYLOAD_BYTES - 1)
                         // PACKET_PAYLOAD_BYTES)
    if not is_put:
        return packets, packets
    full, rem = np.divmod(size, PACKET_PAYLOAD_BYTES)
    tail = np.where(rem > 0, 1 + (rem + 15) // 16, 0)  # 16B per payload flit
    return full * PUT_FLITS_PER_PACKET + tail, packets


def transmission_cycles_eq1(latency_cycles: float, stalls_per_flit: float,
                            flits: int) -> float:
    """Eq. (1): T = L/2 + f*(s+1)."""
    return latency_cycles / 2.0 + flits * (stalls_per_flit + 1.0)


def transmission_cycles_eq2(latency_cycles: float, stalls_per_flit: float,
                            flits: int, packets: int) -> float:
    """Eq. (2): T ~= (p+512)/1024 * L + f*(s+1)."""
    window = (packets + MAX_OUTSTANDING_PACKETS // 2) / MAX_OUTSTANDING_PACKETS
    return window * latency_cycles + flits * (stalls_per_flit + 1.0)


def predict_transmission_cycles(size_bytes: int, latency_cycles: float,
                                stalls_per_flit: float, *,
                                is_put: bool = True) -> float:
    """Eq. (2) from a message size and the two NIC counters."""
    f, p = flits_and_packets(size_bytes, is_put)
    return transmission_cycles_eq2(latency_cycles, stalls_per_flit, f, p)


def flit_threshold(l_a: float, s_a: float, l_b: float, s_b: float,
                   packets: int) -> float:
    """Eq. (4): the flit count below which mode *b* (higher-bias / lower-
    latency) beats mode *a* (adaptive / lower-stall).

        f < (L_a - L_b) / (s_b - s_a) * (p+512)/1024

    Returns +inf when b dominates on both terms, -inf (well, 0-crossing)
    semantics are handled by the caller comparing f < threshold; if
    s_b <= s_a and L_b >= L_a the threshold is 0 (never switch)."""
    window = (packets + MAX_OUTSTANDING_PACKETS // 2) / MAX_OUTSTANDING_PACKETS
    dl = l_a - l_b
    ds = s_b - s_a
    if ds <= 0.0:
        # Outside Eq.(4)'s validity domain (the paper's setting is
        # s_b > s_a: the minimal-biased mode stalls more).  b dominates
        # when it is no worse on BOTH terms; otherwise the caller must
        # compare Eq.(3) directly (AppAwareRouter does).
        return math.inf if dl >= 0.0 else 0.0
    return dl / ds * window


@dataclass(frozen=True)
class AriesNICModel:
    """Bundles the model with a clock so callers can speak seconds."""

    clock_ghz: float = NIC_CLOCK_GHZ

    def cycles_to_us(self, cycles: float) -> float:
        return cycles / (self.clock_ghz * 1e3)

    def us_to_cycles(self, us: float) -> float:
        return us * self.clock_ghz * 1e3

    def predict_us(self, size_bytes: int, latency_us: float,
                   stalls_per_flit: float, *, is_put: bool = True) -> float:
        lat_cyc = self.us_to_cycles(latency_us)
        cyc = predict_transmission_cycles(
            size_bytes, lat_cyc, stalls_per_flit, is_put=is_put)
        return self.cycles_to_us(cyc)
