"""Network-noise estimation methodology — paper §3.

Three rules, each of which this module encodes as an executable guard or
statistic (they are exercised by benchmarks/fig3..5 and the tests):

  §3.1 Fix the allocation: only samples taken inside the *same* allocation
       are comparable (placement alone spans 3 orders of magnitude).
       -> NoiseReport refuses to pool samples across allocation ids.

  §3.2 Correlation is not causation: raw counter values grow with the
       observation window even for an idle app.
       -> only CounterDelta (windowed, normalized) quantities enter reports.

  §3.3 Communication-time variance is not network noise: host-side effects
       (OS noise, imbalance) inflate MPI-call variance.
       -> noise is quantified on NIC *latency* samples via the QCD, with the
          execution-time QCD reported alongside only as an upper bound.

The dispersion statistic is the Quartile Coefficient of Dispersion:
    QCD = (Q3 - Q1) / (Q3 + Q1)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def iqr(samples) -> float:
    """Inter-quartile range Q3 - Q1."""
    q1, q3 = np.percentile(np.asarray(samples, dtype=np.float64), [25, 75])
    return float(q3 - q1)


def qcd(samples) -> float:
    """Quartile coefficient of dispersion (paper §3.3)."""
    q1, q3 = np.percentile(np.asarray(samples, dtype=np.float64), [25, 75])
    denom = q3 + q1
    if denom == 0.0:
        return 0.0
    return float((q3 - q1) / denom)


@dataclass(frozen=True)
class NoiseReport:
    """Noise summary for one (allocation, workload, routing-mode) cell."""

    allocation_id: str
    n_samples: int
    median_exec_us: float
    qcd_exec: float          # upper bound on noise (includes host effects)
    median_latency_us: float
    qcd_latency: float       # the network-noise estimate (paper §3.3)
    mean_stalls_per_flit: float
    qcd_stalls: float
    outlier_ratio: float     # fraction of samples > 10x median (Fig. 3 tails)

    @property
    def network_noise(self) -> float:
        """The paper's network-noise metric: dispersion of NIC latency."""
        return self.qcd_latency


class AllocationMismatch(ValueError):
    """Raised when samples from different allocations are pooled (§3.1)."""


@dataclass
class NoiseEstimator:
    """Accumulates per-iteration samples, enforcing the §3 rules."""

    allocation_id: str
    exec_us: list = field(default_factory=list)
    latency_us: list = field(default_factory=list)
    stalls: list = field(default_factory=list)

    def add(self, *, allocation_id: str, exec_us: float,
            latency_us: float, stalls_per_flit: float) -> None:
        if allocation_id != self.allocation_id:
            raise AllocationMismatch(
                f"sample from allocation {allocation_id!r} cannot be pooled "
                f"with {self.allocation_id!r} (paper §3.1: fix the allocation)"
            )
        self.exec_us.append(exec_us)
        self.latency_us.append(latency_us)
        self.stalls.append(stalls_per_flit)

    def report(self) -> NoiseReport:
        ex = np.asarray(self.exec_us, dtype=np.float64)
        la = np.asarray(self.latency_us, dtype=np.float64)
        st = np.asarray(self.stalls, dtype=np.float64)
        med = float(np.median(ex)) if ex.size else 0.0
        return NoiseReport(
            allocation_id=self.allocation_id,
            n_samples=int(ex.size),
            median_exec_us=med,
            qcd_exec=qcd(ex) if ex.size else 0.0,
            median_latency_us=float(np.median(la)) if la.size else 0.0,
            qcd_latency=qcd(la) if la.size else 0.0,
            mean_stalls_per_flit=float(st.mean()) if st.size else 0.0,
            qcd_stalls=qcd(st) if st.size else 0.0,
            outlier_ratio=float((ex > 10.0 * med).mean()) if ex.size else 0.0,
        )


def estimate_noise(allocation_id: str, exec_us, latency_us,
                   stalls_per_flit) -> NoiseReport:
    """One-shot NoiseReport from parallel sample arrays."""
    est = NoiseEstimator(allocation_id)
    for e, l, s in zip(exec_us, latency_us, stalls_per_flit):
        est.add(allocation_id=allocation_id, exec_us=e, latency_us=l,
                stalls_per_flit=s)
    return est.report()
