"""Routing modes — §2.2 of the paper.

On Cray Aries the user-selectable routing modes (MPICH_GNI_ROUTING_MODE) are
a restricted set of UGAL bias levels plus deterministic modes.  We model the
same enumeration; the Dragonfly simulator interprets the bias, and the TPU
collective layer maps each mode to a collective schedule (see
repro.collectives.modes for the mapping table in DESIGN.md §2).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass


class RoutingMode(enum.Enum):
    """Aries routing modes (paper §2.2)."""

    #: ADAPTIVE_0 — UGAL with no bias toward minimal paths ("ADAPTIVE").
    ADAPTIVE_0 = "ADAPTIVE_0"
    #: ADAPTIVE_1 — bias toward minimal increases as the packet approaches the
    #: destination ("INCREASINGLY MINIMAL BIAS"); Aries default for alltoall.
    ADAPTIVE_1 = "ADAPTIVE_1"
    #: ADAPTIVE_2 — low constant bias toward minimal.
    ADAPTIVE_2 = "ADAPTIVE_2"
    #: ADAPTIVE_3 — high constant bias toward minimal ("ADAPTIVE HIGH BIAS").
    ADAPTIVE_3 = "ADAPTIVE_3"
    #: Deterministic minimal, path picked by header hash.
    MIN_HASH = "MIN_HASH"
    #: Deterministic non-minimal, path picked by header hash.
    NMIN_HASH = "NMIN_HASH"
    #: Deterministic minimal, in-order delivery.
    IN_ORDER = "IN_ORDER"

    @property
    def is_adaptive(self) -> bool:
        return self in ADAPTIVE_MODES

    @property
    def minimal_bias(self) -> float:
        """Constant additive bias applied to the *non-minimal* congestion
        estimate, in units of mean queue depth.  The exact Aries values are
        not public (paper §2.2); these are the calibration defaults used by
        the simulator and exposed for sensitivity sweeps."""
        return _DEFAULT_BIAS[self]


# Aliases used throughout the paper's prose.
ADAPTIVE = RoutingMode.ADAPTIVE_0
INCREASINGLY_MINIMAL_BIAS = RoutingMode.ADAPTIVE_1
LOW_BIAS = RoutingMode.ADAPTIVE_2
HIGH_BIAS = RoutingMode.ADAPTIVE_3

ARIES_MODES = tuple(RoutingMode)
ADAPTIVE_MODES = (
    RoutingMode.ADAPTIVE_0,
    RoutingMode.ADAPTIVE_1,
    RoutingMode.ADAPTIVE_2,
    RoutingMode.ADAPTIVE_3,
)

# Bias defaults (in mean-queue-depth units). ADAPTIVE_1's bias is hop-
# dependent; the value here is its *terminal* bias (at the last hop), the
# simulator interpolates 0 -> terminal along the path (Bataineh et al. 2017).
_DEFAULT_BIAS = {
    RoutingMode.ADAPTIVE_0: 0.0,
    RoutingMode.ADAPTIVE_1: 6.0,
    RoutingMode.ADAPTIVE_2: 2.0,
    RoutingMode.ADAPTIVE_3: 8.0,
    RoutingMode.MIN_HASH: float("inf"),
    RoutingMode.NMIN_HASH: float("-inf"),
    RoutingMode.IN_ORDER: float("inf"),
}


@dataclass(frozen=True)
class ModePerformance:
    """Per-mode observed telemetry: the (L, s) pair of the paper.

    latency_cycles: request->response packet latency L in NIC cycles.
    stall_cycles_per_flit: mean stall cycles s a ready flit waits.
    age: number of selector invocations since this sample was taken
         (Algorithm 1 discards samples that are "too old").
    """

    latency_cycles: float
    stall_cycles_per_flit: float
    age: int = 0

    def aged(self) -> "ModePerformance":
        return ModePerformance(
            self.latency_cycles, self.stall_cycles_per_flit, self.age + 1
        )
