"""DEPRECATED shim — Algorithm 1 now lives in `repro.policy.app_aware`.

`AppAwareRouter` keeps the seed's scalar select/observe API working by
delegating to a single-call-site `AppAwarePolicy` in "message"
granularity, which is decision-for-decision identical to the original
implementation (tests/test_policy.py proves it on recorded traces).

New code should use:

    from repro.policy import AppAwareConfig, AppAwarePolicy, PolicyEngine

`RouterConfig` is an alias of `repro.policy.AppAwareConfig` (same fields,
same defaults).
"""

from __future__ import annotations

import warnings
from typing import Hashable, Optional

# NOTE: repro.policy imports are deferred — policy.app_aware pulls
# repro.core.perf_model, which runs repro.core.__init__, which imports
# this module; an eager import here would make `import repro.policy`
# (before any repro.core import) fail with a circular-import error.


def __getattr__(name):
    # legacy alias — the config moved to repro.policy (fields unchanged)
    if name in ("RouterConfig", "AppAwareConfig"):
        from repro.policy.app_aware import AppAwareConfig
        return AppAwareConfig
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class AppAwareRouter:
    """Deprecated scalar front-end over `repro.policy.AppAwarePolicy`.

    Every attribute of the seed class (`current`, `samples`,
    `cumulative_bytes`, `sent_bytes_by_mode`, `decisions`,
    `_pending_mode`) is proxied to the underlying per-site Algorithm-1
    automaton, so existing callers and tests observe identical state.
    """

    def __init__(self, config=None, current: Hashable = None, *,
                 policy=None):
        from repro.policy.app_aware import AppAwareConfig, AppAwarePolicy

        warnings.warn(
            "AppAwareRouter is deprecated; use repro.policy.AppAwarePolicy "
            "or repro.policy.PolicyEngine (see docs/policy_api.md)",
            DeprecationWarning, stacklevel=2)
        self.config = config or AppAwareConfig()
        self.policy = policy or AppAwarePolicy(self.config,
                                               granularity="message")
        if current is not None:
            self._site.current = current

    # -------------------------------------------------------- state proxies
    @property
    def _site(self):
        return self.policy.site("default")

    @property
    def current(self) -> Hashable:
        return self._site.current

    @current.setter
    def current(self, value: Hashable) -> None:
        self._site.current = value

    @property
    def samples(self) -> dict:
        return self._site.samples

    @samples.setter
    def samples(self, value: dict) -> None:
        self._site.samples = value

    @property
    def cumulative_bytes(self) -> int:
        return self._site.cumulative_bytes

    @cumulative_bytes.setter
    def cumulative_bytes(self, value: int) -> None:
        self._site.cumulative_bytes = value

    @property
    def decisions(self) -> int:
        return self._site.decisions

    @property
    def sent_bytes_by_mode(self) -> dict:
        return self._site.ledger.sent

    @property
    def gated_bytes_by_mode(self) -> dict:
        """Bytes the 4 KiB gate forced to mode_b without a decision
        (tracked separately — see ISSUE satellite / Fig. 8/9 semantics)."""
        return self._site.ledger.gated

    @property
    def decided_bytes_by_mode(self) -> dict:
        """Bytes routed by actual Algorithm-1 decisions."""
        return self._site.ledger.decided

    @property
    def _pending_mode(self) -> Optional[Hashable]:
        return self._site._pending_mode

    # ------------------------------------------------------------ legacy API
    def select(self, msg_size_bytes: int, *, alltoall: bool = False
               ) -> Hashable:
        """selectRouting(msgSize) — Algorithm 1 (delegated)."""
        return self._site.select(msg_size_bytes, alltoall=alltoall)

    def observe(self, latency_cycles: float, stalls_per_flit: float) -> None:
        self._site.observe(latency_cycles, stalls_per_flit)

    def traffic_fraction(self, mode: Hashable, *,
                         include_gated: bool = True) -> float:
        """Fraction of bytes sent with `mode` (the x-axis % in Fig. 8/9).
        include_gated=False excludes gate-forced bytes, counting only
        decision-routed traffic."""
        return self._site.traffic_fraction(mode,
                                           include_gated=include_gated)

    def gated_fraction(self) -> float:
        return self._site.ledger.gated_fraction()
