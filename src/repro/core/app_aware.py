"""Algorithm 1 — application-aware routing selection (paper §4.2/§4.3).

Before each message is sent, `AppAwareRouter.select(msg_size)` returns the
routing mode to use.  After the message is sent, the caller feeds back the
NIC counters observed for that send via `observe(L, s)`.

Faithful details reproduced from the paper:
  * the application starts in ADAPTIVE (the Aries default);
  * for alltoall call sites, "default" means INCREASINGLY MINIMAL BIAS
    (ADAPTIVE_1), matching MPICH_GNI_A2A_ROUTING_MODE;
  * decision rule Eq. (4):  switch to HIGH BIAS iff
        f < (L_ad - L_bs)/(s_bs - s_ad) * (p+512)/1024
    and the dual inequality to switch back;
  * (L, s) for the *other* mode are estimated by scaling factors λ, σ when
    the stored sample is older than `max_sample_age` selector invocations;
  * a cumulative-size gate: the decision logic runs only once at least
    `cumulative_threshold_bytes` (4 KiB) of traffic has accumulated since
    the last decision; below the gate, messages are sent with HIGH BIAS
    (small messages are latency-bound and HIGH BIAS has lower latency);
  * counters are read after the send so the decision never delays the
    message (the router is strictly one message behind, as in the paper).

The router is *network-agnostic*: modes are opaque labels `mode_a` (the
spread/adaptive schedule) and `mode_b` (the minimal/low-latency schedule),
so the same class arbitrates Aries routing modes in the Dragonfly simulator
and DIRECT-vs-HIERARCHICAL collective schedules on the TPU mesh
(repro/collectives/selector.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable, Optional

from repro.core.perf_model import (flit_threshold, flits_and_packets,
                                   transmission_cycles_eq2)
from repro.core.strategies import ModePerformance, RoutingMode


@dataclass(frozen=True)
class RouterConfig:
    mode_a: Hashable = RoutingMode.ADAPTIVE_0      # "Default"/spread schedule
    mode_b: Hashable = RoutingMode.ADAPTIVE_3      # high-bias/minimal schedule
    #: default mode_a replacement for alltoall call sites (paper §4.2 end).
    mode_a_alltoall: Hashable = RoutingMode.ADAPTIVE_1
    cumulative_threshold_bytes: int = 4 * 1024      # experimentally 4 KiB
    max_sample_age: int = 16                        # "too old" horizon
    #: λ, σ — scaling factors mapping mode_a's (L, s) to a mode_b estimate;
    #: medians over microbenchmark sweeps (core/calibration.py).
    lambda_latency: float = 0.8
    sigma_stalls: float = 1.6
    is_put: bool = True


@dataclass
class AppAwareRouter:
    config: RouterConfig = field(default_factory=RouterConfig)
    current: Hashable = None
    samples: dict = field(default_factory=dict)  # mode -> ModePerformance
    cumulative_bytes: int = 0
    sent_bytes_by_mode: dict = field(default_factory=dict)
    decisions: int = 0
    _pending_mode: Optional[Hashable] = None

    def __post_init__(self) -> None:
        if self.current is None:
            self.current = self.config.mode_a  # start ADAPTIVE (paper §4.2)

    # ----------------------------------------------------------------- select
    def select(self, msg_size_bytes: int, *, alltoall: bool = False) -> Hashable:
        """selectRouting(msgSize) — Algorithm 1."""
        cfg = self.config
        mode_a = cfg.mode_a_alltoall if alltoall else cfg.mode_a
        self.cumulative_bytes += msg_size_bytes

        if self.cumulative_bytes < cfg.cumulative_threshold_bytes:
            # Below the gate: latency-bound regime, always minimal-biased.
            chosen = cfg.mode_b
        else:
            self.cumulative_bytes = 0
            self.decisions += 1
            chosen = self._decide(msg_size_bytes, mode_a)
            self.current = chosen

        self._pending_mode = chosen
        self.sent_bytes_by_mode[chosen] = (
            self.sent_bytes_by_mode.get(chosen, 0) + msg_size_bytes)
        return chosen

    def _decide(self, msg_size_bytes: int, mode_a: Hashable) -> Hashable:
        cfg = self.config
        f, p = flits_and_packets(msg_size_bytes, cfg.is_put)

        if self.current == cfg.mode_b:
            # Dual branch: currently HIGH BIAS, maybe switch back to mode_a.
            perf_b = self.samples.get(cfg.mode_b)
            if perf_b is None:
                return cfg.mode_b  # nothing observed yet, keep going
            perf_a = self._estimate_other(
                perf_b, 1.0 / max(cfg.lambda_latency, 1e-9),
                1.0 / max(cfg.sigma_stalls, 1e-9), mode_a)
        else:
            # Currently mode_a (ADAPTIVE / INCR-MINIMAL for alltoall).
            perf_a = self.samples.get(self.current) \
                or self.samples.get(mode_a)
            if perf_a is None:
                return mode_a
            perf_b = self._estimate_other(
                perf_a, cfg.lambda_latency, cfg.sigma_stalls, cfg.mode_b)
        # Eq.(3): compare the Eq.(2) predictions directly (Eq.(4)'s flit
        # threshold is the rearrangement, valid only for s_b > s_a — the
        # direct form is equivalent there and correct in the corners).
        t_a = transmission_cycles_eq2(
            perf_a.latency_cycles, perf_a.stall_cycles_per_flit, f, p)
        t_b = transmission_cycles_eq2(
            perf_b.latency_cycles, perf_b.stall_cycles_per_flit, f, p)
        return cfg.mode_b if t_b < t_a else mode_a

    def _estimate_other(self, known: ModePerformance, lam: float, sig: float,
                        other_mode: Hashable) -> ModePerformance:
        """Return the stored sample for `other_mode` unless it is too old,
        in which case scale the known mode's sample by (λ, σ) — paper §4.2."""
        stored = self.samples.get(other_mode)
        if stored is not None and stored.age <= self.config.max_sample_age:
            return stored
        return ModePerformance(
            latency_cycles=known.latency_cycles * lam,
            stall_cycles_per_flit=known.stall_cycles_per_flit * sig,
        )

    # ---------------------------------------------------------------- observe
    def observe(self, latency_cycles: float, stalls_per_flit: float) -> None:
        """Feed back the NIC counters measured for the last-sent message.
        Called *after* the send (paper: 'Counters are read after sending the
        message to not introduce delays in the transmission')."""
        if self._pending_mode is None:
            return
        # Age every stored sample, then refresh the used mode's slot.
        self.samples = {m: perf.aged() for m, perf in self.samples.items()}
        self.samples[self._pending_mode] = ModePerformance(
            latency_cycles, stalls_per_flit, age=0)
        self._pending_mode = None

    # ------------------------------------------------------------------ stats
    def traffic_fraction(self, mode: Hashable) -> float:
        """Fraction of bytes sent with `mode` (the x-axis % in Fig. 8/9)."""
        total = sum(self.sent_bytes_by_mode.values())
        if total == 0:
            return 0.0
        return self.sent_bytes_by_mode.get(mode, 0) / total
