"""Scaling-factor calibration (λ, σ) — paper §4.2.

λ maps the latency observed under ADAPTIVE to an estimate of the latency
under HIGH BIAS (λ = median L_bs / L_ad over benchmark sweeps); σ does the
same for stalls.  The paper derives them "by considering a median case over
several runs of different microbenchmarks in different allocations"; we do
exactly that against the Dragonfly simulator (benchmarks/fig7 feeds this).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Tuple

import numpy as np


@dataclass(frozen=True)
class ScalingFactors:
    lambda_latency: float   # λ: L_bs ≈ λ · L_ad
    sigma_stalls: float     # σ: s_bs ≈ σ · s_ad
    n_runs: int

    def as_router_kwargs(self) -> dict:
        return {"lambda_latency": self.lambda_latency,
                "sigma_stalls": self.sigma_stalls}


def calibrate_scaling_factors(
    paired_observations: Iterable[Tuple[float, float, float, float]],
    eps: float = 1e-9,
) -> ScalingFactors:
    """paired_observations: iterable of (L_ad, s_ad, L_bs, s_bs) tuples, one
    per (microbenchmark, allocation) run with the two modes alternated on
    successive iterations (the paper's §5 protocol, which cancels transient
    noise).  Returns median ratios."""
    lam, sig = [], []
    n = 0
    for l_ad, s_ad, l_bs, s_bs in paired_observations:
        n += 1
        if l_ad > eps:
            lam.append(l_bs / l_ad)
        if s_ad > eps:
            sig.append(s_bs / s_ad)
    if not lam and not sig:
        raise ValueError("no usable observations for calibration")
    return ScalingFactors(
        lambda_latency=float(np.median(lam)) if lam else 1.0,
        sigma_stalls=float(np.median(sig)) if sig else 1.0,
        n_runs=n,
    )
