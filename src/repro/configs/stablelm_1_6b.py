"""stablelm-2-1.6b [hf:stabilityai/stablelm-2-1_6b; unverified].

24L d_model=2048 32H (kv=32, i.e. MHA) d_ff=5632 vocab=100352.
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family=Family.DENSE,
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352, act="silu", glu=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, remat=False)
