"""qwen2-1.5b — GQA, QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family=Family.DENSE,
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, act="silu", glu=True, qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=512, remat=False)
