"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060; unverified].

24L d_model=768 attention-free, vocab=50280, ssm_state=128.
Runs long_500k (O(1)-state decode).
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family=Family.SSM,
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_chunk=128,
    ssm_expand=2, tie_embeddings=True,
    supports_long_context=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, vocab=512, ssm_state=16,
                      ssm_head_dim=16, ssm_chunk=8, remat=False)
