"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242;
unverified].

81 Mamba2 layers, d_model=3584; the SHARED attention block (32H, kv=32,
d_ff=14336) is applied every 6 Mamba layers (13 supers: 12 applications +
3 trailing Mamba layers).  ssm_state=64.  Runs long_500k (hybrid).
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family=Family.HYBRID,
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_chunk=128, ssm_expand=2,
    shared_attn_period=6, act="silu", glu=True,
    supports_long_context=True,
)

SMOKE = CONFIG.scaled(n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, ssm_state=16, ssm_head_dim=16,
                      ssm_chunk=8, shared_attn_period=2, remat=False)
