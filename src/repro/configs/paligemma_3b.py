"""paligemma-3b — SigLIP + gemma VLM backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.  The SigLIP vision
tower is a stub (precomputed patch embeddings, 256 tokens at 224px/14px
patches); the gemma decoder uses GeGLU and tied embeddings.
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family=Family.VLM,
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, act="gelu", glu=True, tie_embeddings=True,
    img_tokens=256, rope_theta=10000.0,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                      head_dim=16, d_ff=128, vocab=512, img_tokens=8,
                      remat=False)
