"""whisper-large-v3 — enc-dec, conv frontend stub [arXiv:2212.04356;
unverified].

32 encoder + 32 decoder layers, d_model=1280 20H (kv=20) d_ff=5120
vocab=51866, plain-GELU MLPs.  The mel conv frontend is a STUB: the model
consumes precomputed frame embeddings [B, 1500, 1280].
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family=Family.ENCDEC,
    n_layers=32, n_encoder_layers=32, d_model=1280, n_heads=20,
    n_kv_heads=20, d_ff=5120, vocab=51866, act="gelu", glu=False,
    # 1500 mel frames padded to 1504 (§Perf: neither 1500 frames nor 20
    # heads divide the 16-way model axis — 4 pad frames let the cross-attn
    # KV cache shard 16-way, cutting decode HBM reads per chip 16x)
    encoder_frames=1504,
)

SMOKE = CONFIG.scaled(n_layers=2, n_encoder_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                      encoder_frames=16, remat=False)
