"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (kv=16) vocab=151936; 60 routed experts top-4 with
per-expert d_ff=1408 + 4 shared experts.
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family=Family.MOE,
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, d_ff_expert=1408, vocab=151936,
    n_experts=60, top_k=4, n_shared_experts=4,
    act="silu", glu=True, qkv_bias=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=64, d_ff_expert=64, vocab=512, n_experts=8,
                      top_k=2, n_shared_experts=1, remat=False)
