"""--arch <id> registry over the 10 assigned architectures."""

from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

_MODULES = {
    "paligemma-3b": "repro.configs.paligemma_3b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "llama3-8b": "repro.configs.llama3_8b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCHS)}")
    return importlib.import_module(_MODULES[arch]).SMOKE


def list_archs() -> tuple:
    return ARCHS
