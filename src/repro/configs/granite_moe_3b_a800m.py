"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-*; hf].

32L d_model=1536 24H (GQA kv=8) vocab=49155, MoE 40 experts top-8 with
per-expert d_ff=512 (assignment spec line; the hf 1b-a400m sibling uses 32
experts — we follow the assigned 40e/top-8).
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family=Family.MOE,
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, d_ff_expert=512, vocab=49155,
    n_experts=40, top_k=8, act="silu", glu=True, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=64, d_ff_expert=64, vocab=512, n_experts=8,
                      top_k=2, remat=False)
