"""codeqwen1.5-7b — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B; hf].

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416, QKV bias.
"""
from repro.models.common import Family, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family=Family.DENSE,
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, act="silu", glu=True, qkv_bias=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=512, remat=False)
