"""The assigned input-shape sets (one set, shared by all LM archs).

    train_4k      seq 4096,   global_batch 256   -> train_step
    prefill_32k   seq 32768,  global_batch 32    -> serve prefill
    decode_32k    seq 32768,  global_batch 128   -> serve decode (1 token
                                                    against a 32k cache)
    long_500k     seq 524288, global_batch 1     -> long-context decode;
                  needs sub-quadratic attention: SSM/hybrid only (DESIGN.md)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import Family, ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


class ShapeNotSupported(Exception):
    """Raised for documented skips (long_500k on pure full-attention)."""


def check_supported(cfg: ModelConfig, shape: InputShape) -> None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        raise ShapeNotSupported(
            f"{cfg.name}: long_500k requires sub-quadratic attention "
            f"(documented skip for pure full-attention archs, DESIGN.md §4)")


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   {tokens [B,S], labels [B,S]} (+ stub frontend inputs)
    prefill: {tokens [B,S]} (+ stubs)
    decode:  {token [B,1]}  (cache/state shapes come from make_decode_state)
    """
    check_supported(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == Family.ENCDEC and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_frames, cfg.d_model), f)
    if cfg.family == Family.VLM and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.img_tokens, cfg.d_model), f)
    return specs
