# repro.configs — one module per assigned architecture (exact published
# dims) + the input-shape sets + the registry used by --arch <id> flags.

from repro.configs.registry import ARCHS, get_config, get_smoke_config, list_archs
from repro.configs.shapes import SHAPES, InputShape, ShapeNotSupported, input_specs, check_supported

__all__ = [
    "ARCHS", "get_config", "get_smoke_config", "list_archs",
    "SHAPES", "InputShape", "ShapeNotSupported", "input_specs",
    "check_supported",
]
