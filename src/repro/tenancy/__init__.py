"""repro.tenancy — multi-tenant interference on one Dragonfly.

K co-running jobs (node-disjoint allocations, shared links) interleaved
into ONE batched simulator via TenantSegments; per-tenant observables
split back out; victim slowdown scored against run-alone baselines.
See docs/interference.md.

    from repro.tenancy import (InterferenceEngine, TenancyMix, Workload,
                               sweep)

    mix = TenancyMix("pp-vs-a2a", (
        Workload("victim", "pingpong", 32, arm=RoutingMode.ADAPTIVE_3),
        Workload("aggr", "alltoall", 64, arm=RoutingMode.ADAPTIVE_0)))
    res = InterferenceEngine(topo).run_mix(mix, rounds=4)
    res.victim_slowdown      # mix time / run-alone time
"""

from repro.tenancy.engine import (InterferenceEngine, MixResult,
                                  TenantReport, arm_label,
                                  run_mixes_lockstep)
from repro.tenancy.spec import TenancyMix, Workload
from repro.tenancy.sweep import sweep

__all__ = [
    "InterferenceEngine", "MixResult", "TenantReport", "arm_label",
    "TenancyMix", "Workload", "sweep", "run_mixes_lockstep",
]
