"""InterferenceEngine — K co-running jobs on ONE batched simulator.

Each round interleaves every tenant's next phase into a single flattened
flow batch (`TenantSegments` marks the per-tenant segments), runs it
through `DragonflySimulator.run_phase(tenants=...)` — one fixed point
over the SHARED links, reusing the PR-3 bincount/segment-sum fast path —
and splits the observables back out per tenant: completion time, NIC
counters, latency/stall feedback to each tenant's PolicyEngine, and the
per-tenant link-load breakdown.

Victim slowdown (the interference matrix's cell metric) is the mix time
divided by a run-alone baseline: the same tenant, same allocation, same
seed, on a FRESH simulator with nobody else on the machine.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.counters import NICCounters
from repro.core.strategies import RoutingMode
from repro.dragonfly.routing import RoutingPolicy
from repro.dragonfly.simulator import (DragonflySimulator, SimParams,
                                       TenantSegments)
from repro.dragonfly.topology import Topology, make_topology
from repro.dragonfly.traffic import PATTERN_KIND, engine_for_arm
from repro.policy import DecisionBatch, KIND_PT2PT
from repro.tenancy.spec import TenancyMix, Workload


def arm_label(arm) -> str:
    """Stable display/JSON label of a routing arm."""
    return arm if isinstance(arm, str) else getattr(arm, "name", str(arm))


@dataclass
class TenantReport:
    """One tenant's observables over a mix run."""

    name: str
    arm: str
    time_us: float                    # sum of per-round completion + host
    mean_latency_us: float
    mean_stalls: float
    nonmin_fraction: float            # byte-weighted, from the breakdown
    nic: NICCounters                  # this allocation's counter snapshot
    alone_time_us: float | None = None
    #: per-round completion + host time (recovery metrics need the
    #: trajectory, not just the sum)
    round_times_us: list = field(default_factory=list)
    #: app flows that lost every candidate path to faults, summed over
    #: rounds (docs/faults.md)
    stranded_flows: int = 0
    #: fault recovery (run_mix(faults=...) only, docs/faults.md):
    #: rounds after the last fault clears until the per-round time is
    #: back within tolerance of the pre-fault baseline, and the time
    #: spent above baseline getting there.  -1 = never recovered within
    #: the run; None = no faults / faults never clear.
    recovery_rounds: int | None = None
    recovery_time_us: float | None = None

    @property
    def slowdown(self) -> float | None:
        """Mix time over run-alone time (1.0 == no interference)."""
        if self.alone_time_us is None or self.alone_time_us <= 0.0:
            return None
        return self.time_us / self.alone_time_us


@dataclass
class MixResult:
    """One (mix, policy, placement) cell of the interference matrix."""

    mix: str
    rounds: int
    victim: int
    tenants: list                     # [TenantReport], tenant order
    #: [K+1, n_links] mean per-round backlog bytes (row K = background)
    tenant_link_loads: np.ndarray | None = None
    #: fault schedule summary when run with run_mix(faults=...), else None
    faults: list | None = None

    @property
    def victim_report(self) -> TenantReport:
        return self.tenants[self.victim]

    @property
    def victim_slowdown(self) -> float | None:
        return self.victim_report.slowdown


class InterferenceEngine:
    """Run TenancyMix instances and score per-tenant interference.

    shared_engine: tenants whose arm is the SAME policy name share one
    PolicyEngine; their per-site learned state stays separate because
    decision sites are namespaced ``(tenant_name, pattern)`` — recover a
    tenant's view with `repro.policy.scoped_site_filter(tenant_name)`.
    Default is one engine per tenant (independent jobs).
    """

    #: §5.1 counter-read overhead paid per phase by engine-driven arms
    counter_read_overhead_us: float = 0.35

    def __init__(self, topo: Topology | str | None = None,
                 params: SimParams | None = None, *,
                 seed: int = 0, shared_engine: bool = False):
        self.params = params or SimParams()
        # topo may be a Topology, a make_topology spec string, or None
        # (resolve SimParams.topology); a mix's own `topology` overrides
        self.topo = make_topology(topo if topo is not None
                                  else self.params.topology)
        self.seed = seed
        self.shared_engine = shared_engine
        self._base_policy = RoutingPolicy(RoutingMode.ADAPTIVE_0)

    # ----------------------------------------------------------- internals
    def _engines_for(self, workloads: Sequence[Workload],
                     sim: DragonflySimulator) -> dict:
        """tenant index -> PolicyEngine for every named-policy arm."""
        engines: dict = {}
        by_name: dict = {}
        for k, w in enumerate(workloads):
            if not w.is_engine_arm:
                continue
            if self.shared_engine and w.arm in by_name:
                engines[k] = by_name[w.arm]
                continue
            eng = engine_for_arm(w.arm, sim, seed=self.seed + k)
            engines[k] = by_name[w.arm] = eng
        return engines

    def _topo_for(self, mix: TenancyMix) -> Topology:
        """The machine a mix runs on: its own topology spec, else ours."""
        return make_topology(mix.topology) if mix.topology else self.topo

    def _run(self, workloads: Sequence[Workload], allocs: Sequence,
             rounds: int, topo: Topology | None = None, faults=None):
        """Core loop: returns ([TenantReport], mean tenant_link_loads).

        Sequential driver over `_run_steps` — one run_phase per yielded
        request.  `run_mixes_lockstep` drives the same generator with
        phases batched across cells; both orderings are identical per
        cell because each generator owns its simulator and RNG."""
        gen = self._run_steps(workloads, allocs, rounds, topo=topo,
                              faults=faults)
        res = None
        while True:
            try:
                sim, kwargs = gen.send(res)
            except StopIteration as stop:
                return stop.value
            res = sim.run_phase(**kwargs)

    def _run_steps(self, workloads: Sequence[Workload], allocs: Sequence,
                   rounds: int, topo: Topology | None = None, faults=None):
        """Core loop as a generator: yields ``(sim, run_phase kwargs)``
        per round, receives the FlowResult back via ``send``, and
        returns ([TenantReport], mean tenant_link_loads).

        Builds a FRESH simulator (deterministic in SimParams.seed), so a
        K=1 call is the run-alone baseline of that tenant on the same
        nodes — and is bit-identical, round for round, to driving
        run_phase(allocation=...) by hand (tests/test_tenancy.py).

        `faults` (optional FaultSchedule, docs/faults.md): phase indices
        are ROUND indices (one run_phase per round).  On every fault-
        epoch transition each engine-armed tenant's policy samples are
        reset via ``on_fault_epoch`` — measurements from the previous
        link set would contaminate Algorithm 1's regime decisions.
        """
        sim = DragonflySimulator(topo if topo is not None else self.topo,
                                 self.params, faults=faults)
        p = self.params
        engines = self._engines_for(workloads, sim)
        phases = [w.phases() for w in workloads]
        K = len(workloads)
        time_us = np.zeros(K)
        lat: list = [[] for _ in range(K)]
        stl: list = [[] for _ in range(K)]
        nmf: list = [[] for _ in range(K)]
        wts: list = [[] for _ in range(K)]
        round_t: list = [[] for _ in range(K)]
        stranded = np.zeros(K, dtype=np.int64)
        loads_acc = None
        last_epoch = 0
        for r in range(rounds):
            if sim.faults is not None:
                ep = sim.faults.epoch_at(r)
                if ep != last_epoch:
                    last_epoch = ep
                    from repro.policy import scoped_site_filter
                    for k, w in enumerate(workloads):
                        if w.is_engine_arm:
                            engines[k].on_fault_epoch(
                                scoped_site_filter(w.name))
            srcs, dsts, byts, mode_l, counts = [], [], [], [], []
            for k, w in enumerate(workloads):
                s, d, b = phases[k][r % len(phases[k])]
                nodes = np.asarray(allocs[k].nodes)
                srcs.append(nodes[s])
                dsts.append(nodes[d])
                byts.append(np.asarray(b, dtype=np.float64))
                counts.append(len(b))
                if w.is_engine_arm:
                    batch = DecisionBatch.of(
                        b, site=(w.name, w.pattern),
                        kind=PATTERN_KIND.get(w.pattern, KIND_PT2PT))
                    mode_l.append(np.asarray(engines[k].decide(batch),
                                             dtype=object))
                else:
                    m = np.empty(len(b), dtype=object)
                    m[:] = w.arm
                    mode_l.append(m)
            seg = TenantSegments.of(allocs, counts)
            res = yield sim, dict(
                src_nodes=np.concatenate(srcs),
                dst_nodes=np.concatenate(dsts),
                bytes_=np.concatenate(byts), policy=self._base_policy,
                modes=np.concatenate(mode_l), tenants=seg)
            if res.tenant_link_loads is not None:
                loads_acc = res.tenant_link_loads if loads_acc is None \
                    else loads_acc + res.tenant_link_loads
            # split observables back out, tenant order (the host-noise
            # draws consume sim.rng in this order: K=1 matches the
            # single-app run_iteration stream exactly)
            for k, w in enumerate(workloads):
                rows = res.tenant_slice(k)
                if w.is_engine_arm and rows.size:
                    # post-send counter read feeding THIS tenant's engine
                    # (notified exposure sliced per tenant like (L, s):
                    # no cross-tenant leakage through the new counter)
                    nf = res.notified
                    if rows.size == counts[k]:
                        engines[k].bus.publish_flow_arrays(
                            res.latency_us[rows], res.stalls_per_flit[rows],
                            notified=None if nf is None else nf[rows])
                    else:
                        # statistically subsampled: phase-mean sample
                        engines[k].bus.publish_flow_arrays(
                            [float(res.latency_us[rows].mean())],
                            [float(res.stalls_per_flit[rows].mean())],
                            notified=None if nf is None
                            else [float(nf[rows].mean())])
                host = p.host_overhead_us * sim.rng.lognormal(
                    0.0, p.host_noise_sigma)
                if w.is_engine_arm:
                    host += self.counter_read_overhead_us
                t_k = float(res.t_us[rows].max()) if rows.size else 0.0
                time_us[k] += t_k + host
                round_t[k].append(t_k + host)
                if res.stranded is not None and rows.size:
                    stranded[k] += int(res.stranded[rows].sum())
                if rows.size:
                    lat[k].append(float(res.latency_us[rows].mean()))
                    stl[k].append(float(res.stalls_per_flit[rows].mean()))
                    nmf[k].append(float(res.tenant_nonmin_fraction[k]))
                    wts[k].append(float(byts[k].sum()))
        reports = []
        for k, w in enumerate(workloads):
            wk = np.asarray(wts[k]) if wts[k] else np.ones(1)
            reports.append(TenantReport(
                name=w.name, arm=arm_label(w.arm),
                time_us=float(time_us[k]),
                mean_latency_us=float(np.average(lat[k], weights=wk))
                if lat[k] else 0.0,
                mean_stalls=float(np.average(stl[k], weights=wk))
                if stl[k] else 0.0,
                nonmin_fraction=float(np.average(nmf[k], weights=wk))
                if nmf[k] else 0.0,
                nic=sim.counters.get(allocs[k].allocation_id,
                                     NICCounters()).snapshot(),
                round_times_us=round_t[k],
                stranded_flows=int(stranded[k])))
        if loads_acc is not None and rounds:
            loads_acc = loads_acc / rounds
        return reports, loads_acc

    # ------------------------------------------------------------- public
    def run_alone(self, mix: TenancyMix, k: int, *, rounds: int = 4,
                  allocs: Sequence | None = None) -> TenantReport:
        """Tenant k's run-alone baseline: same allocation, empty machine."""
        topo = self._topo_for(mix)
        allocs = allocs if allocs is not None \
            else mix.materialize(topo, seed=self.seed)
        reports, _ = self._run((mix.workloads[k],), [allocs[k]], rounds,
                               topo=topo)
        return reports[0]

    #: a round counts as recovered when its time is back within this
    #: factor of the pre-fault per-round baseline
    recovery_tolerance: float = 1.10

    def _recovery(self, times: list, faults, clean=None) -> tuple:
        """(recovery_rounds, recovery_time_us) from one tenant's
        per-round trajectory (docs/faults.md).

        `clean` (when given) is the same tenant's round trajectory from
        a fault-free companion run of the SAME mix/seed — the round-for-
        round baseline.  Workload phase lists cycle (round r replays
        phase ``r % L``), so per-round times are periodic and a flat
        scalar baseline would misread phase structure as non-recovery;
        the companion trajectory compares like phase with like phase.
        Without `clean`, baseline falls back to the mean pre-fault
        per-round time (min over the run when faults start at round 0).

        From the round the last fault clears, the first round back
        within ``recovery_tolerance`` of its baseline marks recovery;
        the rounds until then and the time they consumed are the
        metrics.  (None, None) when the faults never clear inside the
        run; (-1, -1.0) when they clear but the tenant never gets back
        to baseline.
        """
        first = faults.first_start()
        clear = faults.all_clear_phase()
        if first is None or clear is None or clear >= len(times):
            return None, None
        if clean is None:
            base = float(np.mean(times[:first])) if first > 0 \
                else float(np.min(times))
            clean = [base] * len(times)
        for i in range(clear, len(times)):
            if times[i] <= self.recovery_tolerance * clean[i]:
                return i - clear, float(np.sum(times[clear:i]))
        return -1, -1.0

    def run_mix(self, mix: TenancyMix, *, rounds: int = 4,
                baselines: bool = True, faults=None) -> MixResult:
        """Run the whole mix; with baselines, score per-tenant slowdown.

        `faults` (optional FaultSchedule): inject faults into the mix
        run — round index == fault phase index.  Run-alone baselines
        stay CLEAN (healthy machine), so victim slowdown under faults
        reports the tenant's TOTAL degradation (interference + faults);
        comparing policies under the same schedule isolates the policy
        effect.  Per-tenant recovery metrics (recovery_rounds /
        recovery_time_us) are scored against a fault-free companion run
        of the same mix (round-for-round baseline, see _recovery).
        """
        topo = self._topo_for(mix)
        allocs = mix.materialize(topo, seed=self.seed)
        reports, loads = self._run(mix.workloads, allocs, rounds,
                                   topo=topo, faults=faults)
        if baselines:
            for k in range(len(mix)):
                alone = self.run_alone(mix, k, rounds=rounds, allocs=allocs)
                reports[k].alone_time_us = alone.time_us
        if faults:
            clean, _ = self._run(mix.workloads, allocs, rounds, topo=topo)
            for rep, ref in zip(reports, clean):
                rep.recovery_rounds, rep.recovery_time_us = \
                    self._recovery(rep.round_times_us, faults,
                                   clean=ref.round_times_us)
        return MixResult(mix=mix.name, rounds=rounds, victim=mix.victim,
                         tenants=reports, tenant_link_loads=loads,
                         faults=faults.describe() if faults else None)


# ------------------------------------------------------- lockstep driving
def _drive_lockstep(gens) -> list:
    """Advance several `_run_steps` generators round-for-round.

    Each round, every live generator's pending phase request is handed
    to `run_phase_batch` as ONE call — jax-backed cells with matching
    kernel shapes run as a single vmapped dispatch.  Per-cell results
    are identical to sequential driving: each generator owns its
    simulator and RNG stream, so only the dispatch is shared."""
    from repro.dragonfly.simulator import run_phase_batch

    rets = [None] * len(gens)
    reqs = [None] * len(gens)
    live = []
    for i, gen in enumerate(gens):
        try:
            reqs[i] = gen.send(None)
            live.append(i)
        except StopIteration as stop:
            rets[i] = stop.value
    while live:
        outs = run_phase_batch([reqs[i] for i in live])
        nxt = []
        for i, res in zip(live, outs):
            try:
                reqs[i] = gens[i].send(res)
                nxt.append(i)
            except StopIteration as stop:
                rets[i] = stop.value
        live = nxt
    return rets


def run_mixes_lockstep(engines, mixes, *, rounds: int = 4,
                       baselines: bool = True) -> list:
    """[MixResult] for N (engine, mix) cells advanced in lockstep.

    The batched counterpart of ``[e.run_mix(m) for e, m in ...]`` for
    fault-free cells: every cell's round-r phase kernel is dispatched
    together through `run_phase_batch` (one vmapped jax call when the
    column's shapes agree — the sweep-column case, where cells differ
    only in the victim's routing arm), and so are the per-tenant
    run-alone baselines.  Cell-for-cell results match the sequential
    path: batching changes the dispatch, never the draws."""
    prepped = []
    for eng, mix in zip(engines, mixes):
        topo = eng._topo_for(mix)
        allocs = mix.materialize(topo, seed=eng.seed)
        prepped.append((eng, mix, topo, allocs))
    outs = _drive_lockstep([
        eng._run_steps(mix.workloads, allocs, rounds, topo=topo)
        for eng, mix, topo, allocs in prepped])
    alone: dict = {}
    if baselines:
        for k in range(max(len(m) for _, m, _, _ in prepped)):
            idx = [i for i, (_, m, _, _) in enumerate(prepped)
                   if k < len(m)]
            base = _drive_lockstep([
                prepped[i][0]._run_steps(
                    (prepped[i][1].workloads[k],), [prepped[i][3][k]],
                    rounds, topo=prepped[i][2])
                for i in idx])
            for i, (reports, _) in zip(idx, base):
                alone[(i, k)] = reports[0].time_us
    results = []
    for i, ((eng, mix, topo, allocs), (reports, loads)) in \
            enumerate(zip(prepped, outs)):
        for k, rep in enumerate(reports):
            if (i, k) in alone:
                rep.alone_time_us = alone[(i, k)]
        results.append(MixResult(mix=mix.name, rounds=rounds,
                                 victim=mix.victim, tenants=reports,
                                 tenant_link_loads=loads, faults=None))
    return results
