"""Tenant and job-mix specifications.

A `Workload` is one co-running job's recipe: traffic pattern, scale,
placement tier and routing arm.  A `TenancyMix` is K of them sharing one
physical Dragonfly; `materialize()` turns the recipe into K node-DISJOINT
Allocations (co-tenants contend on links and global channels, never on
NICs — the paper's production setting, where the scheduler hands every
job its own nodes but the network is shared).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.strategies import RoutingMode
from repro.dragonfly.topology import Allocation, Topology, make_allocation
from repro.dragonfly.traffic import PATTERNS


@dataclass(frozen=True)
class Workload:
    """One tenant job: what it sends, where it sits, how it routes.

    arm: a RoutingMode member (static routing, broadcast over the
    tenant's flows) or a repro.policy name ("app_aware" | "eps_greedy" |
    "static") — named arms get a PolicyEngine deciding per phase.
    """

    name: str
    pattern: str                          # repro.dragonfly.traffic.PATTERNS
    n_ranks: int
    pattern_args: Mapping = field(default_factory=dict)
    arm: object = RoutingMode.ADAPTIVE_0
    spread: str = "scattered"             # make_allocation placement tier

    def __post_init__(self):
        if self.pattern not in PATTERNS:
            raise ValueError(f"unknown pattern {self.pattern!r}; expected "
                             f"one of {sorted(PATTERNS)}")

    @property
    def is_engine_arm(self) -> bool:
        """True when `arm` names a repro.policy PolicyEngine."""
        return isinstance(self.arm, str)

    def phases(self):
        """The job's per-iteration phase list [(src, dst, bytes), ...]."""
        return PATTERNS[self.pattern](self.n_ranks, **dict(self.pattern_args))

    def with_arm(self, arm) -> "Workload":
        return dataclasses.replace(self, arm=arm)

    def with_spread(self, spread: str) -> "Workload":
        return dataclasses.replace(self, spread=spread)


@dataclass(frozen=True)
class TenancyMix:
    """K workloads co-scheduled on one machine; workloads[victim] is the
    job whose slowdown the interference matrix reports (the rest are the
    aggressors)."""

    name: str
    workloads: tuple
    victim: int = 0
    #: optional topology spec for this mix (make_topology string); None
    #: means the engine/sweep caller's machine.  docs/topology.md.
    topology: str | None = None

    def __post_init__(self):
        if not self.workloads:
            raise ValueError("a TenancyMix needs at least one workload")
        if not 0 <= self.victim < len(self.workloads):
            raise ValueError(f"victim index {self.victim} out of range")
        names = [w.name for w in self.workloads]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate workload names in {names}")

    def __len__(self) -> int:
        return len(self.workloads)

    @property
    def victim_workload(self) -> Workload:
        return self.workloads[self.victim]

    def with_victim_arm(self, arm) -> "TenancyMix":
        """The sweep's policy axis: swap the victim's routing arm."""
        ws = list(self.workloads)
        ws[self.victim] = ws[self.victim].with_arm(arm)
        return dataclasses.replace(self, workloads=tuple(ws))

    def with_victim_spread(self, spread: str) -> "TenancyMix":
        """The sweep's placement axis: re-place the victim."""
        ws = list(self.workloads)
        ws[self.victim] = ws[self.victim].with_spread(spread)
        return dataclasses.replace(self, workloads=tuple(ws))

    def materialize(self, topo: Topology, *,
                    seed: int = 0, max_tries: int = 64) -> list:
        """Draw node-DISJOINT allocations, one per workload.

        Deterministic in (mix, topo, seed): each tenant retries its
        placement seed until it avoids every earlier tenant's nodes, so
        the same mix on the same machine always lands the same way —
        run-alone baselines reuse these exact allocations.
        """
        allocs: list = []
        used: set = set()
        for i, w in enumerate(self.workloads):
            if w.spread == "scattered":
                # dense mixes: draw straight from the unused-node pool
                # (independent redraws would collide almost surely)
                pool = np.asarray(sorted(set(range(topo.n_nodes))
                                         - used), dtype=np.int64)
                if pool.size < w.n_ranks:
                    raise RuntimeError(
                        f"cannot place {w.name!r}: {w.n_ranks} ranks but "
                        f"only {pool.size} free nodes")
                rng = np.random.default_rng(seed + 1009 * i)
                a = Allocation(
                    allocation_id=f"{self.name}/{w.name}",
                    nodes=tuple(int(x) for x in
                                rng.choice(pool, size=w.n_ranks,
                                           replace=False)))
            else:
                for attempt in range(max_tries):
                    a = make_allocation(
                        topo, w.n_ranks, spread=w.spread,
                        seed=seed + 1009 * i + attempt,
                        allocation_id=f"{self.name}/{w.name}")
                    if used.isdisjoint(a.nodes):
                        break
                else:
                    raise RuntimeError(
                        f"could not place {w.name!r} disjointly after "
                        f"{max_tries} tries (machine too small for the "
                        f"mix?)")
            used.update(a.nodes)
            allocs.append(a)
        return allocs
