"""(job-mix × victim-policy × placement) grid driver.

`sweep()` fills the interference matrix the benchmark / paper discussion
needs: for every mix, every candidate routing arm is installed on the
VICTIM (the aggressors keep their specced arms — they are other people's
jobs), optionally across victim placement tiers, and the victim's
slowdown vs its run-alone baseline is recorded.  The qualitative Kang
result this reproduces: adaptive-heavy aggressors inflate minimal-routed
victims, and the app-aware arm keeps the victim closer to run-alone than
fully-adaptive routing does.
"""

from __future__ import annotations

from typing import Mapping, Sequence

from repro.dragonfly.simulator import SimParams
from repro.dragonfly.topology import Topology
from repro.tenancy.engine import (InterferenceEngine, arm_label,
                                  run_mixes_lockstep)
from repro.tenancy.spec import TenancyMix


def _auto_lockstep(params: SimParams | None) -> bool:
    if params is None or params.backend != "jax":
        return False
    from repro.compat.runtime import resolve_backend
    return resolve_backend("jax") == "jax"


def sweep(topo: Topology | str | None, mixes: Sequence[TenancyMix],
          arms: Mapping, *, params: SimParams | None = None,
          rounds: int = 4, seed: int = 0,
          placements: Sequence = (None,),
          shared_engine: bool = False,
          lockstep: bool | None = None) -> list:
    """Run the grid; one flat record dict per cell.

    arms: {label: RoutingMode member | policy name} — the victim's
    candidate routing arms.  placements: victim spread overrides (None ==
    keep the mix's specced placement).  Every cell re-seeds its own
    InterferenceEngine so cells are independent and order-insensitive.

    lockstep: drive each (mix, placement) column's arm cells
    round-for-round through one batched phase dispatch
    (`run_mixes_lockstep`) instead of cell-after-cell.  Default None
    auto-enables it when the params ask for a usable jax backend, where
    the column becomes a single vmapped kernel call per round; records
    are identical either way because every cell keeps its own simulator
    and RNG stream.
    """
    if lockstep is None:
        lockstep = _auto_lockstep(params)
    records = []
    for mix in mixes:
        for place in placements:
            m = mix if place is None else mix.with_victim_spread(place)
            labels = list(arms.items())
            cells = [m.with_victim_arm(arm) for _, arm in labels]
            engines = [InterferenceEngine(topo, params, seed=seed,
                                          shared_engine=shared_engine)
                       for _ in cells]
            if lockstep and len(cells) > 1:
                col = run_mixes_lockstep(engines, cells, rounds=rounds)
            else:
                col = [eng.run_mix(cell, rounds=rounds)
                       for eng, cell in zip(engines, cells)]
            for (label, arm), eng, cell, res in zip(labels, engines,
                                                    cells, col):
                vic = res.victim_report
                records.append({
                    "mix": mix.name,
                    "topology": eng._topo_for(cell).spec_str(),
                    "policy": label,
                    "arm": arm_label(arm),
                    "placement": place or mix.victim_workload.spread,
                    "victim": vic.name,
                    "victim_slowdown": vic.slowdown,
                    "victim_time_us": vic.time_us,
                    "victim_alone_us": vic.alone_time_us,
                    "victim_nonmin_fraction": vic.nonmin_fraction,
                    "aggressor_slowdowns": {
                        t.name: t.slowdown for i, t in
                        enumerate(res.tenants) if i != res.victim},
                })
    return records
