# repro.data — deterministic synthetic LM data + host-sharded pipeline.

from repro.data.synthetic import SyntheticLM, make_batch
from repro.data.pipeline import DataPipeline, PipelineConfig

__all__ = ["SyntheticLM", "make_batch", "DataPipeline", "PipelineConfig"]
