"""Deterministic synthetic LM data.

A Zipf-ish unigram stream with short-range induction structure (token t+1
repeats token t-k with learned-constant probability), so models actually
reduce loss — useful for the end-to-end training examples without any
dataset dependency.  Fully seeded: (seed, step, shard) -> identical batch
anywhere, which is what checkpoint/restart and elastic rescale tests rely
on (a restarted run replays the exact token stream)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    zipf_a: float = 1.2
    induction_p: float = 0.35
    induction_lag: int = 8

    def batch(self, *, seed: int, step: int, shard: int, n_shards: int,
              batch_size: int) -> dict:
        """Deterministic batch for one host shard of one step."""
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, shard]))
        B, S = batch_size, self.seq_len
        ranks = rng.zipf(self.zipf_a, size=(B, S + 1))
        toks = np.minimum(ranks, self.vocab - 1).astype(np.int32)
        # induction structure: with prob p, token repeats t - lag
        rep = rng.random((B, S + 1)) < self.induction_p
        lag = self.induction_lag
        toks[:, lag:] = np.where(rep[:, lag:], toks[:, :-lag],
                                 toks[:, lag:])
        return {"tokens": toks[:, :S], "labels": toks[:, 1:S + 1]}


def make_batch(cfg, shape, *, seed: int = 0, step: int = 0, shard: int = 0,
               n_shards: int = 1) -> dict:
    """Concrete numpy batch matching configs.shapes.input_specs (incl. the
    stub frontend tensors)."""
    from repro.models.common import Family

    gen = SyntheticLM(vocab=cfg.vocab, seq_len=shape.seq_len)
    b = shape.global_batch // n_shards
    batch = gen.batch(seed=seed, step=step, shard=shard, n_shards=n_shards,
                      batch_size=b)
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard,
                                                        7]))
    if cfg.family == Family.ENCDEC:
        batch["frames"] = rng.standard_normal(
            (b, cfg.encoder_frames, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == Family.VLM:
        batch["patches"] = rng.standard_normal(
            (b, cfg.img_tokens, cfg.d_model)).astype(np.float32) * 0.02
    return batch
