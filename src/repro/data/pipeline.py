"""Host-sharded data pipeline with background prefetch.

Each host process pulls only its shard (shard = process_index), prefetches
`prefetch` batches on a worker thread, and tags every batch with its step
so checkpoint/restart resumes the stream exactly.  Straggler mitigation
hooks in here: a shard that misses the step deadline can be skipped and
its batch re-balanced (runtime/straggler.py drives the policy)."""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field

from repro.data.synthetic import make_batch


@dataclass
class PipelineConfig:
    seed: int = 0
    prefetch: int = 2
    shard: int = 0
    n_shards: int = 1


class DataPipeline:
    def __init__(self, cfg, shape, pcfg: PipelineConfig):
        self.cfg, self.shape, self.pcfg = cfg, shape, pcfg
        self._q: queue.Queue = queue.Queue(maxsize=max(pcfg.prefetch, 1))
        self._step = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self, from_step: int = 0) -> "DataPipeline":
        self._step = from_step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.shape, seed=self.pcfg.seed,
                               step=step, shard=self.pcfg.shard,
                               n_shards=self.pcfg.n_shards)
            batch["_step"] = step
            try:
                self._q.put(batch, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self, timeout: float = 30.0) -> dict:
        return self._q.get(timeout=timeout)

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        # drain
        while not self._q.empty():
            self._q.get_nowait()
